"""Two-tenant HTTP contention smoke (CI gate for DESIGN.md §14 + §15).

Spawns ONE HTTP job manager over a 6-worker pool, then a CLI trainer
(tenant ``train``, priority 0, 4 stages) and a CLI elastic server (tenant
``serve``, priority 10, 2..4 stages, bursty trace) as separate processes.
The serve burst must steal training workers (the trainer shrinks at a safe
point) and the lull must yield them back (the trainer absorbs) — asserted
from both sides' ``--events-out`` streams.

Observability gates (DESIGN.md §15), both tenants run with ``obs.trace``:

  * the manager's ``GET /metrics`` Prometheus page is scraped before
    shutdown and its ``dynmo_scheduler_events_total`` counters must equal
    the per-(tenant, event) counts in the scheduler's own events stream —
    the two views are derived from one list, disagreement is a bug;
  * the two trace files must hold ONE causally-linked cross-process chain
    ``rpc.steal -> cluster.preempt -> resize.shrink`` (serve's steal RPC
    parents train's preemption directive parents train's safe-point
    shrink), validated by ``scripts/check_trace.py``.

  PYTHONPATH=src python scripts/cluster_smoke.py

Exit 0 = contention + observability verified end-to-end; non-zero = a
tenant died, the steal/yield never crossed the scheduler, the metrics
page drifted from the events stream, or the trace chain broke.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import tempfile
import time
import urllib.request

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)

from repro.cluster.http_rpc import HttpJobManager, spawn_http_manager  # noqa: E402

ENV = {**os.environ, "PYTHONPATH": SRC, "REPRO_TRAIN_DEVICES": "4"}


def _spawn_cli(module: str, args: list, log_path: str) -> subprocess.Popen:
    log = open(log_path, "w")
    return subprocess.Popen([sys.executable, "-m", module] + args,
                            stdout=log, stderr=subprocess.STDOUT,
                            text=True, env=ENV)


# label order is the registry's sorted-label identity: event < tenant
_PROM_LINE = re.compile(
    r'^dynmo_scheduler_events_total\{event="([^"]*)",tenant="([^"]*)"\} '
    r'(\d+(?:\.\d+)?)$')


def _check_metrics_page(url: str, events: list) -> list:
    """Scrape GET /metrics and diff the scheduler-event counters against
    the events stream the ``metrics`` RPC verb returned."""
    with urllib.request.urlopen(url + "/metrics", timeout=10) as r:
        assert "version=0.0.4" in r.headers.get("Content-Type", "")
        page = r.read().decode()
    scraped = {}
    for line in page.splitlines():
        m = _PROM_LINE.match(line)
        if m:
            scraped[(m.group(2), m.group(1))] = float(m.group(3))
    expected = {}
    for ev in events:
        key = (str(ev.get("tenant")), ev["ev"])
        expected[key] = expected.get(key, 0.0) + 1.0
    failures = []
    if not scraped:
        failures.append("metrics page had no dynmo_scheduler_events_total")
    if scraped != expected:
        failures.append(f"metrics page drifted from the events stream: "
                        f"scraped={scraped} expected={expected}")
    for ev in events:
        if ev.get("schema") != "obs.event/1" or ev.get("kind") != ev["ev"]:
            failures.append(f"scheduler event missing unified fields: {ev}")
            break
    steals = [ev for ev in events if ev["ev"] == "steal"]
    if steals and not any(ev.get("trace_id") for ev in steals):
        failures.append("no steal event carried a propagated trace_id "
                        "(RPC trace context never reached the scheduler)")
    return failures


def main() -> int:
    run_dir = tempfile.mkdtemp(prefix="cluster_smoke_")
    mgr, url = spawn_http_manager(run_dir, 6, spares=0, idle_timeout_s=900)
    train_events = os.path.join(run_dir, "train_events.json")
    serve_events = os.path.join(run_dir, "serve_events.json")
    train_trace = os.path.join(run_dir, "train.trace.json")
    serve_trace = os.path.join(run_dir, "serve.trace.json")
    train_log = os.path.join(run_dir, "train.log")
    serve_log = os.path.join(run_dir, "serve.log")
    print(f"manager {url} (pool 6, journal {run_dir})")
    children = []
    try:
        train = _spawn_cli("repro.launch.train", [
            "--arch", "smollm-360m", "--layers", "8", "--d-model", "64",
            "--stages", "4", "--steps", "120", "--seq", "32",
            "--num-micro", "2", "--mb-global", "2", "--log-every", "1000",
            "--rebalance-every", "4", "--job-manager", "http",
            "--manager-url", url, "--tenant-id", "train", "--priority", "0",
            "--set", "controller.repack.target=2",
            "--set", "obs.trace=true",
            "--set", f"obs.trace_out={train_trace}",
            "--events-out", train_events], train_log)
        children.append(("train", train, train_log))
        # let the trainer claim its 4 before the server joins, so the serve
        # burst has to STEAL (a fresh pool would hand it free workers)
        probe = HttpJobManager(url, client_id="smoke-probe")
        for _ in range(600):
            t = probe.cluster_metrics()["tenants"].get("train")
            if t and len(t["granted"]) == 4:
                break
            time.sleep(0.1)
        else:
            raise RuntimeError("trainer never registered with the manager")
        print("trainer registered: 4 workers granted")
        serve = _spawn_cli("repro.launch.serve", [
            "--elastic", "--autoscale", "--arch", "smollm-360m",
            "--layers", "8", "--d-model", "64", "--stages", "4",
            "--micro", "2", "--mb-global", "2", "--prompt-len", "8",
            "--gen", "12", "--requests", "300", "--burst-period", "24",
            "--burst-len", "6", "--burst-rate", "4", "--lull-rate", "0",
            "--min-stages", "2", "--queue-high", "2",
            "--occupancy-low", "0.6", "--patience", "2", "--cooldown", "3",
            "--latency-slo-s", "0.5", "--log-every", "1000",
            "--job-manager", "http", "--manager-url", url,
            "--tenant-id", "serve", "--priority", "10",
            "--set", "obs.trace=true",
            "--set", f"obs.trace_out={serve_trace}",
            "--events-out", serve_events], serve_log)
        children.append(("serve", serve, serve_log))
        for name, proc, log_path in children:
            rc = proc.wait(timeout=1500)
            if rc != 0:
                with open(log_path) as f:
                    print(f"--- {name} log tail ---\n{f.read()[-4000:]}")
                raise RuntimeError(f"{name} tenant exited {rc}")
            print(f"{name} tenant finished cleanly")
        # scrape while the manager is still up: the Prometheus page must
        # agree with the events stream it is derived from
        sched_events = probe.cluster_metrics()["events"]
        metrics_failures = _check_metrics_page(url, sched_events)
        print(f"scraped /metrics: {len(sched_events)} scheduler events, "
              f"{len(metrics_failures)} failure(s)")
        probe.close()
    except Exception as e:
        print(f"SMOKE FAILED: {e}", file=sys.stderr)
        for name, proc, log_path in children:
            if proc.poll() is None:
                proc.kill()
            if os.path.exists(log_path):
                with open(log_path) as f:
                    print(f"--- {name} log tail ---\n{f.read()[-2000:]}",
                          file=sys.stderr)
        return 1
    finally:
        try:
            HttpJobManager(url, client_id="smoke-kill", timeout_s=10,
                           shutdown_on_close=True).close()
        except Exception:
            pass
        if mgr.poll() is None:
            mgr.kill()

    with open(train_events) as f:
        train_kinds = [ev["kind"] for ev in json.load(f)]
    with open(serve_events) as f:
        serve_kinds = [ev["kind"] for ev in json.load(f)]
    print(f"train events: {train_kinds}")
    print(f"serve events: {serve_kinds}")
    failures = list(metrics_failures)
    if "steal" not in serve_kinds:
        failures.append("serve never stole (no urgent grow)")
    if "preempt" not in train_kinds:
        failures.append("train never saw the preemption directive")
    if "yield" not in serve_kinds:
        failures.append("serve never yielded back")
    if "absorb" not in train_kinds:
        failures.append("train never absorbed the yielded workers")
    # the two trace files must hold the causally-linked cross-process
    # steal chain (and pass structural validation)
    import check_trace
    rc = check_trace.main([serve_trace, train_trace, "--expect-chain",
                           "rpc.steal,cluster.preempt,resize.shrink"])
    if rc != 0:
        failures.append("trace validation failed (see check_trace output)")
    if failures:
        print("SMOKE FAILED: " + "; ".join(failures), file=sys.stderr)
        for log_path in (train_log, serve_log):
            with open(log_path) as f:
                print(f"--- {log_path} ---\n{f.read()[-2500:]}",
                      file=sys.stderr)
        return 1
    print("SMOKE OK: steal -> safe-point shrink -> yield -> absorb, "
          "two processes, one pool; /metrics == events; trace chain "
          "causally linked across processes")
    return 0


if __name__ == "__main__":
    sys.exit(main())
