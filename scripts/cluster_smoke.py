"""Two-tenant HTTP contention smoke (CI gate for DESIGN.md §14).

Spawns ONE HTTP job manager over a 6-worker pool, then a CLI trainer
(tenant ``train``, priority 0, 4 stages) and a CLI elastic server (tenant
``serve``, priority 10, 2..4 stages, bursty trace) as separate processes.
The serve burst must steal training workers (the trainer shrinks at a safe
point) and the lull must yield them back (the trainer absorbs) — asserted
from both sides' ``--events-out`` streams.

  PYTHONPATH=src python scripts/cluster_smoke.py

Exit 0 = contention observed end-to-end; non-zero = a tenant died or the
steal/yield never crossed the scheduler.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)

from repro.cluster.http_rpc import HttpJobManager, spawn_http_manager  # noqa: E402

ENV = {**os.environ, "PYTHONPATH": SRC, "REPRO_TRAIN_DEVICES": "4"}


def _spawn_cli(module: str, args: list, log_path: str) -> subprocess.Popen:
    log = open(log_path, "w")
    return subprocess.Popen([sys.executable, "-m", module] + args,
                            stdout=log, stderr=subprocess.STDOUT,
                            text=True, env=ENV)


def main() -> int:
    run_dir = tempfile.mkdtemp(prefix="cluster_smoke_")
    mgr, url = spawn_http_manager(run_dir, 6, spares=0, idle_timeout_s=900)
    train_events = os.path.join(run_dir, "train_events.json")
    serve_events = os.path.join(run_dir, "serve_events.json")
    train_log = os.path.join(run_dir, "train.log")
    serve_log = os.path.join(run_dir, "serve.log")
    print(f"manager {url} (pool 6, journal {run_dir})")
    children = []
    try:
        train = _spawn_cli("repro.launch.train", [
            "--arch", "smollm-360m", "--layers", "8", "--d-model", "64",
            "--stages", "4", "--steps", "120", "--seq", "32",
            "--num-micro", "2", "--mb-global", "2", "--log-every", "1000",
            "--rebalance-every", "4", "--job-manager", "http",
            "--manager-url", url, "--tenant-id", "train", "--priority", "0",
            "--set", "controller.repack.target=2",
            "--events-out", train_events], train_log)
        children.append(("train", train, train_log))
        # let the trainer claim its 4 before the server joins, so the serve
        # burst has to STEAL (a fresh pool would hand it free workers)
        probe = HttpJobManager(url, client_id="smoke-probe")
        for _ in range(600):
            t = probe.cluster_metrics()["tenants"].get("train")
            if t and len(t["granted"]) == 4:
                break
            time.sleep(0.1)
        else:
            raise RuntimeError("trainer never registered with the manager")
        print("trainer registered: 4 workers granted")
        serve = _spawn_cli("repro.launch.serve", [
            "--elastic", "--autoscale", "--arch", "smollm-360m",
            "--layers", "8", "--d-model", "64", "--stages", "4",
            "--micro", "2", "--mb-global", "2", "--prompt-len", "8",
            "--gen", "12", "--requests", "300", "--burst-period", "24",
            "--burst-len", "6", "--burst-rate", "4", "--lull-rate", "0",
            "--min-stages", "2", "--queue-high", "2",
            "--occupancy-low", "0.6", "--patience", "2", "--cooldown", "3",
            "--latency-slo-s", "0.5", "--log-every", "1000",
            "--job-manager", "http", "--manager-url", url,
            "--tenant-id", "serve", "--priority", "10",
            "--events-out", serve_events], serve_log)
        children.append(("serve", serve, serve_log))
        for name, proc, log_path in children:
            rc = proc.wait(timeout=1500)
            if rc != 0:
                with open(log_path) as f:
                    print(f"--- {name} log tail ---\n{f.read()[-4000:]}")
                raise RuntimeError(f"{name} tenant exited {rc}")
            print(f"{name} tenant finished cleanly")
        probe.close()
    except Exception as e:
        print(f"SMOKE FAILED: {e}", file=sys.stderr)
        for name, proc, log_path in children:
            if proc.poll() is None:
                proc.kill()
            if os.path.exists(log_path):
                with open(log_path) as f:
                    print(f"--- {name} log tail ---\n{f.read()[-2000:]}",
                          file=sys.stderr)
        return 1
    finally:
        try:
            HttpJobManager(url, client_id="smoke-kill", timeout_s=10,
                           shutdown_on_close=True).close()
        except Exception:
            pass
        if mgr.poll() is None:
            mgr.kill()

    with open(train_events) as f:
        train_kinds = [ev["kind"] for ev in json.load(f)]
    with open(serve_events) as f:
        serve_kinds = [ev["kind"] for ev in json.load(f)]
    print(f"train events: {train_kinds}")
    print(f"serve events: {serve_kinds}")
    failures = []
    if "steal" not in serve_kinds:
        failures.append("serve never stole (no urgent grow)")
    if "preempt" not in train_kinds:
        failures.append("train never saw the preemption directive")
    if "yield" not in serve_kinds:
        failures.append("serve never yielded back")
    if "absorb" not in train_kinds:
        failures.append("train never absorbed the yielded workers")
    if failures:
        print("SMOKE FAILED: " + "; ".join(failures), file=sys.stderr)
        for log_path in (train_log, serve_log):
            with open(log_path) as f:
                print(f"--- {log_path} ---\n{f.read()[-2500:]}",
                      file=sys.stderr)
        return 1
    print("SMOKE OK: steal -> safe-point shrink -> yield -> absorb, "
          "two processes, one pool")
    return 0


if __name__ == "__main__":
    sys.exit(main())
