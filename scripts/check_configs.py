"""config-check: validate every JSON under configs/ against the RunSpec
schema (strict — unknown keys, bad choices, and cross-field violations all
fail), and pin the scenario files to the preset registry.

    PYTHONPATH=src python scripts/check_configs.py

Run by the CI ``config-check`` step; tests/test_api.py covers the same
invariants in tier-1.
"""
import glob
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api.scenarios import SCENARIOS  # noqa: E402
from repro.api.specs import RunSpec, SpecError  # noqa: E402

REPO = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")


def main() -> int:
    paths = sorted(glob.glob(os.path.join(REPO, "configs", "**", "*.json"),
                             recursive=True))
    if not paths:
        print("config-check: no JSON configs found under configs/",
              file=sys.stderr)
        return 1
    failed = False
    for path in paths:
        rel = os.path.relpath(path, REPO)
        try:
            spec = RunSpec.load(path)
        except SpecError as e:
            print(f"FAIL {rel}: {e}", file=sys.stderr)
            failed = True
            continue
        name = os.path.splitext(os.path.basename(path))[0]
        if (os.path.basename(os.path.dirname(path)) == "scenarios"
                and spec != SCENARIOS.get(name)):
            print(f"FAIL {rel}: drifted from repro.api.scenarios preset "
                  f"{name!r}; run scripts/gen_scenarios.py",
                  file=sys.stderr)
            failed = True
            continue
        print(f"ok   {rel}")
    scenario_files = {os.path.splitext(os.path.basename(p))[0]
                      for p in paths
                      if os.path.basename(os.path.dirname(p)) == "scenarios"}
    missing = sorted(set(SCENARIOS) - scenario_files)
    if missing:
        print(f"FAIL configs/scenarios/ missing presets {missing}; run "
              f"scripts/gen_scenarios.py", file=sys.stderr)
        failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
