"""Validate Chrome trace-event files exported by ``repro.obs.trace``.

Structural checks (every file):
  * loads as Chrome trace JSON: ``traceEvents`` list + ``otherData.trace_id``;
  * every event has ``name``/``ph``/``ts``/``pid``/``tid`` and carries the
    tracer identity in ``args`` (``trace_id``, ``span_id``, ``lc``);
  * complete spans (``ph == "X"``) have a non-negative ``dur``;
  * span ids are unique and prefixed by their trace id;
  * logical clocks are unique within one trace (one counter per tracer);
  * every ``parent_id`` resolves to a span in one of the loaded files —
    cross-FILE references are the point: a serve-side steal parents a
    train-side preempt, so pass both traces together.

Causal-chain checks (``--expect-chain a,b,c``): require at least one
sequence of events named ``a`` -> ``b`` -> ``c`` where each link's
``parent_id`` equals the previous event's ``span_id``.  The chaos/cluster
CI gate uses::

  python scripts/check_trace.py serve.trace.json train.trace.json \
      --expect-chain rpc.steal,cluster.preempt,resize.shrink

Exit 0 = all checks pass; non-zero prints every violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

PHASES = {"X", "i", "M"}


def load_trace(path: str, errors: List[str]) -> List[dict]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        errors.append(f"{path}: unreadable ({e})")
        return []
    if not isinstance(doc.get("traceEvents"), list):
        errors.append(f"{path}: no traceEvents list")
        return []
    other = doc.get("otherData") or {}
    if not other.get("trace_id"):
        errors.append(f"{path}: otherData.trace_id missing")
    events = []
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"{path}#{i}"
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                errors.append(f"{where}: missing {key!r}")
        if ev.get("ph") not in PHASES:
            errors.append(f"{where}: bad phase {ev.get('ph')!r}")
            continue
        if ev.get("ph") == "X" and ev.get("dur", -1) < 0:
            errors.append(f"{where}: span {ev.get('name')!r} has no dur")
        args = ev.get("args") or {}
        if not args.get("trace_id") or not args.get("span_id"):
            errors.append(f"{where}: args lack trace_id/span_id")
            continue
        if not isinstance(args.get("lc"), int):
            errors.append(f"{where}: args.lc not an int")
        if not str(args["span_id"]).startswith(str(args["trace_id"])):
            errors.append(f"{where}: span_id {args['span_id']!r} not "
                          f"prefixed by trace_id {args['trace_id']!r}")
        ev["_where"] = where
        events.append(ev)
    return events


def check_identity(events: List[dict], errors: List[str]) -> None:
    seen_span: Dict[str, str] = {}
    seen_lc: Dict[Tuple[str, int], str] = {}
    for ev in events:
        a = ev["args"]
        sid, where = a["span_id"], ev["_where"]
        if sid in seen_span:
            errors.append(f"{where}: duplicate span_id {sid!r} "
                          f"(first at {seen_span[sid]})")
        seen_span[sid] = where
        lc = a.get("lc")
        if isinstance(lc, int):
            key = (a["trace_id"], lc)
            if key in seen_lc:
                errors.append(f"{where}: duplicate lc {lc} in trace "
                              f"{a['trace_id']!r} (first at {seen_lc[key]})")
            seen_lc[key] = where


def check_parents(events: List[dict], errors: List[str]) -> None:
    ids = {ev["args"]["span_id"] for ev in events}
    for ev in events:
        parent = ev["args"].get("parent_id")
        if parent is not None and parent not in ids:
            errors.append(f"{ev['_where']}: parent_id {parent!r} resolves "
                          f"to no span in the loaded traces")


def check_chain(events: List[dict], names: List[str],
                errors: List[str]) -> None:
    """At least one causal path name[0] -> ... -> name[-1] via parent_id."""
    by_name: Dict[str, List[dict]] = {}
    for ev in events:
        by_name.setdefault(ev["name"], []).append(ev)
    if names[0] not in by_name:
        errors.append(f"chain: no event named {names[0]!r}")
        return
    frontier = {ev["args"]["span_id"] for ev in by_name[names[0]]}
    path = [names[0]]
    for name in names[1:]:
        nxt = {ev["args"]["span_id"] for ev in by_name.get(name, ())
               if ev["args"].get("parent_id") in frontier}
        if not nxt:
            errors.append(
                f"chain broken at {' -> '.join(path)} -> {name!r}: no "
                f"{name!r} event parents on a surviving "
                f"{path[-1]!r} span")
            return
        frontier, path = nxt, path + [name]
    print(f"chain OK: {' -> '.join(names)} "
          f"({len(frontier)} terminal span(s))")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="+", help="Chrome trace JSON files")
    ap.add_argument("--expect-chain", action="append", default=[],
                    metavar="A,B,C",
                    help="require a parent-linked event chain A->B->C "
                         "(repeatable)")
    ap.add_argument("--expect-event", action="append", default=[],
                    metavar="NAME",
                    help="require at least one event named NAME "
                         "(repeatable)")
    args = ap.parse_args(argv)

    errors: List[str] = []
    events: List[dict] = []
    for path in args.traces:
        evs = load_trace(path, errors)
        print(f"{path}: {len(evs)} events")
        events.extend(evs)
    check_identity(events, errors)
    check_parents(events, errors)
    names_present = {ev["name"] for ev in events}
    for name in args.expect_event:
        if name not in names_present:
            errors.append(f"expected event {name!r}: absent")
    for chain in args.expect_chain:
        names = [n.strip() for n in chain.split(",") if n.strip()]
        if len(names) < 2:
            errors.append(f"--expect-chain needs >=2 names: {chain!r}")
        else:
            check_chain(events, names, errors)
    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print(f"trace OK: {len(events)} events across "
          f"{len(args.traces)} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
