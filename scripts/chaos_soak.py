"""Chaos soak: a fault-free baseline vs a seeded-chaos run of the same
spec, with the paper-level acceptance checks (DESIGN.md §12) asserted and
a machine-readable fault-event log written for the CI artifact.

  train: auto-derived faults (worker crash, job-manager kill -9/respawn,
         RPC loss+dup, straggler spike) against the file job manager; the
         chaos run must end within loss tolerance of the baseline — a
         crash costs capacity, never correctness.
  serve: a worker crash mid-flight; the chaos run must complete the EXACT
         same request->tokens map as the baseline (zero lost requests,
         every in-flight request requeued and replayed).

Usage (CI chaos job; 4 forced host devices are set up internally):
  PYTHONPATH=src python scripts/chaos_soak.py --mode train \
      --fault-seed 1 --out chaos_events_train_1.json
"""
import argparse
import json
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.api import RunSpec, Session  # noqa: E402

LOSS_TOL = 3e-3     # ULP-level drift of a different stage split (§12)

TRAIN_BASE = {
    "steps": 16, "seed": 5, "log_every": 4,
    "model": {"arch": "smollm-360m", "layers": 8, "d_model": 64,
              "num_heads": 4, "num_kv_heads": 2, "d_ff": 256,
              "vocab_size": 512},
    "parallel": {"stages": 4, "num_micro": 2, "mb_global": 2, "seq": 32,
                 "remat": "none", "param_dtype": "float32"},
    "cluster": {"job_manager": "file", "autoscale": True,
                "heartbeat_timeout": 3.0, "rpc_timeout_s": 2.0,
                "spares": 1},
}

SERVE_BASE = {
    "seed": 3,
    "model": {"arch": "smollm-360m", "layers": 8, "d_model": 64,
              "num_heads": 4, "num_kv_heads": 2, "d_ff": 256,
              "vocab_size": 512},
    "parallel": {"stages": 4, "num_micro": 2, "mb_global": 2, "seq": 16,
                 "remat": "none", "param_dtype": "float32"},
    "serve": {"requests": 10, "prompt_len": 16, "gen": 12, "min_prompt": 4,
              "burst_period": 6, "burst_len": 2, "burst_rate": 3,
              "lull_rate": 1},
    "cluster": {"job_manager": "inproc", "autoscale": False, "spares": 1},
}


def soak_train(fault_seed: int) -> dict:
    with Session(RunSpec.from_dict(dict(TRAIN_BASE))) as s:
        base = s.train()
    chaos_cfg = dict(TRAIN_BASE)
    chaos_cfg["faults"] = {"enabled": True, "auto": True,
                           "seed": fault_seed}
    with Session(RunSpec.from_dict(chaos_cfg)) as s:
        chaos = s.train()
    diffs = [abs(a - b) for a, b in zip(base["losses"], chaos["losses"])]
    verdict = {
        "steps": len(chaos["losses"]),
        "max_loss_diff": max(diffs),
        "loss_tol": LOSS_TOL,
        "resizes": [(r["kind"], r["step"]) for r in chaos["resizes"]],
        "ok": (len(chaos["losses"]) == TRAIN_BASE["steps"]
               and max(diffs) < LOSS_TOL),
    }
    return {"mode": "train", "fault_seed": fault_seed, "verdict": verdict,
            "fault_plan": chaos["fault_plan"], "events": chaos["faults"],
            "degraded_events": chaos["degraded_events"],
            "rpc": chaos["rpc"],
            "baseline_losses": base["losses"],
            "chaos_losses": chaos["losses"]}


def soak_serve(fault_seed: int) -> dict:
    with Session(RunSpec.from_dict(dict(SERVE_BASE))) as s:
        base = s.serve()
    chaos_cfg = dict(SERVE_BASE)
    chaos_cfg["faults"] = {"enabled": True, "auto": True,
                           "seed": fault_seed}
    with Session(RunSpec.from_dict(chaos_cfg)) as s:
        chaos = s.serve()
    tok_a = {c["rid"]: c["tokens"] for c in base["completions"]}
    tok_b = {c["rid"]: c["tokens"] for c in chaos["completions"]}
    mismatched = sorted(r for r in tok_a if tok_b.get(r) != tok_a[r])
    verdict = {
        "requests": len(tok_a),
        "lost_requests": sorted(set(tok_a) - set(tok_b)),
        "token_mismatches": mismatched,
        "requeued_total": chaos["requeued_total"],
        "resizes": [(r["kind"], r["step"]) for r in chaos["resizes"]],
        "ok": set(tok_a) == set(tok_b) and not mismatched,
    }
    return {"mode": "serve", "fault_seed": fault_seed, "verdict": verdict,
            "fault_plan": chaos["fault_plan"], "events": chaos["faults"],
            "degraded_events": chaos["degraded_events"],
            "completions": chaos["completions"]}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mode", choices=["train", "serve"], required=True)
    ap.add_argument("--fault-seed", type=int, default=1)
    ap.add_argument("--out", default=None, metavar="EVENTS.JSON",
                    help="write the fault-event log here (CI artifact)")
    args = ap.parse_args()
    log = (soak_train if args.mode == "train" else soak_serve)(
        args.fault_seed)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(log, f, indent=1)
    v = log["verdict"]
    print(f"chaos soak [{log['mode']} seed {log['fault_seed']}]: "
          f"{'PASS' if v['ok'] else 'FAIL'} {v}")
    print(f"  injected: {[(e['step'], e['kind']) for e in log['events']]}")
    if log["degraded_events"]:
        print(f"  degraded: {log['degraded_events']}")
    return 0 if v["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
