"""Regenerate configs/scenarios/*.json from the preset registry.

    PYTHONPATH=src python scripts/gen_scenarios.py

The checked-in files must always equal ``repro.api.scenarios.SCENARIOS``
serialized (tests/test_api.py asserts it), so edits go in scenarios.py
and this script refreshes the JSON — never the other way around.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api.scenarios import SCENARIOS  # noqa: E402


def main() -> None:
    out_dir = os.path.join(os.path.dirname(__file__), "..", "configs",
                           "scenarios")
    os.makedirs(out_dir, exist_ok=True)
    for name, spec in sorted(SCENARIOS.items()):
        path = os.path.join(out_dir, f"{name}.json")
        spec.save(path)
        print(f"wrote {os.path.relpath(path)}")


if __name__ == "__main__":
    main()
