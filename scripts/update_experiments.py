"""Inject the generated roofline table into EXPERIMENTS.md.

    PYTHONPATH=src python scripts/update_experiments.py
"""
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.bench_roofline import load_cells, markdown_table  # noqa: E402

REPO = os.path.join(os.path.dirname(__file__), "..")


def main():
    cells = load_cells()
    table = markdown_table(cells)
    path = os.path.join(REPO, "EXPERIMENTS.md")
    with open(path) as fh:
        text = fh.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        head, tail = text.split(marker, 1)
        # drop the previous generated table (up to the next section marker)
        tail_rest = re.split(r"\n## ", tail, 1)
        rest = ("\n## " + tail_rest[1]) if len(tail_rest) > 1 else ""
        text = head + marker + "\n\n" + table + "\n" + rest
    with open(path, "w") as fh:
        fh.write(text)
    n = sum(1 for c in cells if "roofline" in c or "skipped" in c
            or "memory" in c)
    print(f"updated EXPERIMENTS.md with {n} cells")


if __name__ == "__main__":
    main()
