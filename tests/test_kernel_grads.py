"""Gradient parity for the Pallas backward kernels (interpret mode on CPU).

jax.grad through kernel_impl="pallas" must match the reference attention /
SwiGLU within atol 2e-2 across a density sweep, including GQA and a
non-multiple sequence length; fully-masked rows must produce zero (not NaN)
gradients.  Also checks the tile-work accounting helpers used by
benchmarks/bench_kernels.py."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attention import (attention_tile_work,
                                                  block_sparse_attention)
from repro.kernels.pruned_matmul import (matmul_tile_work, pruned_matmul,
                                         pruned_matmul_ref, pruned_swiglu,
                                         pruned_swiglu_ref)
from repro.models.layers import flash_attention, swiglu

NEG_INF = -1e30


def _dense_block_masked_ref(q, k, v, mask, bq):
    """Dense oracle with block-granular mask + token causal (fp32)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    kr = jnp.repeat(k, hq // hkv, axis=2)
    vr = jnp.repeat(v, hq // hkv, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(d)
    m = jnp.repeat(jnp.repeat(mask, bq, 2), bq, 3)[:, :, :s, :s] > 0
    m = m & (jnp.arange(s)[:, None] >= jnp.arange(s)[None, :])
    sc = jnp.where(m, sc, NEG_INF)
    mx = jnp.max(sc, -1, keepdims=True)
    p = jnp.where(m, jnp.exp(sc - mx), 0.0)
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bhqk,bkhd->bhqd", p, vr) / jnp.maximum(l, 1e-30)
    return jnp.where(l > 0, o, 0.0).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("density", [1.0, 0.5, 0.25])
@pytest.mark.parametrize("s,hq,hkv", [
    (128, 2, 2),
    (256, 4, 2),      # GQA
    (192, 4, 1),      # GQA + non-multiple of the 128 default block
])
def test_attention_grad_parity(density, s, hq, hkv):
    rng = np.random.RandomState(int(density * 100) + s)
    b, d, bq = 2, 32, 64
    q = jnp.asarray(rng.randn(b, s, hq, d) * 0.4, jnp.float32)
    k = jnp.asarray(rng.randn(b, s, hkv, d) * 0.4, jnp.float32)
    v = jnp.asarray(rng.randn(b, s, hkv, d) * 0.4, jnp.float32)
    nb = (s + bq - 1) // bq
    mask = jnp.asarray((rng.rand(b, hq, nb, nb) <= density).astype(np.int32))

    def loss_pallas(q, k, v):
        return jnp.sum(jnp.sin(block_sparse_attention(
            q, k, v, mask, causal=True, block_q=bq, block_k=bq,
            interpret=True)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(_dense_block_masked_ref(q, k, v, mask, bq)))

    gp = jax.grad(loss_pallas, (0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for a, b_, name in zip(gp, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-2, err_msg=name)


def test_attention_fully_masked_rows_zero_grad():
    rng = np.random.RandomState(3)
    b, s, h, d, bq = 1, 128, 2, 32, 64
    q = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    mask = jnp.zeros((b, h, s // bq, s // bq), jnp.int32)
    grads = jax.grad(
        lambda q, k, v: jnp.sum(block_sparse_attention(
            q, k, v, mask, causal=True, block_q=bq, block_k=bq,
            interpret=True)), (0, 1, 2))(q, k, v)
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g)))
        assert bool(jnp.all(g == 0))


def test_layers_flash_attention_pallas_matches_scan_grads():
    """The model dispatch path: impl='pallas' grads == impl='scan' grads,
    dense causal (mask None) and hash-style per-batch block mask."""
    rng = np.random.RandomState(11)
    b, s, hq, hkv, d, blk = 2, 96, 4, 2, 16, 32
    q = jnp.asarray(rng.randn(b, s, hq, d) * 0.4, jnp.float32)
    k = jnp.asarray(rng.randn(b, s, hkv, d) * 0.4, jnp.float32)
    v = jnp.asarray(rng.randn(b, s, hkv, d) * 0.4, jnp.float32)
    nb = s // blk
    masks = [None,
             jnp.asarray((rng.rand(b, 1, nb, nb) > 0.3).astype(np.float32))]
    for bm in masks:
        def loss(impl, q, k, v):
            return jnp.sum(flash_attention(
                q, k, v, causal=True, block_mask=bm, kv_block=blk,
                impl=impl) ** 2)
        gs = jax.grad(lambda *a: loss("scan", *a), (0, 1, 2))(q, k, v)
        gp = jax.grad(lambda *a: loss("pallas", *a), (0, 1, 2))(q, k, v)
        for a, b_, name in zip(gp, gs, ("dq", "dk", "dv")):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=2e-2, err_msg=name)


@pytest.mark.parametrize("mask_axis", ["n", "k"])
@pytest.mark.parametrize("density", [1.0, 0.5, 0.25])
def test_pruned_matmul_grad_parity(mask_axis, density):
    rng = np.random.RandomState(int(density * 10))
    M, K, N = 100, 256, 384              # non-multiple M exercises padding
    x = jnp.asarray(rng.randn(M, K) * 0.2, jnp.float32)
    w = jnp.asarray(rng.randn(K, N) * 0.2, jnp.float32)
    nb = (N if mask_axis == "n" else K) // 128
    keep = max(1, int(round(nb * density)))
    mask = jnp.asarray([1] * keep + [0] * (nb - keep), jnp.int32)

    def loss_k(x, w):
        return jnp.sum(jnp.cos(pruned_matmul(
            x, w, mask, mask_axis=mask_axis, interpret=True)))

    def loss_r(x, w):
        return jnp.sum(jnp.cos(pruned_matmul_ref(
            x, w, mask, mask_axis=mask_axis)))

    gk = jax.grad(loss_k, (0, 1))(x, w)
    gr = jax.grad(loss_r, (0, 1))(x, w)
    for a, b_, name in zip(gk, gr, ("dx", "dw")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-3, err_msg=name)
        # pruned blocks contribute exactly zero weight gradient
    dw = np.asarray(gk[1])
    if mask_axis == "n":
        assert np.all(dw[:, keep * 128:] == 0)


@pytest.mark.parametrize("density", [1.0, 0.5, 0.25])
def test_pruned_swiglu_grad_parity(density):
    rng = np.random.RandomState(int(density * 10) + 1)
    M, d, ff = 64, 128, 512
    x = jnp.asarray(rng.randn(M, d) * 0.3, jnp.float32)
    wi = jnp.asarray(rng.randn(d, ff) * 0.05, jnp.float32)
    wg = jnp.asarray(rng.randn(d, ff) * 0.05, jnp.float32)
    wo = jnp.asarray(rng.randn(ff, d) * 0.05, jnp.float32)
    nb = ff // 128
    keep = max(1, int(round(nb * density)))
    mask = jnp.asarray([1] * keep + [0] * (nb - keep), jnp.int32)

    def loss_k(x, wi, wg, wo):
        return jnp.sum(pruned_swiglu(x, wi, wg, wo, mask,
                                     interpret=True) ** 2)

    def loss_r(x, wi, wg, wo):
        return jnp.sum(pruned_swiglu_ref(x, wi, wg, wo, mask) ** 2)

    gk = jax.grad(loss_k, (0, 1, 2, 3))(x, wi, wg, wo)
    gr = jax.grad(loss_r, (0, 1, 2, 3))(x, wi, wg, wo)
    for a, b_, name in zip(gk, gr, ("dx", "dwi", "dwg", "dwo")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-3, err_msg=name)


def test_layers_swiglu_pallas_matches_dense_grads():
    """Model dispatch: swiglu(impl='pallas') with the block-level dyn mask
    == the masked-XLA fallback, values and grads."""
    rng = np.random.RandomState(5)
    b, s, d, ff = 2, 16, 64, 256
    x = jnp.asarray(rng.randn(b, s, d) * 0.3, jnp.float32)
    wi = jnp.asarray(rng.randn(d, ff) * 0.05, jnp.float32)
    wg = jnp.asarray(rng.randn(d, ff) * 0.05, jnp.float32)
    wo = jnp.asarray(rng.randn(ff, d) * 0.05, jnp.float32)
    bmask = jnp.asarray([1.0, 0.0], jnp.float32)      # 2 blocks of 128

    def loss(impl, x, wi, wg, wo):
        return jnp.sum(swiglu(x, wi, wg, wo, bmask, impl=impl,
                              interpret=True) ** 2)

    ls = jax.value_and_grad(lambda *a: loss("scan", *a), (0, 1, 2, 3))
    lp = jax.value_and_grad(lambda *a: loss("pallas", *a), (0, 1, 2, 3))
    vs, gs = ls(x, wi, wg, wo)
    vp, gp = lp(x, wi, wg, wo)
    np.testing.assert_allclose(float(vp), float(vs), rtol=1e-5)
    for a, b_, name in zip(gp, gs, ("dx", "dwi", "dwg", "dwo")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-3, err_msg=name)


def test_tile_work_helpers_match_manual_count():
    rng = np.random.RandomState(0)
    nb, bq = 4, 64
    mask = (rng.rand(2, 3, nb, nb) > 0.5).astype(np.int32)
    work = attention_tile_work(mask, causal=True, block_q=bq, block_k=bq)
    tril = np.tril(np.ones((nb, nb), np.int32))
    manual = float((mask * tril).sum()) / (2 * 3)
    assert work["fwd_total"] == nb * (nb + 1) // 2
    assert abs(work["fwd_active"] - manual) < 1e-9
    assert work["bwd_active"] == 2 * work["fwd_active"]

    pm = matmul_tile_work(256, 512, 512, np.asarray([1, 0, 1, 0]),
                          mask_axis="n")
    assert pm["fwd_total"] == 2 * 4 * 4
    assert pm["fwd_active"] == pm["fwd_total"] * 0.5
    assert pm["bwd_active"] / pm["bwd_total"] == 0.5


def test_rectangular_blocks_fully_masked_rows_zero():
    """block_q > block_k: a q-row whose only active tiles are entirely above
    the causal diagonal must emit 0 (regression: m_new == NEG_INF made
    p = exp(0) = 1, averaging v instead)."""
    rng = np.random.RandomState(0)
    b, s, h, d = 1, 128, 1, 32
    bq, bk = 128, 64
    q = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    # only tile (0, 1): rows 0..63 cannot causally reach cols 64..127
    mask = jnp.zeros((b, h, 1, 2), jnp.int32).at[:, :, 0, 1].set(1)
    out = block_sparse_attention(q, k, v, mask, causal=True, block_q=bq,
                                 block_k=bk, interpret=True)
    out = np.asarray(out)
    assert np.abs(out[:, :64]).max() == 0.0, np.abs(out[:, :64]).max()
    assert np.all(np.isfinite(out))
    # and their gradients are zero, not NaN
    g = jax.grad(lambda q: jnp.sum(block_sparse_attention(
        q, k, v, mask, causal=True, block_q=bq, block_k=bk,
        interpret=True)))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("density", [1.0, 0.5])
def test_layers_gelu_mlp_pallas_matches_dense_grads(density):
    """Whisper enc/dec FFN dispatch: gelu_mlp(impl='pallas') == the masked
    dense path, values and grads."""
    from repro.models.layers import gelu_mlp
    rng = np.random.RandomState(int(density * 10))
    b, s, d, ff = 2, 8, 32, 256
    x = jnp.asarray(rng.randn(b, s, d) * 0.3, jnp.float32)
    w1 = jnp.asarray(rng.randn(d, ff) * 0.05, jnp.float32)
    b1 = jnp.asarray(rng.randn(ff) * 0.01, jnp.float32)
    w2 = jnp.asarray(rng.randn(ff, d) * 0.05, jnp.float32)
    b2 = jnp.asarray(rng.randn(d) * 0.01, jnp.float32)
    nb = ff // 128
    keep = max(1, int(round(nb * density)))
    bmask = jnp.asarray([1.0] * keep + [0.0] * (nb - keep), jnp.float32)

    def loss(impl, x, w1, b1, w2, b2):
        return jnp.sum(gelu_mlp(x, w1, b1, w2, b2, bmask, impl=impl,
                                interpret=True) ** 2)

    vs, gs = jax.value_and_grad(
        lambda *a: loss("scan", *a), (0, 1, 2, 3, 4))(x, w1, b1, w2, b2)
    vp, gp = jax.value_and_grad(
        lambda *a: loss("pallas", *a), (0, 1, 2, 3, 4))(x, w1, b1, w2, b2)
    np.testing.assert_allclose(float(vp), float(vs), rtol=1e-5)
    for a, b_, name in zip(gp, gs, ("dx", "dw1", "db1", "dw2", "db2")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-3, err_msg=name)


@pytest.mark.parametrize("density", [1.0, 0.5])
def test_noncausal_rectangular_grad_parity(density):
    """Cross-attention shape: sq != sk, causal=False, both non-multiples of
    the block — exercises the exact kv_len padded-column masking in fwd and
    bwd (the old wrapper could only pad safely for causal+square)."""
    rng = np.random.RandomState(int(density * 7))
    b, sq, sk, h, d, blk = 2, 48, 80, 2, 16, 32
    q = jnp.asarray(rng.randn(b, sq, h, d) * 0.4, jnp.float32)
    k = jnp.asarray(rng.randn(b, sk, h, d) * 0.4, jnp.float32)
    v = jnp.asarray(rng.randn(b, sk, h, d) * 0.4, jnp.float32)
    nqb, nkb = -(-sq // blk), -(-sk // blk)
    mask = jnp.asarray(
        (rng.rand(b, h, nqb, nkb) <= density).astype(np.int32))

    def ref(q, k, v):
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(d)
        m = jnp.repeat(jnp.repeat(mask, blk, 2), blk, 3)[:, :, :sq, :sk] > 0
        sc = jnp.where(m, sc, NEG_INF)
        mx = jnp.max(sc, -1, keepdims=True)
        p = jnp.where(m, jnp.exp(sc - mx), 0.0)
        l = p.sum(-1, keepdims=True)
        o = jnp.einsum("bhqk,bkhd->bhqd", p, v) / jnp.maximum(l, 1e-30)
        return jnp.where(l > 0, o, 0.0).transpose(0, 2, 1, 3)

    def loss_pallas(q, k, v):
        return jnp.sum(jnp.sin(block_sparse_attention(
            q, k, v, mask, causal=False, block_q=blk, block_k=blk,
            interpret=True)))

    out = block_sparse_attention(q, k, v, mask, causal=False, block_q=blk,
                                 block_k=blk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref(q, k, v)),
                               atol=2e-5)
    gp = jax.grad(loss_pallas, (0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q, k, v: jnp.sum(jnp.sin(ref(q, k, v))),
                  (0, 1, 2))(q, k, v)
    for a, b_, name in zip(gp, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-2, err_msg=name)
