"""Elastic engine tests: live shrink/grow resharding (paper §3.4).

Host-level tests cover the resplit math (bit-identical round trip); the
subprocess tests run the real multi-device engine: loss parity across an
in-process 4→2 resize and the full 4→2→4 training loop with the controller
deciding the shrink.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_in_subprocess

from repro.checkpoint.elastic import (_resplit_stage_tree, elastic_restore,
                                      resplit_indices)
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.models import model as M
from repro.optim.optimizers import OptConfig, make_optimizer


def _setup(stages=4):
    cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                         d_model=64, d_ff=128)
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig()
    params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    init_fn, _ = make_optimizer(OptConfig(name="adamw"))
    opt = init_fn(params)
    return cfg, dcfg, dyncfg, params, opt, dyn


def _tree_bitwise_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(fa) == len(fb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def test_resplit_indices_cover_all_layers():
    ss, sl, valid = resplit_indices([2, 2, 2, 2], [4, 4], 6)
    assert valid.sum() == 8
    # global order preserved: walking dst slots in order yields src (s, l)
    # in contiguous global order
    got = [(int(ss[s, l]), int(sl[s, l]))
           for s in range(2) for l in range(6) if valid[s, l]]
    want = [(g // 2, g % 2) for g in range(8)]
    assert got == want


def test_shrink_grow_roundtrip_bit_identical():
    """4→2→4 resplit must return bit-identical params, opt moments, and dyn
    state for every live slot (PAD slots are canonically zero)."""
    cfg, dcfg4, dyncfg, params, opt, dyn = _setup(stages=4)
    lps4 = [2, 2, 2, 2]
    L4 = dcfg4.slots_for(cfg)
    dcfg2 = DistConfig(num_stages=2, slot_slack=2, remat="none",
                       param_dtype="float32")

    # normalize: identity resplit zeroes the randomly-initialized PAD slots
    base_stages = _resplit_stage_tree(params["stages"], lps4, lps4, L4)
    base_params = dict(params)
    base_params["stages"] = base_stages
    base_dyn = _resplit_stage_tree(dyn, lps4, lps4, L4)

    p2, o2, d2, _, lps2 = elastic_restore(
        cfg, dcfg4, dcfg2, base_params, opt, base_dyn, lps4)
    p4, o4, d4, _, lps4b = elastic_restore(
        cfg, dcfg2, dcfg4, p2, o2, d2, lps2)

    assert lps4b == lps4
    assert _tree_bitwise_equal(p4["stages"], base_params["stages"])
    assert _tree_bitwise_equal(p4["embed"], base_params["embed"])
    assert _tree_bitwise_equal(d4, base_dyn)
    # optimizer moments follow their layers bit-exactly
    o_base = dict(opt)
    o_base["m"] = dict(opt["m"])
    o_base["m"]["stages"] = _resplit_stage_tree(opt["m"]["stages"], lps4,
                                                lps4, L4)
    o_base["v"] = dict(opt["v"])
    o_base["v"]["stages"] = _resplit_stage_tree(opt["v"]["stages"], lps4,
                                                lps4, L4)
    assert _tree_bitwise_equal(o4["m"]["stages"], o_base["m"]["stages"])
    assert _tree_bitwise_equal(o4["v"]["stages"], o_base["v"]["stages"])
    assert int(o4["count"]) == int(opt["count"])


def test_resplit_rejects_bad_splits():
    with pytest.raises(AssertionError):
        resplit_indices([2, 2], [3, 2], 4)       # layer count not conserved
    with pytest.raises(AssertionError):
        resplit_indices([2, 2], [4], 3)          # over slot capacity


@pytest.mark.slow
def test_engine_shrink_loss_parity():
    """One engine: the SAME batch must produce the same loss on the 4-stage
    world and, after a live 4→2 resize, on the 2-stage world — and one
    further train step must keep training (finite, updating)."""
    out = run_in_subprocess("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config, DistConfig
from repro.dynamics import DynamicsConfig
from repro.launch.engine import ElasticEngine
from repro.pipeline.pipeline import PipelineShapes

cfg = reduced_config(get_config("smollm-360m"), num_layers=8, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512)
dcfg = DistConfig(num_stages=4, slot_slack=2, remat="none",
                  param_dtype="float32")
engine = ElasticEngine(cfg, dcfg, DynamicsConfig(),
                       PipelineShapes(2, 2, 32), data=1)
state = engine.init_state(jax.random.PRNGKey(0))
r = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(r.randint(0, cfg.vocab_size, (2, 2, 32)),
                               jnp.int32),
         "labels": jnp.asarray(r.randint(0, cfg.vocab_size, (2, 2, 32)),
                               jnp.int32),
         "label_mask": jnp.ones((2, 2, 32), jnp.float32)}
l4 = float(engine.eval_loss(state, batch))
state2 = engine.resize(state, 2)
l2 = float(engine.eval_loss(state2, batch))
assert abs(l4 - l2) < 3e-3, (l4, l2)
assert engine.pool.num_active == 4        # resize() alone is pool-neutral
loss, _, gnorm = engine.step(state2, batch, jnp.float32(3e-4))
assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
l2b = float(engine.eval_loss(state2, batch))
assert l2b < l2, (l2b, l2)               # params actually updated
print("PASS", l4, l2, l2b)
""", devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_engine_evict_failure_path():
    """A mid-list worker failure: the engine rebuilds without it, the loss
    is preserved, and the job manager records it dead (not released — it
    is not grantable until revived on the manager side)."""
    out = run_in_subprocess("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config, DistConfig
from repro.dynamics import DynamicsConfig
from repro.launch.engine import ElasticEngine
from repro.pipeline.pipeline import PipelineShapes

cfg = reduced_config(get_config("smollm-360m"), num_layers=8, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512)
dcfg = DistConfig(num_stages=4, slot_slack=2, remat="none",
                  param_dtype="float32")
engine = ElasticEngine(cfg, dcfg, DynamicsConfig(),
                       PipelineShapes(2, 2, 32), data=1)
state = engine.init_state(jax.random.PRNGKey(0))
r = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(r.randint(0, cfg.vocab_size, (2, 2, 32)),
                               jnp.int32),
         "labels": jnp.asarray(r.randint(0, cfg.vocab_size, (2, 2, 32)),
                               jnp.int32),
         "label_mask": jnp.ones((2, 2, 32), jnp.float32)}
l4 = float(engine.eval_loss(state, batch))
epoch0 = engine.epoch
state3 = engine.evict(state, [1], step=7)
assert engine.epoch == epoch0 + 1          # resize fenced the epoch
assert engine.stage_workers == [0, 2, 3]
assert engine.pool.dead == {1} and not engine.pool.released
assert engine.pool.num_active == 3
assert engine.jm.request(1) == []          # dead workers are not grantable
l3 = float(engine.eval_loss(state3, batch))
assert abs(l4 - l3) < 3e-3, (l4, l3)
rz = engine.resizes[-1]
assert rz.kind == "evict" and rz.workers == [1] and rz.step == 7
assert engine.evict(state3, [9]) is state3   # unknown worker: no-op
print("PASS", l4, l3)
""", devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_engine_live_shrink_grow_in_training_loop():
    """The acceptance demo: pruning shrinks the model, the controller's
    repack decision triggers a live 4→2 shrink mid-run (released workers
    reported via the WorkerPool), --grow-back re-expands to 4; the loss
    keeps descending across both resizes."""
    out = run_in_subprocess("""
from repro.launch.train import run_training
out = run_training("smollm-360m", steps=26, stages=4, layers=8, d_model=128,
                   seq=32, num_micro=4, mb_global=2, dynamism="pruning",
                   repack=True, grow_back=6, rebalance_every=5,
                   log_every=1000)
rz = out["resizes"]
assert len(rz) == 2, rz
assert rz[0]["kind"] == "shrink" and rz[0]["from_stages"] == 4 \
    and rz[0]["to_stages"] == 2, rz
assert rz[1]["kind"] == "grow" and rz[1]["to_stages"] == 4, rz
assert rz[0]["ticks_after"] < rz[0]["ticks_before"], rz
assert set(rz[0]["workers"]) == set(rz[1]["workers"]) == {2, 3}, rz
assert out["pool_log"] == ["release:2", "release:3", "grant:2", "grant:3"], \
    out["pool_log"]
assert out["final_stages"] == 4
assert 2 in out["stages_history"] and 4 in out["stages_history"]
import math
assert all(math.isfinite(l) for l in out["losses"])
# loss continues descending through both resizes (compare window means)
pre = out["losses"][:rz[0]["step"]]
post = out["losses"][rz[0]["step"] + 1:]
assert sum(post) / len(post) < sum(pre) / len(pre), (pre, post)
print("PASS", out["losses"][0], "->", out["losses"][-1])
""", devices=4, timeout=900)
    assert "PASS" in out
