"""Multi-tenant cluster scheduler tests (DESIGN.md §14): arbitration
(register/request/steal/yield/poll), the double-grant guard, preemption
riding the epoch-fenced plan mailbox (fence-rejected directives retried,
steal shrink bit-identical to a voluntary shrink), and two processes
contending over one HTTP job manager."""
import json
import os
import subprocess
import sys
import threading
import time

import pytest

from conftest import SRC, run_in_subprocess

from repro.cluster.scheduler import (ClusterScheduler,
                                     SchedulerInvariantError, Tenant)
from repro.runtime.fault_tolerance import WorkerPool


def _sched(total=6, spares=0):
    return ClusterScheduler(WorkerPool(total, spares=spares))


def _two_tenants(sched):
    """The canonical contention setup: train holds 4 of 6, serve 2 of 6
    with headroom up to 4."""
    train = sched.register("train", priority=0, kind="train", workers=4,
                           max_workers=4, min_workers=1)
    serve = sched.register("serve", priority=10, kind="serve", workers=2,
                           max_workers=4, min_workers=1)
    return train, serve


# ---------------------------------------------------------------------------
# arbitration
# ---------------------------------------------------------------------------
def test_register_grants_disjoint_workers():
    sched = _sched()
    train, serve = _two_tenants(sched)
    assert len(train) == 4 and len(serve) == 2
    assert not set(train) & set(serve)
    assert sched.pool.num_active == 6


def test_register_is_idempotent():
    sched = _sched()
    first = sched.register("train", priority=0, workers=4, max_workers=4)
    again = sched.register("train", priority=0, workers=4, max_workers=4)
    assert first == again                   # a client retry sees the same
    assert len(sched.tenants["train"].granted) == 4     # grant, not two


def test_request_never_preempts():
    sched = _sched()
    _two_tenants(sched)                     # pool fully granted
    assert sched.request("serve", 2) == []  # no free capacity: nothing
    assert sched.tenants["train"].preempt_due == 0


def test_steal_takes_free_capacity_first():
    sched = _sched(total=6)
    sched.register("train", priority=0, workers=3, max_workers=3)
    sched.register("serve", priority=10, workers=2, max_workers=5)
    out = sched.steal("serve", 1)           # one unassigned-active worker
    assert len(out["granted"]) == 1 and out["pending"] == 0
    assert sched.tenants["train"].preempt_due == 0


def test_steal_preempt_reserve_collect_pipeline():
    """The full preemption ride: steal posts a directive, the victim sees
    it at poll, its release parks the workers on the thief's reservation,
    and a later request collects them — free capacity never leaks to a
    third party in between."""
    sched = _sched()
    train, _ = _two_tenants(sched)
    out = sched.steal("serve", 2)
    assert out["granted"] == [] and out["pending"] == 2
    assert sched.poll("train") == {"preempt": 2, "offer": 0}
    victims = train[-2:]
    assert sched.release("train", victims) == victims
    assert sched.poll("train")["preempt"] == 0          # debt settled
    assert sorted(sched.tenants["serve"].reserved) == sorted(victims)
    # the reserved workers are NOT free for anyone else
    late = sched.register("late", priority=0, workers=2, max_workers=2)
    assert late == []
    got = sched.request("serve", 2)
    assert sorted(got) == sorted(victims)
    assert sched.tenants["serve"].steal_owed == 0
    assert len(sched.tenants["serve"].granted) == 4


def test_steal_only_preempts_strictly_lower_priority():
    sched = _sched(total=4)
    sched.register("a", priority=5, workers=2, max_workers=4)
    sched.register("b", priority=5, workers=2, max_workers=4)
    out = sched.steal("a", 2)               # same priority: no victims
    assert out["granted"] == [] and out["pending"] == 0
    assert sched.tenants["b"].preempt_due == 0


def test_steal_respects_min_workers_floor():
    sched = _sched(total=4)
    sched.register("train", priority=0, workers=2, max_workers=2,
                   min_workers=2)
    sched.register("serve", priority=10, workers=2, max_workers=4,
                   min_workers=1)
    out = sched.steal("serve", 2)           # train is already at its floor
    assert out["granted"] == [] and out["pending"] == 0
    assert sched.poll("train")["preempt"] == 0


def test_victim_selection_is_lowest_priority_most_headroom():
    sched = _sched(total=9)
    sched.register("low", priority=0, workers=2, max_workers=2)    # 1 spare
    sched.register("mid", priority=1, workers=4, max_workers=4)    # 3 spare
    sched.register("hi", priority=10, workers=3, max_workers=9)
    sched.steal("hi", 2)
    # priority 0 loses first even though priority 1 has more headroom
    assert sched.tenants["low"].preempt_due == 1
    assert sched.tenants["mid"].preempt_due == 1


def test_poll_is_level_triggered():
    """A directive lost to an epoch fence on the tenant side is simply
    re-delivered: poll recomputes from live state, there is no ack."""
    sched = _sched()
    train, _ = _two_tenants(sched)
    sched.steal("serve", 2)
    assert sched.poll("train")["preempt"] == 2
    assert sched.poll("train")["preempt"] == 2      # still due
    sched.release("train", train[-1:])              # partial compliance
    assert sched.poll("train")["preempt"] == 1


def test_yield_becomes_offer_to_below_ceiling_tenant():
    sched = _sched()
    _, serve = _two_tenants(sched)
    sched.release("train", sched.tenants["train"].granted[2:])  # train at 2
    assert sched.poll("train")["offer"] == 2        # its own yield offered
    sched.request("train", 2)                       # absorb back
    assert len(sched.tenants["train"].granted) == 4
    assert sched.poll("train") == {"preempt": 0, "offer": 0}
    assert sched.poll("serve")["offer"] == 0        # nothing left over


def test_offer_capped_by_ceiling():
    sched = _sched(total=8)
    sched.register("train", priority=0, workers=4, max_workers=5)
    # 4 unassigned-active workers exist, but only 1 fits under the ceiling
    sched.pool.release([4, 5, 6, 7])
    assert sched.poll("train")["offer"] == 1


def test_worker_death_settles_preemption_debt():
    """Capacity lost to a crash must not be charged again as preemption —
    the victim would shrink twice."""
    sched = _sched()
    train, _ = _two_tenants(sched)
    sched.steal("serve", 2)
    assert sched.poll("train")["preempt"] == 2
    sched.fail("train", train[-1])
    assert sched.poll("train")["preempt"] == 1


def test_death_scrubs_reservations():
    sched = _sched()
    train, _ = _two_tenants(sched)
    sched.steal("serve", 2)
    sched.release("train", train[-2:])
    dead = sched.tenants["serve"].reserved[0]
    sched.fail(None, dead)
    assert dead not in sched.tenants["serve"].reserved
    assert dead in sched.pool.dead


def test_deregister_frees_the_grant():
    sched = _sched()
    _, serve = _two_tenants(sched)
    freed = sched.deregister("serve")
    assert sorted(freed) == sorted(serve)
    assert sched.poll("train")["offer"] == 0        # train at its ceiling
    sched.register("bigger", priority=0, workers=0, max_workers=6)
    assert sched.poll("bigger")["offer"] == 2


def test_state_roundtrip_preserves_tenancy():
    sched = _sched()
    train, _ = _two_tenants(sched)
    sched.steal("serve", 2)
    sched.release("train", train[-1:])
    back = ClusterScheduler.from_state(
        json.loads(json.dumps(sched.state_dict())))
    assert back.poll("train") == sched.poll("train")
    assert back.tenants["serve"].steal_owed == \
        sched.tenants["serve"].steal_owed
    assert back.tenants["serve"].reserved == \
        sched.tenants["serve"].reserved


# ---------------------------------------------------------------------------
# transport dispatch
# ---------------------------------------------------------------------------
def test_handle_legacy_ops_match_plain_pool():
    """Requests without a tenant field keep the single-Session pool
    semantics bit-for-bit (the pre-§14 contract)."""
    sched = _sched(total=4)
    plain = WorkerPool(4)
    out = sched.handle({"op": "release", "seq": 1, "workers": [2, 3]})
    plain.release([2, 3])
    assert out["released"] == [2, 3] and out["active"] == plain.num_active
    out = sched.handle({"op": "request", "seq": 2, "n": 5})
    assert out["granted"] == plain.request(5)
    sched.handle({"op": "fail", "seq": 3, "worker": 0})
    plain.fail(0)
    assert sched.pool.state_dict() == plain.state_dict()


def test_handle_unknown_tenant_is_an_error_not_a_crash():
    sched = _sched()
    out = sched.handle({"op": "steal", "seq": 1, "tenant": "ghost", "n": 1})
    assert "register first" in out["error"]
    assert out["active"] == 6


def test_handle_metrics_reports_tenants_and_events():
    sched = _sched()
    _two_tenants(sched)
    out = sched.handle({"op": "metrics", "seq": 1})
    assert set(out["tenants"]) == {"train", "serve"}
    assert out["total"] == 6
    assert any(e["ev"] == "grant" for e in out["events"])


# ---------------------------------------------------------------------------
# the double-grant guard
# ---------------------------------------------------------------------------
def test_pool_guard_catches_active_released_overlap():
    pool = WorkerPool(4)
    pool.released.add(1)                    # corrupt: 1 is also active
    with pytest.raises(AssertionError, match="active and released"):
        pool.check_consistent()


def test_pool_fail_scrubs_released_workers_too():
    """A machine dying while idle must leave the released set — or a later
    request() re-grants a dead id (the original double-grant bug)."""
    pool = WorkerPool(4)
    pool.release([2])
    pool.fail(2)
    pool.check_consistent()
    assert pool.request(1) == []            # never re-granted
    assert 2 in pool.dead and 2 not in pool.released


def test_guard_catches_worker_held_by_two_tenants():
    sched = _sched()
    _two_tenants(sched)
    w = sched.tenants["train"].granted[0]
    sched.tenants["serve"].granted.append(w)        # corrupt the books
    with pytest.raises(SchedulerInvariantError, match="held by both"):
        sched._check()


def test_guard_catches_grant_of_inactive_worker():
    sched = _sched()
    _two_tenants(sched)
    sched.tenants["train"].granted.append(99)
    with pytest.raises(SchedulerInvariantError, match="not\\s+active"):
        sched._check()


def test_guard_holds_through_evict_revive_and_spare_promotion():
    """The invariant survives the full fault choreography: a granted
    worker dies (evict), its replacement is minted from the spare budget,
    a released worker is re-granted (revive), and reservations never
    overlap any of it — _check() runs inside every op and stays quiet."""
    sched = _sched(total=4, spares=2)
    train = sched.register("train", priority=0, workers=4, max_workers=6)
    sched.fail("train", train[0])                       # evict
    assert len(sched.tenants["train"].granted) == 3
    got = sched.request("train", 1)                     # spare promotion:
    assert got == [4]                                   # a NEVER-seen id
    sched.release("train", [train[1]])                  # park one worker
    assert sched.request("train", 1) == [train[1]]      # revive it
    sched.handle({"op": "metrics", "seq": 1})
    sched._check()
    # and the guard still has teeth after all that churn
    sched.tenants["train"].reserved.append(train[2])
    with pytest.raises(SchedulerInvariantError, match="held by both"):
        sched._check()


# ---------------------------------------------------------------------------
# preemption rides the epoch-fenced plan mailbox
# ---------------------------------------------------------------------------
def _control_plane():
    from repro.cluster.service import ControlPlane
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.core.controller import ControllerConfig, DynMoController
    from repro.dynamics.config import DynamicsConfig
    cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                         d_model=64)
    dcfg = DistConfig(num_stages=4, slot_slack=2, remat="none",
                      param_dtype="float32")
    ctrl = DynMoController(cfg, dcfg, DynamicsConfig(kind="none"),
                           ControllerConfig(method="partition"))
    return ControlPlane(ctrl, async_mode=False)


def test_injected_preempt_plan_is_epoch_fenced_and_retried():
    """A steal directive injected mid-decide against a world that resizes
    concurrently must be fence-REJECTED (never applied to the wrong
    world) — and because directives are level-triggered, the re-injection
    at the new epoch goes through.  Nothing is lost."""
    cp = _control_plane()
    cp.inject_resize(0, 2)                  # decided against epoch 0
    assert cp.poll(1) is None               # world moved to epoch 1: fenced
    assert cp.stale_rejected == 1
    # next tenant poll re-delivers the directive; re-inject at the live
    # epoch and it applies
    plan = cp.inject_resize(1, 2)
    assert plan.resize.policy == "preempt"
    out = cp.poll(1)
    assert out is not None
    assert out.resize.target_stages == 2
    assert out.resize.layers_per_stage is None      # uniform re-split
    assert cp.stale_rejected == 1


def test_injected_plan_is_latest_wins():
    cp = _control_plane()
    cp.inject_resize(0, 3)
    cp.inject_resize(0, 2)                  # deeper preemption supersedes
    assert cp.poll(0).resize.target_stages == 2
    assert cp.poll(0) is None               # consumed


# ---------------------------------------------------------------------------
# end-to-end (subprocess, multi-device)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_steal_shrink_is_bit_identical_to_voluntary_shrink():
    """The acceptance criterion: an externally-originated preemption (HTTP
    steal by a higher-priority tenant) shrinks the trainer 4->2 through
    the SAME safe-point machinery as a voluntary shrink — the loss
    trajectories match float-for-float."""
    out = run_in_subprocess("""
import threading, time, tempfile
from repro.api.session import Session
from repro.cluster.http_rpc import HttpJobManager, spawn_http_manager
from repro.launch.train import train_spec

run_dir = tempfile.mkdtemp()
proc, url = spawn_http_manager(run_dir, 4, spares=0)
kw = dict(steps=12, stages=4, layers=8, d_model=64, seq=32, num_micro=2,
          mb_global=2, dynamism="none", rebalance_every=1000, log_every=1000)
stolen = []
def thief():
    ext = HttpJobManager(url, client_id="ext", shutdown_on_close=False)
    ext.register_tenant("ext", priority=10, kind="serve", workers=0,
                        max_workers=2, min_workers=1)
    for _ in range(1200):        # wait for the trainer to hold its 4
        t = ext.cluster_metrics()["tenants"].get("train")
        if t and len(t["granted"]) == 4:
            break
        time.sleep(0.05)
    got = list(ext.steal(2))
    for _ in range(2400):        # collect as the victim frees them
        if len(got) >= 2:
            break
        got.extend(ext.request(2 - len(got)))
        time.sleep(0.05)
    stolen.extend(got)
    ext.close()

th = threading.Thread(target=thief)
th.start()
spec_a = train_spec("smollm-360m", job_manager="http", manager_url=url,
                    tenant_id="train", priority=0, **kw)
with Session(spec_a) as sa:
    a = sa.train()
th.join(timeout=60)
try:
    HttpJobManager(url, client_id="kill", shutdown_on_close=True).close()
except Exception:
    pass
proc.wait(timeout=30)

assert len(stolen) == 2, stolen
shr = [r for r in a["resizes"] if r["kind"] == "shrink"]
assert len(shr) == 1 and shr[0]["from_stages"] == 4 \\
    and shr[0]["to_stages"] == 2, a["resizes"]
assert sorted(shr[0]["workers"]) == sorted(stolen)
assert any(ev.kind == "preempt" for ev in sa.events)
k = shr[0]["step"]

# the oracle: single-tenant run, VOLUNTARY shrink scripted at the same step
spec_b = train_spec("smollm-360m", **kw)
with Session(spec_b) as sb:
    b = sb.train(shrink_at={k: 2})
shr_b = [r for r in b["resizes"] if r["kind"] == "shrink"]
assert len(shr_b) == 1 and shr_b[0]["step"] == k, b["resizes"]
assert a["losses"] == b["losses"], (k, a["losses"], b["losses"])
assert a["stages_history"] == b["stages_history"]
print("PASS shrink@", k, a["losses"][0], "->", a["losses"][-1])
""", devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_two_processes_contend_over_one_http_manager(tmp_path):
    """Separate-process contention: a CLI trainer (tenant, priority 0) and
    this test (tenant, priority 10) share one HTTP job manager.  The steal
    shrinks the trainer at a safe point; the later yield is absorbed back
    (grow) — both visible in the trainer's --events-out stream."""
    from repro.cluster.http_rpc import HttpJobManager, spawn_http_manager

    run_dir = str(tmp_path)
    proc, url = spawn_http_manager(run_dir, 4, spares=0)
    events_path = os.path.join(run_dir, "events.json")
    child = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "smollm-360m", "--layers", "8", "--d-model", "64",
         "--stages", "4", "--steps", "40", "--seq", "32",
         "--num-micro", "2", "--mb-global", "2", "--log-every", "1000",
         "--job-manager", "http", "--manager-url", url,
         "--tenant-id", "train", "--priority", "0",
         "--rebalance-every", "3", "--events-out", events_path],
        env={**os.environ, "PYTHONPATH": SRC, "REPRO_TRAIN_DEVICES": "4"},
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    ext = HttpJobManager(url, client_id="ext", shutdown_on_close=False)
    try:
        ext.register_tenant("ext", priority=10, kind="serve", workers=0,
                            max_workers=2, min_workers=1)
        deadline = time.time() + 300
        while time.time() < deadline:       # trainer up and holding 4
            t = ext.cluster_metrics()["tenants"].get("train")
            if t and len(t["granted"]) == 4:
                break
            time.sleep(0.1)
        else:
            pytest.fail("trainer never registered")
        got = list(ext.steal(2))
        while len(got) < 2 and time.time() < deadline:
            got.extend(ext.request(2 - len(got)))
            time.sleep(0.1)
        assert len(got) == 2, got           # preemption crossed processes
        ext.yield_workers(got)              # load dropped: hand them back
        out, _ = child.communicate(timeout=600)
        assert child.returncode == 0, out[-4000:]
    finally:
        ext.close()
        if child.poll() is None:
            child.kill()
        try:
            HttpJobManager(url, client_id="kill", timeout_s=10,
                           shutdown_on_close=True).close()
        except Exception:
            pass
        if proc.poll() is None:
            proc.kill()
    with open(events_path) as f:
        kinds = [ev["kind"] for ev in json.load(f)]
    assert "tenant_register" in kinds
    assert "preempt" in kinds, kinds        # the steal arrived
    assert "absorb" in kinds, kinds         # the yield flowed back
    assert "SHRINK[PREEMPT] 4->2" in out, out[-4000:]
    assert "ABSORB 2->4" in out, out[-4000:]
