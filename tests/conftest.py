"""Shared test helpers.

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
Multi-device tests run in subprocesses that set the flag themselves.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 900) -> str:
    """Run ``code`` in a fresh python with N fake devices; raises on failure,
    returns stdout."""
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        import sys
        sys.path.insert(0, {SRC!r})
    """)
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "PYTHONPATH": SRC},
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout
