"""Pallas kernel validation: interpret-mode (CPU) vs the pure-jnp ref.py
oracles, swept over shapes / dtypes / sparsity levels."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attention import (block_sparse_attention,
                                                  block_sparse_attention_ref)
from repro.kernels.pruned_matmul import (pruned_matmul, pruned_matmul_ref,
                                         pruned_swiglu, pruned_swiglu_ref)


def _bsa_ref_from_bhsd(q, k, v, mask, causal, bq, bk):
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    kr = jnp.repeat(k, hq // hkv, axis=2)
    vr = jnp.repeat(v, hq // hkv, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = kr.transpose(0, 2, 1, 3).reshape(b * hq, k.shape[1], d)
    vf = vr.transpose(0, 2, 1, 3).reshape(b * hq, v.shape[1], d)
    mf = mask.reshape(b * hq, mask.shape[2], mask.shape[3])
    ref = block_sparse_attention_ref(qf, kf, vf, mf, causal=causal,
                                     block_q=bq, block_k=bk)
    return ref.reshape(b, hq, s, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("s,hq,hkv,d,bq", [
    (128, 2, 2, 32, 64),
    (256, 4, 2, 64, 64),
    (192, 2, 1, 32, 64),     # non-power-of-two seq
])
@pytest.mark.parametrize("density", [1.0, 0.5, 0.15])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_sparse_attention_sweep(s, hq, hkv, d, bq, density, dtype):
    rng = np.random.RandomState(hash((s, hq, density == 1.0)) % 2 ** 31)
    b = 2
    q = jnp.asarray(rng.randn(b, s, hq, d) * 0.4, dtype)
    k = jnp.asarray(rng.randn(b, s, hkv, d) * 0.4, dtype)
    v = jnp.asarray(rng.randn(b, s, hkv, d) * 0.4, dtype)
    nqb = (s + bq - 1) // bq
    mask = (rng.rand(b, hq, nqb, nqb) <= density).astype(np.int32)
    out = block_sparse_attention(q, k, v, jnp.asarray(mask), causal=True,
                                 block_q=bq, block_k=bq, interpret=True)
    # oracle works on the padded shapes
    pq = (-s) % bq
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pq), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pq), (0, 0), (0, 0)))
    ref = _bsa_ref_from_bhsd(qp, kp, vp, jnp.asarray(mask), True, bq, bq)
    ref = ref[:, :s]
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_bsa_dense_mask_equals_flash():
    """Full mask == ordinary causal attention (cross-check vs the model's
    flash oracle)."""
    from repro.models.layers import flash_attention
    rng = np.random.RandomState(0)
    b, s, h, d, bq = 1, 128, 2, 32, 64
    q = jnp.asarray(rng.randn(b, s, h, d) * 0.4, jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, d) * 0.4, jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, d) * 0.4, jnp.float32)
    mask = jnp.ones((b, h, s // bq, s // bq), jnp.int32)
    out = block_sparse_attention(q, k, v, mask, causal=True, block_q=bq,
                                 block_k=bq, interpret=True)
    ref = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("M,K,N", [(64, 256, 384), (100, 128, 128),
                                   (257, 384, 256)])
@pytest.mark.parametrize("mask_axis", ["n", "k"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pruned_matmul_sweep(M, K, N, mask_axis, dtype):
    rng = np.random.RandomState(M + K + N)
    x = jnp.asarray(rng.randn(M, K) * 0.2, dtype)
    w = jnp.asarray(rng.randn(K, N) * 0.2, dtype)
    nb = (N if mask_axis == "n" else K) // 128
    mask = jnp.asarray((rng.rand(nb) > 0.4).astype(np.int32))
    out = pruned_matmul(x, w, mask, mask_axis=mask_axis, interpret=True)
    ref = pruned_matmul_ref(x, w, mask, mask_axis=mask_axis)
    atol = 1e-3 if dtype == jnp.float32 else 0.25
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol,
                               rtol=1e-2)


@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9])
def test_pruned_swiglu(sparsity):
    rng = np.random.RandomState(int(sparsity * 10))
    M, d, ff = 64, 128, 512
    x = jnp.asarray(rng.randn(M, d) * 0.3, jnp.float32)
    wi = jnp.asarray(rng.randn(d, ff) * 0.05, jnp.float32)
    wg = jnp.asarray(rng.randn(d, ff) * 0.05, jnp.float32)
    wo = jnp.asarray(rng.randn(ff, d) * 0.05, jnp.float32)
    nb = ff // 128
    mask = jnp.asarray((rng.rand(nb) >= sparsity).astype(np.int32))
    out = pruned_swiglu(x, wi, wg, wo, mask, interpret=True)
    ref = pruned_swiglu_ref(x, wi, wg, wo, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_pruned_matmul_matches_model_semantics():
    """Kernel semantics == the masked-XLA fallback used by blocks.swiglu."""
    from repro.models.layers import swiglu
    rng = np.random.RandomState(7)
    M, d, ff = 32, 64, 256
    x = jnp.asarray(rng.randn(M, d) * 0.3, jnp.float32)
    wi = jnp.asarray(rng.randn(d, ff) * 0.05, jnp.float32)
    wg = jnp.asarray(rng.randn(d, ff) * 0.05, jnp.float32)
    wo = jnp.asarray(rng.randn(ff, d) * 0.05, jnp.float32)
    mask = jnp.asarray([1, 0, 1, 1], jnp.int32)     # 4 blocks of 64 = ff 256
    kern = pruned_swiglu(x, wi, wg, wo, mask, bf=64, interpret=True)
    model = swiglu(x, wi, wg, wo, jnp.repeat(mask.astype(jnp.float32), 64))
    np.testing.assert_allclose(np.asarray(kern), np.asarray(model),
                               atol=1e-4)
