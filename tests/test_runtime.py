"""Fault-tolerance runtime + gradient compression tests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.runtime.compression import (compress_topk, decompress_topk,
                                       int8_dequantize, int8_quantize)
from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                           StragglerDetector, WorkerPool)


def test_heartbeat_detects_failure():
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=10.0, clock=lambda: t[0])
    t[0] = 5.0
    for w in range(3):
        mon.beat(w)
    t[0] = 12.0
    assert mon.failed_workers() == {3}
    t[0] = 30.0
    assert mon.failed_workers() == {0, 1, 2, 3}
    mon.revive(2)
    assert 2 not in mon.failed_workers()


def test_straggler_detector_feeds_balancer():
    det = StragglerDetector(4, ema=0.5, threshold=1.15)
    expected = np.ones(4)
    for _ in range(10):
        det.update(np.array([1.0, 1.0, 1.6, 1.0]))
    assert det.stragglers(expected) == [2]
    slow = det.slowdown(expected)
    assert slow[2] > 1.4 and slow[0] < 1.1
    # a straggler looks like imbalance: balancer moves layers off stage 2
    from repro.core.balancer import partition_balance, stage_loads
    layer_t = np.ones(16)
    lps = [4, 4, 4, 4]
    eff = layer_t.copy()
    eff[8:12] *= slow[2]      # stage 2's layers appear slower
    res = partition_balance(eff, 4)
    assert res.layers_per_stage[2] < 4


def test_heartbeat_rejects_unknown_worker():
    """A typo'd id must not silently grow the watch set (it could never be
    reported failed for the real worker); ``revive`` is the only way to
    (re-)register after construction."""
    t = [0.0]
    mon = HeartbeatMonitor(2, timeout_s=10.0, clock=lambda: t[0])
    with pytest.raises(KeyError):
        mon.beat(5)
    assert mon.known_workers() == {0, 1}
    mon.revive(5)                    # explicit registration
    mon.beat(5)
    assert mon.known_workers() == {0, 1, 5}
    # expire: deliberate departure (released worker) fails immediately …
    mon.expire(1)
    assert mon.failed_workers() == {1}
    mon.beat(1)                      # failed workers' beats are ignored
    assert mon.failed_workers() == {1}
    # … and revive is the recovery transition
    mon.revive(1)
    assert mon.failed_workers() == set()


def test_straggler_relative_slowdown_is_scale_free():
    det = StragglerDetector(4, ema=0.5)
    expected = np.array([1.0, 1.0, 1.0, 1.0])
    for _ in range(10):
        det.update(np.array([3.0, 3.0, 6.0, 3.0]))   # 3x scale error + 2x
    rel = det.relative_slowdown(expected)
    np.testing.assert_allclose(rel, [1.0, 1.0, 1.6, 1.0], atol=1e-6)
    # absolute slowdown would misread the calibration error as everyone
    # straggling
    assert det.slowdown(expected).min() >= 3.0
    det.reset(2)
    assert not det.initialized and len(det.times) == 2


def test_worker_pool_lifecycle():
    pool = WorkerPool(8)
    pool.release([6, 7])          # re-packing freed two workers
    assert pool.num_active == 6
    pool.fail(0)
    assert pool.num_active == 5
    granted = pool.request(2)
    assert granted == [6, 7]
    assert pool.num_active == 7
    assert pool.log[0] == "release:6"


def test_topk_compression_error_feedback():
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(1000), jnp.float32)
    vals, idx, residual = compress_topk(g, frac=0.1)
    rec = decompress_topk(vals, idx, g.shape)
    # top-k + residual reconstructs exactly
    np.testing.assert_allclose(np.asarray(rec + residual.reshape(-1)),
                               np.asarray(g), atol=1e-6)
    # picked entries are the largest-magnitude ones
    assert np.abs(np.asarray(vals)).min() >= np.abs(
        np.asarray(residual)).max() - 1e-6


def test_int8_quantization_bound():
    rng = np.random.RandomState(1)
    g = jnp.asarray(rng.randn(4096), jnp.float32)
    q, scale = int8_quantize(g)
    rec = int8_dequantize(q, scale)
    err = np.abs(np.asarray(rec) - np.asarray(g)).max()
    assert err <= float(scale) * 0.5 + 1e-6
    assert q.dtype == jnp.int8


def test_compressed_psum_single_axis():
    """psum over a singleton axis == identity recovery (exactness check of
    the codec inside the collective wrapper)."""
    from repro.runtime.compression import compressed_psum
    from repro.launch.mesh import _auto_mesh
    mesh = _auto_mesh((1,), ("d",))
    g = jnp.asarray(np.random.RandomState(2).randn(256), jnp.float32)

    def f(x):
        red, err = compressed_psum(x, "d", method="int8")
        return red, err

    from repro.pipeline.pipeline import _shard_map
    red, err = jax.jit(_shard_map(
        f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec(), axis_names={"d"}))(g)
    np.testing.assert_allclose(np.asarray(red + err), np.asarray(g),
                               atol=1e-5)
