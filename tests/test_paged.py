"""Paged KV memory subsystem tests (DESIGN.md §16).

Fast tests exercise the host-side ``PageAllocator`` (free-list
conservation, refcounted prefix sharing, copy-on-write forking, the
admission gate's CoW reservation) and the Pallas paged-decode kernel
against its gather-based reference.  Slow tests run the real pipeline in
subprocesses: paged serving must be token-identical to the dense oracle
on a bursty staggered trace (including prefix-sharing lanes), the paged
pool must ride a live 4->2->4 resize bit-exactly, per-lane temperature
sampling must be deterministic, and the per-micro-count decode variants
must be invisible to tokens.
"""
import numpy as np
import pytest

from conftest import run_in_subprocess


# ---------------------------------------------------------------------------
# PageAllocator: property-style random walk
# ---------------------------------------------------------------------------
def test_page_allocator_random_walk():
    """Random admit/free/fork walk: after every transition the allocator's
    own invariant check passes — no double-mapped block without refcount,
    free + live == pool (conservation), prefix index alive."""
    from repro.serve.kv import PageAllocator

    rng = np.random.RandomState(0)
    al = PageAllocator(24, 4, max_pages_per_req=6, prefix_cache=True)
    live = {}
    next_rid = 0
    # a small prompt universe so random draws actually collide on prefixes
    prompts = [rng.randint(0, 9, n).astype(np.int32)
               for n in (4, 4, 8, 8, 11, 13)]
    for step in range(600):
        op = rng.rand()
        if op < 0.5:
            p = prompts[rng.randint(len(prompts))]
            gen = int(rng.randint(1, 8))
            if al.can_admit(p, gen):
                blocks = al.admit(next_rid, p, gen)
                assert len(blocks) == al.pages_needed(len(p), gen)
                live[next_rid] = len(blocks)
                next_rid += 1
        elif op < 0.85 and live:
            rid = list(live)[rng.randint(len(live))]
            del live[rid]
            al.free(rid)
        elif live:
            rid = list(live)[rng.randint(len(live))]
            j = rng.randint(live[rid])
            # arbitrary (non-admission-reserved) forks may legitimately
            # find the free list empty — that must be a loud refusal, not
            # a corrupted table
            if al.num_free == 0 and al._refs[al.pages_of(rid)[j]] > 1:
                with pytest.raises(RuntimeError):
                    al.ensure_private(rid, j)
            else:
                cp = al.ensure_private(rid, j)
                if cp is not None:
                    src, dst = cp
                    assert al.pages_of(rid)[j] == dst != src
        al.check()
        assert al.num_free + al.live_pages == al.pool_pages
    for rid in list(live):
        al.free(rid)
    al.check()
    assert al.num_free == al.pool_pages        # nothing leaked


def test_page_allocator_prefix_sharing_and_cow():
    """Two requests with one common full prompt page share the block
    (refcount 2); a CoW fork repoints the writer only; frees return blocks
    to the free list exactly once."""
    from repro.serve.kv import PageAllocator

    al = PageAllocator(8, 4, max_pages_per_req=4, prefix_cache=True)
    prompt = np.arange(8, dtype=np.int32)
    a = al.admit(1, prompt, 2)        # pages 0,1 prompt (+pos 8) -> 3 blocks
    assert al.prefix_hits == 0
    b = al.admit(2, prompt, 3)
    # both full prompt pages shared
    assert al.prefix_hits == 2
    assert a[0] == b[0] and a[1] == b[1] and a[2] != b[2]
    al.check()
    before = al.num_free
    cp = al.ensure_private(2, 1)
    assert cp is not None and cp[0] == a[1]
    assert al.pages_of(2)[1] != a[1]           # writer repointed
    assert al.pages_of(1)[1] == a[1]           # reader untouched
    assert al.num_free == before - 1 and al.cow_forks == 1
    al.check()
    # a third admission re-shares page 0 but sees the forked page 1 as
    # still registered under rid 1's prefix
    c = al.admit(3, prompt, 1)
    assert c[0] == a[0] and c[1] == a[1]
    al.check()
    al.free(1)
    al.free(2)
    al.free(3)
    al.check()
    assert al.num_free == al.pool_pages


def test_page_allocator_admission_gate_reserves_cow_fork():
    """Regression: the admission gate must count the bootstrap-page fork.
    When ``plen % page_size == 0`` the write position ``plen-1`` lands in
    a SHARED full prompt page, so ``blocks_required`` is hits-discounted
    pages PLUS one fork block — otherwise a later admission could drain
    the free list and the fork would deadlock mid-flight."""
    from repro.serve.kv import PageAllocator

    al = PageAllocator(5, 4, max_pages_per_req=4, prefix_cache=True)
    prompt = np.arange(8, dtype=np.int32)
    al.admit(1, prompt, 2)                     # 3 blocks, 2 free left
    # second identical request: 3 needed - 2 hits + 1 fork = 2 fresh
    assert al.blocks_required(prompt, 2) == 2
    assert al.can_admit(prompt, 2)
    al.admit(2, prompt, 2)
    assert al.ensure_private(2, 1) is not None   # the reserved fork block
    al.check()
    assert al.num_free == 0
    # a third cannot be admitted — and must be told so by the gate, not by
    # a mid-flight empty free list
    assert not al.can_admit(prompt, 2)
    with pytest.raises(RuntimeError):
        al.admit(3, prompt, 2)


def test_page_allocator_guards():
    from repro.serve.kv import PageAllocator, PagedKVConfig

    with pytest.raises(ValueError):
        PagedKVConfig(page_size=0, pool_pages=4)
    al = PageAllocator(4, 4, max_pages_per_req=2)
    with pytest.raises(ValueError):            # footprint > table capacity
        al.can_admit(np.zeros(8, np.int32), 8)
    al.admit(1, np.zeros(4, np.int32), 1)
    with pytest.raises(ValueError):            # double admission
        al.admit(1, np.zeros(4, np.int32), 1)


# ---------------------------------------------------------------------------
# Paged decode kernel vs reference (single device, interpret mode)
# ---------------------------------------------------------------------------
def test_paged_attention_kernel_matches_ref():
    """The Pallas paged-decode kernel (online softmax over gathered KV
    blocks, count-gated on live pages) matches the gather+dense reference
    to fp32 tolerance, with unmapped pages and per-lane lengths."""
    import jax.numpy as jnp
    from repro.kernels.paged_attention import (paged_attention,
                                               paged_attention_ref)

    rng = np.random.RandomState(3)
    b, page, J, pool, n_q, n_kv, hd = 4, 4, 4, 12, 4, 2, 16
    kp = jnp.asarray(rng.randn(pool + 1, page, n_kv, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(pool + 1, page, n_kv, hd), jnp.float32)
    q = jnp.asarray(rng.randn(b, 1, n_q, hd), jnp.float32)
    pt = np.full((b, J), -1, np.int32)
    blocks = rng.permutation(pool)
    clen = np.array([4, 7, 13, 16], np.int32)
    k = 0
    for i in range(b):
        for j in range(-(-int(clen[i]) // page)):
            pt[i, j] = blocks[k]
            k += 1
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(pt), jnp.asarray(clen))
    out = paged_attention(q, kp, vp, jnp.asarray(pt), jnp.asarray(clen),
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_ref_bit_equal_to_dense_oracle():
    """Gathering a lane's pages into a contiguous row and running the
    UNMODIFIED dense decode_attention is bit-equal to the dense cache path
    — the foundation of the paged==dense token-parity guarantee."""
    import jax.numpy as jnp
    from repro.kernels.paged_attention import paged_attention_ref
    from repro.models.layers import decode_attention

    rng = np.random.RandomState(11)
    b, page, J, n_q, n_kv, hd = 2, 4, 3, 4, 2, 8
    dense_k = jnp.asarray(rng.randn(b, J * page, n_kv, hd), jnp.float32)
    dense_v = jnp.asarray(rng.randn(b, J * page, n_kv, hd), jnp.float32)
    q = jnp.asarray(rng.randn(b, 1, n_q, hd), jnp.float32)
    clen = jnp.asarray([5, 11], jnp.int32)
    # scatter the dense rows into a shuffled pool; table maps them back
    perm = rng.permutation(b * J)
    pool = np.zeros((b * J + 1, page, n_kv, hd), np.float32)
    pt = np.zeros((b, J), np.int32)
    for i in range(b):
        for j in range(J):
            blk = int(perm[i * J + j])
            pool[blk] = np.asarray(dense_k[i, j * page:(j + 1) * page])
            pt[i, j] = blk
    kp = jnp.asarray(pool)
    poolv = np.zeros_like(pool)
    for i in range(b):
        for j in range(J):
            poolv[pt[i, j]] = np.asarray(dense_v[i, j * page:(j + 1) * page])
    vp = jnp.asarray(poolv)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(pt), clen)
    dense = decode_attention(q, dense_k, dense_v, clen)
    assert np.array_equal(np.asarray(ref), np.asarray(dense))


def test_paged_tile_work_counts_live_pages():
    from repro.kernels.paged_attention import paged_tile_work

    pt = np.array([[0, 1, -1, -1], [2, 3, 4, 5]], np.int32)
    clen = np.array([5, 16], np.int32)
    live, total = paged_tile_work(pt, clen, 4)
    # lane 0: pages 0,1 cover positions < 5 (page 1 partially); lane 1: all
    assert (live, total) == (2 + 4, 8)
    # lanes past their cache_len cost nothing
    live0, _ = paged_tile_work(pt, np.zeros(2, np.int32), 4)
    assert live0 == 0


# ---------------------------------------------------------------------------
# End-to-end: paged serving == dense oracle (token identity)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_paged_serving_token_identical_to_dense():
    """Acceptance: on a fixed-seed bursty trace with staggered admissions,
    mixed prompt lengths, AND prefix-sharing lanes (identical prompts),
    the paged server (block pool + page tables + CoW prefix cache) emits
    token-for-token what the dense per-lane cache server emits.  The pool
    is sized to the dense equivalent so the admission schedule matches."""
    out = run_in_subprocess("""
import copy
import numpy as np
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.pipeline.pipeline import PipelineShapes
from repro.serve import ElasticServer
from repro.serve.kv import PagedKVConfig
from repro.serve.requests import Request

cfg = reduced_config(get_config("smollm-360m"), num_layers=6, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
dcfg = DistConfig(num_stages=4, slot_slack=2, remat="none",
                  param_dtype="float32")
rng = np.random.RandomState(5)
shared = rng.randint(0, 256, 8).astype(np.int32)   # two full prompt pages
plens  = [8, 8, 5, 8, 3, 6, 8, 7]
gens   = [4, 6, 5, 2, 6, 3, 5, 4]
arrive = [0, 0, 1, 2, 3, 5, 6, 8]
base = []
for i in range(8):
    p = (shared.copy() if plens[i] == 8
         else rng.randint(0, 256, plens[i]).astype(np.int32))
    base.append(Request(rid=i, arrival=arrive[i], prompt=p, gen=gens[i]))

def serve(paged):
    shapes = PipelineShapes(num_micro=2, mb_global=2, seq=8, cache_len=16)
    srv = ElasticServer(cfg, dcfg, DynamicsConfig(), shapes, seed=0,
                        defrag_every=2, paged=paged)
    rep = srv.serve(copy.deepcopy(base))
    srv.close()
    return rep

dense = serve(None)
paged = serve(PagedKVConfig(page_size=4, pool_pages=16, prefix_cache=True))
td = {c["rid"]: c["tokens"] for c in dense["completions"]}
tp = {c["rid"]: c["tokens"] for c in paged["completions"]}
assert td == tp, (td, tp)
assert len(td) == 8
assert paged["prefix_hits"] > 0, "identical prompts must share pages"
assert paged["kv_pages_total"] == 16
assert 0 < paged["peak_live_pages"] <= 16
# count-gating telemetry: only live pages cost tile work
assert 0 < paged["page_tile_live"] < paged["page_tile_total"]
print("PASS")
""", devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_paged_pool_rides_elastic_resize_bit_exact():
    """Acceptance: the paged pool + page tables survive a live 4->2->4
    resize — tokens identical to the fixed-mesh paged run, and the pool
    tensor round-trips the shrink/grow cycle bit-exactly."""
    out = run_in_subprocess("""
import copy
import jax
import numpy as np
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.pipeline.pipeline import PipelineShapes
from repro.serve import ElasticServer
from repro.serve.kv import PagedKVConfig
from repro.serve.requests import Request

cfg = reduced_config(get_config("smollm-360m"), num_layers=6, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
dcfg = DistConfig(num_stages=4, slot_slack=2, remat="none",
                  param_dtype="float32")
rng = np.random.RandomState(9)
base = [Request(rid=i, arrival=[0, 0, 1, 3, 4, 6][i],
                prompt=rng.randint(0, 256, [8, 6, 8, 4, 7, 8][i])
                .astype(np.int32),
                gen=[6, 4, 5, 6, 3, 5][i]) for i in range(6)]
paged = PagedKVConfig(page_size=4, pool_pages=16, prefix_cache=False)

def serve(resize_at):
    shapes = PipelineShapes(num_micro=2, mb_global=2, seq=8, cache_len=16)
    srv = ElasticServer(cfg, dcfg, DynamicsConfig(), shapes, seed=0,
                        paged=paged)
    rep = srv.serve(copy.deepcopy(base), resize_at=resize_at)
    toks = {c["rid"]: c["tokens"] for c in rep["completions"]}
    return srv, rep, toks

srv_f, rep_f, fixed = serve(None)
srv_f.close()
srv, rep, elastic = serve({4: 2, 9: 4})
kinds = [r["kind"] for r in rep["resizes"]]
assert kinds == ["shrink", "grow"], kinds
assert fixed == elastic, (fixed, elastic)

# pool bit-exactness through one more shrink/grow cycle on the live state
before = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                      srv.state.cache)
st = srv.engine.shrink(srv.state, 2, step=100)
st = srv.engine.grow(st, 2, step=101)
after = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), st.cache)
assert set(before) == set(after)
for key in before:
    assert before[key].shape == after[key].shape, key
    assert np.array_equal(before[key], after[key]), key
srv.close()
print("PASS")
""", devices=4, timeout=900)
    assert "PASS" in out


# ---------------------------------------------------------------------------
# Satellites: temperature sampling + per-micro-count decode variants
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_temperature_sampling_deterministic_and_distinct():
    """temperature>0 samples per lane from a (seed, rid, pos)-keyed PRNG:
    two runs are token-identical (deterministic), and a hot temperature
    diverges from the argmax stream; temperature=0 is the argmax graph
    (covered by every other serving test, where it is the default)."""
    out = run_in_subprocess("""
import copy
import numpy as np
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.pipeline.pipeline import PipelineShapes
from repro.serve import ElasticServer
from repro.serve.requests import Request

cfg = reduced_config(get_config("smollm-360m"), num_layers=4, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
dcfg = DistConfig(num_stages=2, slot_slack=2, remat="none",
                  param_dtype="float32")
rng = np.random.RandomState(2)
base = [Request(rid=i, arrival=0,
                prompt=rng.randint(0, 256, [8, 5, 7, 8][i])
                .astype(np.int32),
                gen=[6, 5, 6, 4][i]) for i in range(4)]

def serve(temperature):
    shapes = PipelineShapes(num_micro=2, mb_global=2, seq=8, cache_len=16)
    srv = ElasticServer(cfg, dcfg, DynamicsConfig(), shapes, seed=0,
                        temperature=temperature)
    rep = srv.serve(copy.deepcopy(base))
    srv.close()
    return {c["rid"]: c["tokens"] for c in rep["completions"]}

argmax = serve(0.0)
hot1 = serve(5.0)
hot2 = serve(5.0)
assert hot1 == hot2, "sampling must be deterministic per (seed, rid, pos)"
assert hot1 != argmax, "a hot temperature should diverge from argmax"
assert sorted(hot1) == sorted(argmax)        # same request set completes
print("PASS")
""", devices=2, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_micro_variant_decode_is_token_invisible():
    """Per-micro-count decode variants (carry-over fix): with defrag
    compacting live lanes into the lane prefix, trailing all-empty
    microbatch rows are served by a smaller-micro variant — tokens must be
    identical to always running the full-micro pipeline, and the smaller
    variant must actually have been built."""
    out = run_in_subprocess("""
import copy
import numpy as np
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.pipeline.pipeline import PipelineShapes
from repro.serve import ElasticServer
from repro.serve.requests import Request

cfg = reduced_config(get_config("smollm-360m"), num_layers=4, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
dcfg = DistConfig(num_stages=2, slot_slack=2, remat="none",
                  param_dtype="float32")
rng = np.random.RandomState(4)
# one long request + short ones: the tail of the trace runs with a single
# live lane, which defrag keeps in microbatch 0
base = [Request(rid=i, arrival=0,
                prompt=rng.randint(0, 256, [8, 6, 5, 7][i])
                .astype(np.int32),
                gen=[8, 2, 2, 3][i]) for i in range(4)]

def serve(micro_variants):
    shapes = PipelineShapes(num_micro=2, mb_global=2, seq=8, cache_len=16)
    srv = ElasticServer(cfg, dcfg, DynamicsConfig(), shapes, seed=0,
                        defrag_every=1, micro_variants=micro_variants)
    rep = srv.serve(copy.deepcopy(base))
    variants = sorted(srv.engine.world(srv.state.stages).decode)
    srv.close()
    return {c["rid"]: c["tokens"] for c in rep["completions"]}, variants

full, fv = serve(False)
var, vv = serve(True)
assert full == var, (full, var)
assert fv == [2], fv                   # micro_variants off: full micro only
assert 1 in vv, vv                     # the drained-tail variant was built
print("PASS")
""", devices=2, timeout=900)
    assert "PASS" in out
