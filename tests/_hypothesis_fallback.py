"""Minimal deterministic stand-in for the hypothesis API used by this repo.

The real dependency is declared in pyproject.toml; containers without it
still run the property tests against a fixed seeded sample sweep instead of
erroring at collection.  Only the strategies this test-suite uses are
implemented (lists/floats/integers)."""
from __future__ import annotations

import functools

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class strategies:                                    # noqa: N801  (st alias)
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.randint(min_value,
                                                     max_value + 1)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                       max_value)))

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.randint(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(size)]
        return _Strategy(draw)


def settings(max_examples=20, **_):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # zero-arg wrapper (like real hypothesis): the drawn params must not
        # look like pytest fixtures
        def run():
            rng = np.random.RandomState(0)
            for _ in range(getattr(run, "_max_examples", 20)):
                fn(**{k: s.draw(rng) for k, s in strats.items()})
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run
    return deco
