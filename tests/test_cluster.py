"""Cluster control plane tests: async decision service (mailbox, epoch
fencing, inline/async parity), autoscaler policy, and the job-manager RPC
boundary (in-process + file-backed across a real process boundary)."""
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
from repro.cluster.rpc import (FileJobManager, InProcessJobManager,
                               spawn_file_manager)
from repro.cluster.service import ControlPlane, StatsSnapshot
from repro.configs import DistConfig, get_config, reduced_config
from repro.core.controller import ControllerConfig, DynMoController
from repro.dynamics.config import DynamicsConfig
from repro.models import model as M
from repro.runtime.fault_tolerance import HeartbeatMonitor, WorkerPool


# ---------------------------------------------------------------------------
# decision service
# ---------------------------------------------------------------------------
def _setup(stages=4, layers=8, **ccfg_kw):
    # wide FFN so per-layer cost actually tracks the ff_active stats (at
    # d_ff=d_model attention would dominate and retention skew vanishes)
    cfg = reduced_config(get_config("smollm-360m"), num_layers=layers,
                         d_model=64, d_ff=2048)
    dcfg = DistConfig(num_stages=stages, slot_slack=3, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig(kind="pruning")
    ctrl = DynMoController(
        cfg, dcfg, dyncfg,
        ControllerConfig(method="partition", rebalance_every=1, **ccfg_kw))
    return cfg, dcfg, ctrl


def _snapshot(cfg, dcfg, iteration, epoch=0, seed=0):
    """Synthetic per-slot stats with enough skew to force decisions: later
    stages retain most of their FFN, earlier ones are heavily pruned (plus
    per-iteration jitter so successive decisions differ)."""
    tags = np.asarray(M.make_assignment(cfg, dcfg)["tags"])
    rng = np.random.RandomState(seed + iteration)
    num_micro = 4
    live = tags != 0
    S = tags.shape[0]
    grad = np.linspace(0.1, 1.0, S)[:, None] * np.ones_like(tags, float)
    ff = np.where(live, num_micro * np.clip(
        grad + rng.uniform(-0.05, 0.05, tags.shape), 0.02, 1.0), 0.0)
    stats = {
        "ff_active": ff,
        "attn_density": np.where(live, 0.1 * num_micro, 0.0),
        "expert_load": np.zeros(tags.shape + (1,)),
    }
    return StatsSnapshot(iteration=iteration, epoch=epoch, stats=stats,
                         tags=tags, num_micro=num_micro, tokens=4096,
                         seq=64)


def _plan_key(plan):
    if plan is None:
        return None
    rz = plan.resize
    return (plan.iteration, plan.epoch,
            tuple(plan.new_lps) if plan.new_lps is not None else None,
            (rz.target_stages, tuple(rz.layers_per_stage),
             tuple(rz.released_stages), tuple(rz.mem_per_stage))
            if rz is not None else None,
            plan.event.imbalance_before, plan.event.imbalance_after,
            plan.event.moved_layers, plan.event.rebalanced)


@pytest.mark.parametrize("repack", [False, True])
def test_async_decision_equals_inline_on_same_snapshots(repack):
    """Deterministic-thread parity: the decision computed on the background
    thread must be bit-identical to the inline one from the same stats
    snapshot, across an evolving sequence of controller states — both for
    rebalance plans (repack=False) and resize plans (repack=True)."""
    kw = (dict(repack=True, repack_mem_cap=1e18, repack_target=2)
          if repack else {})
    cfg, dcfg, ctrl_a = _setup(layers=16, **kw)
    _, _, ctrl_b = _setup(layers=16, **kw)
    inline = ControlPlane(ctrl_a, async_mode=False)
    background = ControlPlane(ctrl_b, async_mode=True)
    try:
        interesting = 0
        for it in range(1, 8):
            snap = _snapshot(cfg, dcfg, it)
            inline.publish(snap)
            background.publish(snap)
            background.drain()
            p_in = inline.poll(0)
            p_bg = background.poll(0)
            assert _plan_key(p_in) == _plan_key(p_bg)
            if p_in is None:
                continue
            if repack:
                interesting += p_in.resize is not None
            elif p_in.new_lps is not None:
                interesting += 1
                # advance both controller states identically (the trainer
                # would migrate here) so later decisions see evolving lps
                new = list(p_in.new_lps)
                inline.with_ctrl(lambda c: setattr(c, "lps", list(new)))
                background.with_ctrl(lambda c: setattr(c, "lps", list(new)))
        assert interesting >= 1     # the skewed stats did force decisions
        assert background.decided == inline.decided == 7
    finally:
        background.close()


def test_stale_epoch_plan_rejected_on_poll():
    """A plan decided against epoch 0 must be fenced off once a concurrent
    resize moved the world to epoch 1 — never applied."""
    cfg, dcfg, ctrl = _setup()
    cp = ControlPlane(ctrl, async_mode=True)
    try:
        cp.publish(_snapshot(cfg, dcfg, 1, epoch=0))
        cp.drain()
        assert cp.poll(1) is None           # world resized meanwhile
        assert cp.stale_rejected == 1
        # same snapshot polled at its own epoch is fine
        cp.publish(_snapshot(cfg, dcfg, 2, epoch=0))
        cp.drain()
        assert cp.poll(0) is not None
    finally:
        cp.close()


def test_stale_epoch_snapshot_skipped_before_decide():
    """With a live epoch_fn the worker refuses to even decide on a
    pre-resize snapshot (no wasted work, no polluted controller events)."""
    cfg, dcfg, ctrl = _setup()
    epoch = [1]
    cp = ControlPlane(ctrl, async_mode=True, epoch_fn=lambda: epoch[0])
    try:
        cp.publish(_snapshot(cfg, dcfg, 1, epoch=0))   # decided vs epoch 0
        cp.drain()
        assert cp.poll(1) is None
        assert cp.stale_rejected == 1
        assert ctrl.events == []            # decide never ran
        assert cp.decided == 0
    finally:
        cp.close()


def test_worker_thread_error_surfaces_on_training_thread():
    """A failure inside the background decide must crash the training
    thread loudly (like inline would), not silently stop all decisions —
    and the worker must survive to serve later snapshots."""
    cfg, dcfg, ctrl = _setup()
    cp = ControlPlane(ctrl, async_mode=True)
    try:
        bad = _snapshot(cfg, dcfg, 1)
        bad.tags = np.zeros(3)              # wrong rank: profiler raises
        cp.publish(bad)
        with pytest.raises(RuntimeError, match="decision worker failed"):
            cp.drain()
        cp.publish(_snapshot(cfg, dcfg, 2))  # worker thread still alive
        cp.drain()
        assert cp.poll(0) is not None
    finally:
        cp.close()


def test_mailbox_is_latest_wins():
    """The training thread never queues behind the worker: an unconsumed
    snapshot is overwritten, not accumulated."""
    cfg, dcfg, ctrl = _setup()
    cp = ControlPlane(ctrl, async_mode=True)
    cp.close()                              # freeze the worker
    cp.publish(_snapshot(cfg, dcfg, 1))
    cp.publish(_snapshot(cfg, dcfg, 2))
    cp.publish(_snapshot(cfg, dcfg, 3))
    assert cp.published == 3
    assert cp.dropped == 2
    assert cp._inbox.iteration == 3


# ---------------------------------------------------------------------------
# autoscaler
# ---------------------------------------------------------------------------
def test_autoscaler_evicts_on_heartbeat_failure():
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=5.0, clock=lambda: t[0])
    sc = Autoscaler(AutoscalerConfig(min_stages=1, max_stages=4,
                                     watermark=False), mon)
    for step in range(10):
        t[0] = float(step)
        for w in (0, 1, 2):                 # worker 3 goes silent
            mon.beat(w)
        d = sc.observe(step, 1.0, stages=4, active_workers=[0, 1, 2, 3],
                       tokens=1000)
        if d.action != "none":
            assert d.action == "evict" and d.ids == [3]
            assert step > 5                 # after the timeout, not before
            break
    else:
        pytest.fail("failure never detected")
    # the failure is reported once, not every step
    d = sc.observe(step + 1, 1.0, stages=3, active_workers=[0, 1, 2],
                   tokens=1000)
    assert d.action == "none"


def test_autoscaler_grow_on_recovery_is_remembered():
    """A revive while growth is impossible (already at max_stages) must not
    be lost — the grow fires when capacity headroom appears."""
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=5.0, clock=lambda: t[0])
    sc = Autoscaler(AutoscalerConfig(min_stages=1, max_stages=4,
                                     watermark=False), mon)
    mon.expire(3)                           # worker 3 released to the pool
    d = sc.observe(0, 1.0, stages=3, active_workers=[0, 1, 2], tokens=1000)
    assert d.action == "none"
    mon.revive(3)
    # at max_stages there is no headroom: the recovery must be remembered
    d = sc.observe(1, 1.0, stages=4, active_workers=[0, 1, 2, 9],
                   tokens=1000)
    assert d.action == "none"
    d = sc.observe(2, 1.0, stages=3, active_workers=[0, 1, 2], tokens=1000)
    assert d.action == "grow" and d.ids == [3]
    # not consumed until the worker turns up active — but retries are
    # cooldown-spaced, so the very next step stays quiet
    d = sc.observe(3, 1.0, stages=3, active_workers=[0, 1, 2], tokens=1000)
    assert d.action == "none"
    # the grant failed (worker still absent): the recovery is retried
    # after the cooldown instead of being lost
    d = sc.observe(2 + sc.cfg.cooldown, 1.0, stages=3,
                   active_workers=[0, 1, 2], tokens=1000)
    assert d.action == "grow" and d.ids == [3]
    # a successful grant clears it: once active, no more grow attempts
    d = sc.observe(3 + 2 * sc.cfg.cooldown, 1.0, stages=4,
                   active_workers=[0, 1, 2, 3], tokens=1000)
    assert d.action == "none"
    d = sc.observe(4 + 3 * sc.cfg.cooldown, 1.0, stages=3,
                   active_workers=[0, 1, 2], tokens=1000)
    assert d.action == "none"


def test_autoscaler_recovery_survives_retimeout_before_grant():
    """A revived worker is not beaten until it is actually granted back, so
    it may time out into failed again while waiting — the pending recovery
    must survive that and keep retrying on the cooldown cadence."""
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=3.0, clock=lambda: t[0])
    cfg = AutoscalerConfig(min_stages=1, max_stages=4, cooldown=4,
                           watermark=False)
    sc = Autoscaler(cfg, mon)
    def beat_active():
        for w in (0, 1, 2):
            mon.beat(w)

    mon.expire(3)
    beat_active()
    sc.observe(0, 1.0, stages=3, active_workers=[0, 1, 2], tokens=1000)
    mon.revive(3)
    t[0] = 1.0
    beat_active()
    d = sc.observe(1, 1.0, stages=3, active_workers=[0, 1, 2], tokens=1000)
    assert d.action == "grow" and d.ids == [3]   # first attempt (fails)
    t[0] = 6.0          # worker 3 unbeaten past the timeout: failed again
    beat_active()
    assert mon.failed_workers() == {3}
    d = sc.observe(6, 1.0, stages=3, active_workers=[0, 1, 2], tokens=1000)
    assert d.action == "grow" and d.ids == [3]   # retried, not lost


def test_autoscaler_capped_eviction_retries_remaining_dead_workers():
    """When min_stages caps how many dead workers can be evicted at once,
    the remainder must stay due for eviction — not be silently absorbed
    into the known-failed set."""
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=2.0, clock=lambda: t[0])
    sc = Autoscaler(AutoscalerConfig(min_stages=3, max_stages=4,
                                     watermark=False), mon)
    t[0] = 5.0
    mon.beat(0)
    mon.beat(3)                                  # workers 1 AND 2 die
    d = sc.observe(5, 1.0, stages=4, active_workers=[0, 1, 2, 3],
                   tokens=1000)
    assert d.action == "evict" and d.ids == [1]  # capped at min_stages
    # worker 2 is still dead and still active: it must be reported again
    # as soon as capacity allows (here: the pipeline grew back to 4)
    d = sc.observe(6, 1.0, stages=3, active_workers=[0, 2, 3], tokens=1000)
    assert d.action == "none"                    # at min_stages: blocked
    d = sc.observe(7, 1.0, stages=4, active_workers=[0, 2, 3, 9],
                   tokens=1000)
    assert d.action == "evict" and d.ids == [2]


def test_autoscaler_blocked_evict_does_not_starve_recovery_grow():
    """At min_stages a dead active worker cannot be evicted — but a
    pending recovery grow must still fire (it is exactly what creates the
    capacity to evict the corpse)."""
    t = [0.0]
    mon = HeartbeatMonitor(4, timeout_s=2.0, clock=lambda: t[0])
    sc = Autoscaler(AutoscalerConfig(min_stages=2, max_stages=4,
                                     watermark=False), mon)
    mon.expire(2)                               # 2 and 3 released earlier
    mon.expire(3)
    sc.observe(0, 1.0, stages=2, active_workers=[0, 1], tokens=1000)
    t[0] = 5.0
    mon.beat(0)                                 # worker 1 dies at min size
    assert mon.failed_workers() == {1, 2, 3}
    mon.revive(3)                               # and worker 3 recovers
    d = sc.observe(5, 1.0, stages=2, active_workers=[0, 1], tokens=1000)
    assert d.action == "grow" and d.ids == [3]  # not starved by the evict
    # once grown, the dead worker is evictable
    mon.beat(0)
    mon.beat(3)
    d = sc.observe(6, 1.0, stages=3, active_workers=[0, 1, 3], tokens=1000)
    assert d.action == "evict" and d.ids == [1]


def test_file_job_manager_ignores_previous_runs_leftovers(tmp_path):
    """A server started over a directory holding a finished run's req/resp
    files must not replay those ops (including the old shutdown)."""
    import json
    root = str(tmp_path)
    # a previous run: release + shutdown, all answered
    for seq, op in ((1, {"op": "release", "workers": [2, 3]}),
                    (2, {"op": "shutdown"})):
        with open(f"{root}/req-{seq:06d}.json", "w") as f:
            json.dump(op, f)
        with open(f"{root}/resp-{seq:06d}.json", "w") as f:
            json.dump({"op": op["op"], "active": 2, "released": [2, 3]}, f)
    proc = spawn_file_manager(root, workers=4, idle_timeout_s=60.0)
    try:
        jm = FileJobManager(root, timeout_s=30.0)
        assert jm._seq == 2         # client skipped the stale namespace
        assert jm.num_active == 4   # old release NOT replayed, resp fresh
        jm.close()                  # and the old shutdown didn't kill it
        assert proc.wait(timeout=20) == 0
    finally:
        if proc.poll() is None:
            proc.kill()


def test_autoscaler_watermark_does_not_oscillate():
    """Shrink and grow watermarks oppose each other in compute-bound
    regimes (a shrink lowers total throughput): after one shrink→grow
    round trip the shrunk size is remembered as bad and never re-tried."""
    cfg = AutoscalerConfig(min_stages=2, max_stages=4, window=2,
                           low_watermark=0.6, high_watermark=0.9,
                           patience=2, cooldown=2, watermark=True)
    sc = Autoscaler(cfg, monitor=None)
    step = 0
    for _ in range(4):                  # best recorded at 4 stages, 1.0s
        sc.observe(step, 1.0, 4, [0, 1, 2, 3], 1000)
        step += 1
    actions, last_action_step = [], None
    stages = 4
    for _ in range(60):                 # compute-bound: 3x slower forever
        d = sc.observe(step, 3.0, stages, list(range(stages)), 1000)
        if d.action == "shrink":
            stages -= d.workers
            sc.note_resize(step, stages)
        elif d.action == "grow":
            stages += d.workers
            sc.note_resize(step, stages)
        if d.action != "none":
            actions.append(d.action)
            last_action_step = step
        step += 1
    # bounded exploration, not a steady resize cycle: each shrunk size is
    # tried at most once (then remembered as bad), so the total action
    # count is bounded and the tail of the run is quiet
    span = cfg.max_stages - cfg.min_stages
    assert 0 < actions.count("shrink") <= span, actions
    assert actions.count("grow") <= span, actions
    assert last_action_step < step - 20, (actions, last_action_step)
    assert stages == 4                  # settled back at full size


def test_autoscaler_watermark_shrink_with_hysteresis():
    cfg = AutoscalerConfig(min_stages=2, max_stages=4, window=2,
                           low_watermark=0.6, patience=2, cooldown=5,
                           watermark=True)
    sc = Autoscaler(cfg, monitor=None)
    step = 0
    for _ in range(4):                      # establish best throughput
        d = sc.observe(step, 1.0, stages=4, active_workers=[0, 1, 2, 3],
                       tokens=1000)
        assert d.action == "none"
        step += 1
    shrinks = []
    for _ in range(12):                     # sustained idleness
        d = sc.observe(step, 3.0, stages=4, active_workers=[0, 1, 2, 3],
                       tokens=1000)
        if d.action == "shrink":
            shrinks.append(step)
            sc.note_resize(step, 3)         # what the trainer does
        step += 1
    # hysteresis: patience delays the first shrink, cooldown spaces repeats
    assert shrinks, "watermark shrink never fired"
    assert shrinks[0] >= 4 + cfg.patience - 1
    assert all(b - a >= cfg.cooldown for a, b in zip(shrinks, shrinks[1:]))


def test_autoscaler_watermark_grow_on_throughput_drop():
    cfg = AutoscalerConfig(min_stages=2, max_stages=4, window=2,
                           high_watermark=0.9, patience=2, cooldown=3,
                           watermark=True)
    sc = Autoscaler(cfg, monitor=None)
    step = 0
    for _ in range(4):
        assert sc.observe(step, 1.0, 2, [0, 1], 1000).action == "none"
        step += 1
    grew = False
    for _ in range(6):                      # total throughput regressed 3x
        d = sc.observe(step, 3.0, 2, [0, 1], 1000)
        if d.action == "grow":
            grew = True
            break
        step += 1
    assert grew


# ---------------------------------------------------------------------------
# job-manager RPC boundary
# ---------------------------------------------------------------------------
def test_in_process_job_manager_wraps_pool():
    pool = WorkerPool(4)
    jm = InProcessJobManager(pool)
    assert jm.release([2, 3]) == [2, 3]
    assert jm.num_active == 2
    assert jm.release([3]) == []            # already released
    assert jm.request(5) == [2, 3]
    assert jm.num_active == 4
    jm.fail(0)
    assert jm.num_active == 3
    assert jm.log == pool.log


def test_file_job_manager_crosses_process_boundary(tmp_path):
    root = str(tmp_path)
    proc = spawn_file_manager(root, workers=4, idle_timeout_s=60.0)
    try:
        jm = FileJobManager(root, timeout_s=30.0)
        assert jm.num_active == 4
        assert jm.release([2, 3]) == [2, 3]
        assert jm.num_active == 2
        assert jm.release([3]) == []
        assert jm.request(1) == [2]
        jm.fail(1)
        assert jm.num_active == 2           # 0 and 2 active; 3 released
        assert jm.request(5) == [3]
        assert jm.log == ["release:2", "release:3", "grant:2", "fail:1",
                          "grant:3"]
        jm.close()
        assert proc.wait(timeout=20) == 0   # shutdown op ends the server
    finally:
        if proc.poll() is None:
            proc.kill()


def test_file_job_manager_timeout_without_server(tmp_path):
    jm = FileJobManager(str(tmp_path), timeout_s=0.2, poll_s=0.02)
    with pytest.raises(TimeoutError):
        jm.request(1)


# ---------------------------------------------------------------------------
# end-to-end (subprocess, multi-device)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_async_controller_loss_parity():
    """--async-controller on/off must produce the SAME training trajectory
    (decisions drained deterministically): identical losses, identical
    resizes — the acceptance parity criterion."""
    out = run_in_subprocess("""
from repro.launch.train import run_training
kw = dict(steps=20, stages=4, layers=8, d_model=128, seq=32, num_micro=4,
          mb_global=2, dynamism="pruning", repack=True, rebalance_every=5,
          log_every=1000)
a = run_training("smollm-360m", async_controller=False, **kw)
b = run_training("smollm-360m", async_controller=True, async_drain=True,
                 **kw)
assert a["losses"] == b["losses"], (a["losses"], b["losses"])
ra = [(r["kind"], r["step"], r["from_stages"], r["to_stages"])
      for r in a["resizes"]]
rb = [(r["kind"], r["step"], r["from_stages"], r["to_stages"])
      for r in b["resizes"]]
assert ra == rb and len(ra) == 1 and ra[0][0] == "shrink", (ra, rb)
assert a["stages_history"] == b["stages_history"]
assert b["controller"]["mode"] == "async"
assert b["controller"]["decided"] >= 1
print("PASS", a["losses"][0], "->", a["losses"][-1], ra)
""", devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_autoscale_with_file_job_manager_end_to_end():
    """The acceptance demo with NO --grow-back: the controller's repack
    decision shrinks 4->2 live, the released workers cross the file-RPC
    job-manager boundary (separate process), and a simulated heartbeat
    recovery grows back to 4 via the autoscaler."""
    out = run_in_subprocess("""
from repro.launch.train import run_training
out = run_training("smollm-360m", steps=30, stages=4, layers=8, d_model=128,
                   seq=32, num_micro=4, mb_global=2, dynamism="pruning",
                   repack=True, rebalance_every=5, log_every=1000,
                   async_controller=True, autoscale=True,
                   simulate_recover=18, job_manager="file")
rz = out["resizes"]
assert len(rz) == 2, rz
assert rz[0]["kind"] == "shrink" and rz[0]["from_stages"] == 4 \\
    and rz[0]["to_stages"] == 2, rz
assert rz[1]["kind"] == "grow" and rz[1]["to_stages"] == 4, rz
assert set(rz[0]["workers"]) == set(rz[1]["workers"]) == {2, 3}, rz
# the pool transitions crossed the RPC boundary (client-side mirror)
assert out["pool_log"] == ["release:2", "release:3", "grant:2", "grant:3"], \\
    out["pool_log"]
assert out["final_stages"] == 4
ad = out["autoscale_decisions"]
assert any(d["action"] == "grow" and set(d["ids"]) == {2, 3} for d in ad), ad
assert out["controller"]["mode"] == "async"
import math
assert all(math.isfinite(l) for l in out["losses"])
pre = out["losses"][:rz[0]["step"]]
post = out["losses"][rz[0]["step"] + 1:]
assert sum(post) / len(post) < sum(pre) / len(pre), (pre, post)
print("PASS", out["losses"][0], "->", out["losses"][-1])
""", devices=4, timeout=900)
    assert "PASS" in out
