"""Checkpoint + elastic restart tests (fault tolerance, paper §3.4.2)."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import (CheckpointManager, load_checkpoint,
                                         save_checkpoint)
from repro.checkpoint.elastic import elastic_restore
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.models import model as M
from repro.optim.optimizers import OptConfig, make_optimizer


def _setup(stages=4):
    cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                         d_model=64, d_ff=128)
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig()
    params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    init_fn, _ = make_optimizer(OptConfig(name="adamw"))
    opt = init_fn(params)
    return cfg, dcfg, dyncfg, params, opt, dyn


def _tree_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.allclose(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def test_roundtrip(tmp_path):
    cfg, dcfg, dyncfg, params, opt, dyn = _setup()
    lps = [2, 2, 2, 2]
    save_checkpoint(str(tmp_path), 7, params, opt, dyn, lps)
    templates = (jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape,
                                                             a.dtype),
                              t) for t in (params, opt, dyn))
    p2, o2, d2, index = load_checkpoint(str(tmp_path), tuple(templates))
    assert index["step"] == 7
    assert index["layers_per_stage"] == lps
    assert _tree_equal(params, p2)
    assert _tree_equal(opt, o2)
    assert _tree_equal(dyn, d2)


def test_torn_checkpoint_falls_back(tmp_path):
    cfg, dcfg, dyncfg, params, opt, dyn = _setup()
    lps = [2, 2, 2, 2]
    save_checkpoint(str(tmp_path), 5, params, opt, dyn, lps)
    save_checkpoint(str(tmp_path), 10, params, opt, dyn, lps)
    # corrupt the newest
    victim = os.path.join(str(tmp_path), "step_00000010", "stage_001.npz")
    with open(victim, "wb") as fh:
        fh.write(b"garbage")
    templates = tuple(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
        for t in (params, opt, dyn))
    _, _, _, index = load_checkpoint(str(tmp_path), templates)
    assert index["step"] == 5      # fell back to the complete one


def test_manager_gc(tmp_path):
    cfg, dcfg, dyncfg, params, opt, dyn = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    for s in range(5):
        mgr.maybe_save(s, params, opt, dyn, [2, 2, 2, 2])
    dirs = sorted(d for d in os.listdir(str(tmp_path))
                  if d.startswith("step_"))
    assert len(dirs) == 2
    assert dirs[-1] == "step_00000004"


def test_elastic_restore_preserves_model(tmp_path):
    """Restore 4-stage state onto 2 stages (re-pack path): the model function
    must be IDENTICAL — same reference loss."""
    cfg, dcfg4, dyncfg, params, opt, dyn = _setup(stages=4)
    assignment4 = M.make_assignment(cfg, dcfg4)
    rng = np.random.RandomState(0)
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)), jnp.int32)
    loss4 = M.reference_loss(cfg, dcfg4, dyncfg, params, assignment4, dyn,
                             tok, tok)

    dcfg2 = DistConfig(num_stages=2, slot_slack=2, remat="none",
                       param_dtype="float32")
    p2, o2, d2, assignment2, lps2 = elastic_restore(
        cfg, dcfg4, dcfg2, params, opt, dyn, [2, 2, 2, 2])
    assert sum(lps2) == cfg.total_blocks()
    loss2 = M.reference_loss(cfg, dcfg2, dyncfg, p2, assignment2, d2, tok,
                             tok)
    assert abs(float(loss4) - float(loss2)) < 1e-5


def test_elastic_grow(tmp_path):
    """2 -> 6 stages (recovered workers)."""
    cfg, dcfg2, dyncfg, params, opt, dyn = _setup(stages=2)
    assignment2 = M.make_assignment(cfg, dcfg2)
    rng = np.random.RandomState(0)
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)), jnp.int32)
    loss2 = M.reference_loss(cfg, dcfg2, dyncfg, params, assignment2, dyn,
                             tok, tok)
    dcfg6 = DistConfig(num_stages=6, slot_slack=2, remat="none",
                       param_dtype="float32")
    p6, o6, d6, assignment6, _ = elastic_restore(
        cfg, dcfg2, dcfg6, params, opt, dyn, [4, 4])
    loss6 = M.reference_loss(cfg, dcfg6, dyncfg, p6, assignment6, d6, tok,
                             tok)
    assert abs(float(loss2) - float(loss6)) < 1e-5
