"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config of the same family, one forward/train step on CPU — asserting shapes
and no NaNs.  The FULL configs are exercised only via the dry-run."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import (SHAPES, DistConfig, get_config, list_configs,
                           reduced_config)
from repro.dynamics.config import DynamicsConfig
from repro.models import model as M

ARCHS = [
    "mixtral-8x7b", "mixtral-8x22b", "llama3-405b", "command-r-plus-104b",
    "smollm-360m", "deepseek-coder-33b", "internvl2-26b", "zamba2-1.2b",
    "xlstm-1.3b", "whisper-large-v3",
]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_step(arch):
    cfg = reduced_config(get_config(arch), num_layers=4, d_model=64,
                         num_heads=4, num_kv_heads=2, d_ff=128)
    dcfg = DistConfig(num_stages=2, slot_slack=1, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig()
    params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    rng = np.random.RandomState(0)
    B, s = 2, 16
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, s)), jnp.int32)
    lab = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, s)), jnp.int32)
    pe = None
    if cfg.family == "vlm":
        pe = jnp.asarray(rng.randn(B, cfg.num_patches, cfg.d_model) * 0.1,
                         jnp.float32)
    if cfg.is_encdec:
        pe = jnp.asarray(rng.randn(B, cfg.encoder_seq, cfg.d_model) * 0.1,
                         jnp.float32)

    def loss_fn(p):
        return M.reference_loss(cfg, dcfg, dyncfg, p, assignment, dyn, tok,
                                lab, prefix_emb=pe)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # one SGD step, loss still finite
    params2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - 1e-3 * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    loss2 = loss_fn(params2)
    assert np.isfinite(float(loss2)), arch
    # grads exist and are finite on all trainable stage params
    gsum = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
               for g in jax.tree.leaves(grads["stages"]))
    assert np.isfinite(gsum) and gsum > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_param_counts_sane(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    assert n > 0
    # headline sizes within tolerance of published totals
    published = {
        "mixtral-8x7b": 46.7e9, "mixtral-8x22b": 141e9,
        "llama3-405b": 405e9, "command-r-plus-104b": 104e9,
        "smollm-360m": 0.36e9, "deepseek-coder-33b": 33e9,
        "zamba2-1.2b": 1.2e9,
    }
    if arch in published:
        assert abs(n - published[arch]) / published[arch] < 0.2, (
            arch, n / 1e9)


def test_shape_cells_defined():
    """All 4 shapes exist with the assigned sizes."""
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_applicability():
    from repro.launch.specs import cell_skip_reason
    # SSM/hybrid/SWA run long_500k
    for a in ("zamba2-1.2b", "xlstm-1.3b", "mixtral-8x7b", "mixtral-8x22b"):
        assert cell_skip_reason(get_config(a), "long_500k") is None, a
    # full attention archs skip it
    for a in ("llama3-405b", "command-r-plus-104b", "smollm-360m",
              "deepseek-coder-33b", "internvl2-26b", "whisper-large-v3"):
        assert cell_skip_reason(get_config(a), "long_500k") is not None, a
