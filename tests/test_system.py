"""End-to-end system tests (multi-device, subprocess-isolated so the main
pytest process keeps 1 device).

These are the heavyweight integration gates:
  * pipelined loss == single-device sequential reference (with grads),
  * live rebalancing mid-training preserves the loss math (no recompile),
  * prefill + decode == incremental full-forward,
  * mini multi-pod dry-run (AOT lower/compile on a (2,2,2) mesh with the
    production sharding rules — same code path as the 512-chip dry-run).
"""
import jax
import pytest

from conftest import run_in_subprocess

# grad-of-shard_map with MoE scalar residuals trips an upstream _SpecError
# in jax<0.5's experimental shard_map transpose (its own error text says to
# file a jax issue); the modern jax.shard_map path is fine.  Dense archs
# grad correctly on both.
requires_modern_shard_map = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="MoE grad through jax.experimental.shard_map (jax<0.5) hits an "
           "upstream _SpecError; needs jax.shard_map")


@pytest.mark.slow
@pytest.mark.parametrize("arch", [
    "smollm-360m",
    pytest.param("mixtral-8x7b", marks=requires_modern_shard_map),
])
def test_pipeline_equals_reference(arch):
    out = run_in_subprocess("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config, DistConfig
from repro.dynamics import DynamicsConfig
from repro.models import model as M
from repro.pipeline.pipeline import PipelineShapes, build_loss_fn

from repro.launch.mesh import _auto_mesh
mesh = _auto_mesh((2, 4), ("data", "model"))
for arch in (__ARCH__,):
    cfg = reduced_config(get_config(arch), num_layers=6)
    dcfg = DistConfig(num_stages=4, slot_slack=1, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig()
    params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    micro, mbg, seq = 4, 4, 32
    r = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(r.randint(0, cfg.vocab_size,
                                             (micro, mbg, seq)), jnp.int32),
             "labels": jnp.asarray(r.randint(0, cfg.vocab_size,
                                             (micro, mbg, seq)), jnp.int32),
             "label_mask": jnp.ones((micro, mbg, seq), jnp.float32)}
    loss_fn = build_loss_fn(cfg, dcfg, dyncfg, mesh,
                            PipelineShapes(micro, mbg, seq))
    with mesh:
        loss, stats = jax.jit(loss_fn)(params, assignment, dyn, batch)
        g = jax.jit(jax.grad(
            lambda p: loss_fn(p, assignment, dyn, batch)[0]))(params)
    ref = M.reference_loss(cfg, dcfg, dyncfg, params, assignment, dyn,
                           batch["tokens"].reshape(-1, seq),
                           batch["labels"].reshape(-1, seq))
    assert abs(float(loss) - float(ref)) < 3e-3, (arch, loss, ref)
    gs = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gs) and gs > 0
    print(arch, "OK", float(loss))
print("PASS")
""".replace("__ARCH__", repr(arch)), devices=8, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_live_rebalance_preserves_training_math():
    """Migrate to a skewed split mid-run; the jitted loss (NOT recompiled)
    must produce the identical value — DynMo's 'no accuracy impact'."""
    out = run_in_subprocess("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config, DistConfig
from repro.dynamics import DynamicsConfig
from repro.models import model as M
from repro.core.controller import ControllerConfig, DynMoController
from repro.pipeline.pipeline import PipelineShapes, build_loss_fn

from repro.launch.mesh import _auto_mesh
mesh = _auto_mesh((2, 4), ("data", "model"))
cfg = reduced_config(get_config("smollm-360m"), num_layers=8)
dcfg = DistConfig(num_stages=4, slot_slack=3, remat="none",
                  param_dtype="float32")
dyncfg = DynamicsConfig()
params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
assignment = M.make_assignment(cfg, dcfg)
dyn = M.init_dyn(cfg, dcfg, dyncfg)
micro, mbg, seq = 4, 4, 32
r = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(r.randint(0, cfg.vocab_size,
                                         (micro, mbg, seq)), jnp.int32),
         "labels": jnp.asarray(r.randint(0, cfg.vocab_size,
                                         (micro, mbg, seq)), jnp.int32),
         "label_mask": jnp.ones((micro, mbg, seq), jnp.float32)}
loss_fn = jax.jit(build_loss_fn(cfg, dcfg, dyncfg, mesh,
                                PipelineShapes(micro, mbg, seq)))
with mesh:
    l1, _ = loss_fn(params, assignment, dyn, batch)
    ctrl = DynMoController(cfg, dcfg, dyncfg,
                           ControllerConfig(method="partition"))
    params2, _, dyn2, assignment2, _ = ctrl.apply([1, 2, 2, 3], params,
                                                  None, dyn)
    l2, _ = loss_fn(params2, assignment2, dyn2, batch)
assert abs(float(l1) - float(l2)) < 3e-3, (float(l1), float(l2))
print("PASS", float(l1), float(l2))
""", devices=8, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_serve_prefill_decode_consistency():
    out = run_in_subprocess("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config, DistConfig
from repro.dynamics import DynamicsConfig
from repro.models import model as M
from repro.models import blocks as B
from repro.pipeline.pipeline import (PipelineShapes, build_decode_fn,
                                     build_prefill_fn)

from repro.launch.mesh import _auto_mesh
mesh = _auto_mesh((2, 4), ("data", "model"))
cfg = reduced_config(get_config("smollm-360m"), num_layers=6)
dcfg = DistConfig(num_stages=4, slot_slack=1, remat="none",
                  param_dtype="float32")
dyncfg = DynamicsConfig()
params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
assignment = M.make_assignment(cfg, dcfg)
dyn = M.init_dyn(cfg, dcfg, dyncfg)
micro, mbg, seq, gen = 2, 4, 16, 3
shapes = PipelineShapes(micro, mbg, seq, cache_len=seq + gen)
r = np.random.RandomState(0)
tokens = jnp.asarray(r.randint(0, cfg.vocab_size, (micro, mbg, seq)),
                     jnp.int32)
cache = M.init_cache(cfg, dcfg, micro, mbg, seq + gen)
prefill = jax.jit(build_prefill_fn(cfg, dcfg, dyncfg, mesh, shapes))
decode = jax.jit(build_decode_fn(cfg, dcfg, dyncfg, mesh, shapes))
with mesh:
    ids0, cache, _ = prefill(params, assignment, dyn, cache,
                             {"tokens": tokens})
    seqs = [np.asarray(ids0)]
    toks = ids0
    for g in range(1, gen):
        ids, lp, cache, _ = decode(params, assignment, dyn, cache, toks,
                                   jnp.int32(seq + g - 1))
        seqs.append(np.asarray(ids))
        toks = ids

def ref_next(tok_full):
    carry = M.embed(params, cfg, tok_full)
    pos = jnp.arange(carry["x"].shape[1])
    tags = np.asarray(assignment["tags"])
    for s in range(tags.shape[0]):
        for l in range(tags.shape[1]):
            if tags[s, l] == 0:
                continue
            p = jax.tree.map(lambda a: a[s, l], params["stages"])
            ds = jax.tree.map(lambda a: a[s, l], dyn)
            carry, _, _, _ = B.apply_block(cfg, dyncfg, "train", p,
                                           params["shared"], carry,
                                           jnp.int32(tags[s, l]), ds, None,
                                           pos)
    return np.asarray(jnp.argmax(
        M.lm_logits(params, cfg, carry["x"][:, -1]), -1).astype(jnp.int32))

for mi in range(micro):
    tf = tokens[mi]
    for g in range(gen):
        want = ref_next(tf)
        got = seqs[g][mi]
        assert (want == got).all(), (mi, g, want[:4], got[:4])
        tf = jnp.concatenate([tf, want[:, None].astype(jnp.int32)], axis=1)
print("PASS")
""", devices=8, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
@requires_modern_shard_map       # reduced mixtral: MoE grad, see above
def test_mini_multipod_dryrun():
    out = run_in_subprocess("""
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config, reduced_config, DistConfig
from repro.dynamics import DynamicsConfig
from repro.models import model as M
from repro.launch import sharding as SH
from repro.launch.train import make_train_step
from repro.optim.optimizers import OptConfig, make_optimizer
from repro.pipeline.pipeline import PipelineShapes

from repro.launch.mesh import _auto_mesh
mesh = _auto_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced_config(get_config("mixtral-8x7b"), num_layers=4, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=256)
dcfg = DistConfig(num_stages=2, slot_slack=1, remat="full",
                  param_dtype="bfloat16")
dyncfg = DynamicsConfig()
shapes = PipelineShapes(num_micro=2, mb_global=4, seq=32)
pspec = M.param_spec(cfg, dcfg)
pshard = SH.param_shardings(cfg, dcfg, mesh, pspec)
params_sds = SH.attach(pspec, pshard)
aspec = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                     M.make_assignment(cfg, dcfg))
assign_sds = SH.attach(aspec, SH.stage_tree_shardings(aspec, mesh))
dspec = M.dyn_spec(cfg, dcfg, dyncfg)
dyn_sds = SH.attach(dspec, SH.stage_tree_shardings(dspec, mesh))
init_fn, _ = make_optimizer(OptConfig(name="adamw"))
opt_t = jax.eval_shape(init_fn, pspec)
opt_sds = SH.attach(opt_t, SH.opt_shardings(opt_t, pshard, mesh))
batch_spec = {
    "tokens": jax.ShapeDtypeStruct((2, 4, 32), jnp.int32),
    "labels": jax.ShapeDtypeStruct((2, 4, 32), jnp.int32),
    "label_mask": jax.ShapeDtypeStruct((2, 4, 32), jnp.float32)}
batch_sds = SH.attach(batch_spec, SH.batch_shardings(batch_spec, mesh))
_, step = make_train_step(cfg, dcfg, dyncfg, mesh, shapes)
lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
    params_sds, opt_sds, assign_sds, dyn_sds, batch_sds,
    jax.ShapeDtypeStruct((), jnp.float32))
compiled = lowered.compile()
ma = compiled.memory_analysis()
assert ma.temp_size_in_bytes > 0
import re
colls = re.findall(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                   r"collective-permute)", compiled.as_text())
assert "collective-permute" in colls   # the pipeline ring exists
print("PASS", sorted(set(colls)))
""", devices=8, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
@pytest.mark.parametrize("dynamism", ["sparse_attention", "pruning"])
def test_training_loop_pallas_kernels(dynamism):
    """End-to-end pipelined training through kernel_impl="pallas": the
    block-skipping Pallas kernels (interpret mode on CPU) carry the real
    forward AND backward for attention + SwiGLU under both dynamism schemes.
    sparse_block is shrunk so the hash mask actually fires at toy seq."""
    out = run_in_subprocess(f"""
from repro.launch.train import run_training
out = run_training("smollm-360m", steps=6, stages=2, layers=4, d_model=64,
                   seq=32, num_micro=2, mb_global=2,
                   dynamism={dynamism!r}, kernel_impl="pallas",
                   dyn_overrides=dict(sparse_block=16, sparse_nbuckets=4),
                   rebalance_every=3, log_every=100)
import math
assert all(math.isfinite(l) for l in out["losses"]), out["losses"]
assert out["losses"][-1] < out["losses"][0] + 0.5, out["losses"]
print("PASS", out["losses"][0], "->", out["losses"][-1])
""", devices=2, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_training_loop_with_dynmo_controller():
    """Real training with the full DynMo loop: loss descends, pruning fires,
    checkpoints restore."""
    out = run_in_subprocess("""
from repro.launch.train import run_training
out = run_training("smollm-360m", steps=22, stages=4, layers=8, d_model=64,
                   seq=32, num_micro=2, mb_global=2, dynamism="pruning",
                   rebalance_every=5, log_every=100)
assert out["losses"][-1] < out["losses"][0], out["losses"][:3]
print("PASS", out["losses"][0], "->", out["losses"][-1])
""", devices=4, timeout=900)
    assert "PASS" in out
