"""Expert-parallel MoE: grouped ragged Pallas matmul + live expert re-layout.

Local tests pin the grouped kernel (fwd AND grads) against the fp32
capacity-einsum oracle at the ragged corner cases — empty experts, one
expert taking every token, counts not a multiple of the row tile — and pin
``moe_ffn``'s pallas path to the scan/capacity path (same routing, same
drops, same grads).  Placement neutrality (the invariant that makes live
re-layout restart-free) is asserted bitwise.  Subprocess tests run the
real multi-device engine: expert_map rides a 4→2→4 resize, and (on modern
jax) a Session train with re-layout ON matches re-layout OFF loss-for-loss.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_in_subprocess

from repro.checkpoint.elastic import _resplit_stage_tree, elastic_restore
from repro.configs import DistConfig, get_config, reduced_config
from repro.core import expert_layout as el
from repro.core.controller import ControllerConfig, DynMoController
from repro.core.cost_model import LayerDynState
from repro.core.profiler import LayerProfile
from repro.dynamics.config import DynamicsConfig
from repro.kernels.grouped_matmul import (grouped_matmul, grouped_matmul_ref,
                                          grouped_tile_work)
from repro.models import model as M
from repro.models.blocks import moe_ffn

# see tests/test_system.py: MoE grad through jax<0.5's experimental
# shard_map transpose trips an upstream _SpecError; forward-only paths
# (serving, eval_loss, resize) are fine on both.
requires_modern_shard_map = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="MoE grad through jax.experimental.shard_map (jax<0.5) hits an "
           "upstream _SpecError; needs jax.shard_map")


def _moe_cfg(capacity_factor=1.0):
    cfg = reduced_config(get_config("mixtral-8x7b"), num_layers=4,
                         d_model=64, d_ff=128)
    import dataclasses
    return dataclasses.replace(cfg, moe_capacity_factor=capacity_factor)


def _moe_params(rng, cfg, d, ff):
    E = cfg.num_experts
    return {
        "router": jnp.asarray(rng.randn(d, E) * 0.4, jnp.float32),
        "ewi": jnp.asarray(rng.randn(E, d, ff) * 0.2, jnp.float32),
        "ewg": jnp.asarray(rng.randn(E, d, ff) * 0.2, jnp.float32),
        "ewo": jnp.asarray(rng.randn(E, ff, d) * 0.2, jnp.float32),
    }


# ---------------------------------------------------------------------------
# grouped kernel vs fp32 oracle
# ---------------------------------------------------------------------------

# G=8 groups over E=4 experts; cap=20 is NOT a multiple of bm=8, K=96 and
# N=72 are NOT multiples of bk/bn=128 (both padding paths exercised)
_KERNEL_CASES = {
    "uniform": [10, 10, 10, 10, 10, 10, 10, 10],
    "empty_experts": [20, 0, 7, 0, 0, 13, 0, 0],
    "one_takes_all": [20, 0, 0, 0, 20, 0, 0, 0],
    "all_empty": [0, 0, 0, 0, 0, 0, 0, 0],
    "ragged": [1, 19, 3, 8, 20, 0, 5, 2],
}


@pytest.mark.parametrize("case", sorted(_KERNEL_CASES))
def test_grouped_matmul_matches_oracle(case):
    rng = np.random.RandomState(0)
    G, cap, K, N, E = 8, 20, 96, 72, 4
    x = jnp.asarray(rng.randn(G, cap, K) * 0.3, jnp.float32)
    w = jnp.asarray(rng.randn(E, K, N) * 0.3, jnp.float32)
    counts = jnp.asarray(_KERNEL_CASES[case], jnp.int32)
    out = grouped_matmul(x, w, counts, interpret=True)
    ref = grouped_matmul_ref(x, w, counts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # dead rows are zero by contract, regardless of input garbage there
    live = np.arange(cap)[None, :] < np.asarray(counts)[:, None]
    assert np.all(np.asarray(out)[~live] == 0.0)
    garbage = x + jnp.asarray(~live[..., None] * 1e6, jnp.float32)
    out_g = grouped_matmul(garbage, w, counts, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_g), np.asarray(out))


@pytest.mark.parametrize("case",
                         ["uniform", "empty_experts", "one_takes_all",
                          "ragged"])
def test_grouped_matmul_grads_match_oracle(case):
    rng = np.random.RandomState(1)
    G, cap, K, N, E = 8, 20, 96, 72, 4
    x = jnp.asarray(rng.randn(G, cap, K) * 0.3, jnp.float32)
    w = jnp.asarray(rng.randn(E, K, N) * 0.3, jnp.float32)
    cot = jnp.asarray(rng.randn(G, cap, N) * 0.3, jnp.float32)
    counts = jnp.asarray(_KERNEL_CASES[case], jnp.int32)

    def loss(fn):
        return lambda x, w: jnp.sum(fn(x, w, counts) * cot)

    gk = jax.grad(loss(lambda *a: grouped_matmul(*a, interpret=True)),
                  argnums=(0, 1))(x, w)
    gr = jax.grad(loss(grouped_matmul_ref), argnums=(0, 1))(x, w)
    for got, want, name in zip(gk, gr, ("dx", "dw")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5, err_msg=name)
    # empty experts pay zero tile work, fwd and bwd
    work = grouped_tile_work(_KERNEL_CASES[case], cap)
    dense = grouped_tile_work([cap] * G, cap)
    assert work["fwd_total"] == dense["fwd_total"]
    if case != "uniform":
        assert work["fwd_active"] < work["fwd_total"]
        assert work["bwd_active"] < work["bwd_total"]


# ---------------------------------------------------------------------------
# moe_ffn: grouped path == capacity path (routing, drops, grads)
# ---------------------------------------------------------------------------

def test_moe_ffn_pallas_matches_scan():
    cfg = _moe_cfg(capacity_factor=1.0)    # tight capacity -> real drops
    rng = np.random.RandomState(2)
    b, s, d, ff = 2, 32, cfg.d_model, cfg.d_ff
    p = _moe_params(rng, cfg, d, ff)
    x = jnp.asarray(rng.randn(b, s, d) * 0.5, jnp.float32)
    y_s, load_s, aux_s, drop_s = moe_ffn(p, x, cfg, kernel_impl="scan")
    y_p, load_p, aux_p, drop_p = moe_ffn(p, x, cfg, kernel_impl="pallas")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_s),
                               rtol=1e-5, atol=1e-6)
    # routing is shared across impls: load / aux / drop are EXACT
    np.testing.assert_array_equal(np.asarray(load_p), np.asarray(load_s))
    assert float(aux_p) == float(aux_s)
    assert float(drop_p) == float(drop_s)
    assert float(drop_s) > 0.0             # the tight capacity actually drops

    def total(p, impl):
        y, _, aux, _ = moe_ffn(p, x, cfg, kernel_impl=impl)
        return jnp.sum(y ** 2) + aux       # router grads via aux too

    gs = jax.grad(lambda p: total(p, "scan"))(p)
    gp = jax.grad(lambda p: total(p, "pallas"))(p)
    for k in sorted(p):
        np.testing.assert_allclose(np.asarray(gp[k]), np.asarray(gs[k]),
                                   rtol=2e-4, atol=1e-5, err_msg=k)


def test_moe_ffn_decode_token_identity():
    """s == 1 (the serving decode shape) takes the grouped path too and
    must agree with the capacity oracle."""
    cfg = _moe_cfg(capacity_factor=4.0)
    rng = np.random.RandomState(3)
    p = _moe_params(rng, cfg, cfg.d_model, cfg.d_ff)
    x = jnp.asarray(rng.randn(4, 1, cfg.d_model) * 0.5, jnp.float32)
    y_s = moe_ffn(p, x, cfg, kernel_impl="scan")[0]
    y_p = moe_ffn(p, x, cfg, kernel_impl="pallas")[0]
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_s),
                               rtol=1e-5, atol=1e-6)


def test_expert_map_placement_is_bit_neutral():
    """Any expert placement computes the same y BITWISE — the invariant
    that lets a live re-layout run mid-training with zero loss impact."""
    cfg = _moe_cfg(capacity_factor=1.0)
    rng = np.random.RandomState(4)
    E = cfg.num_experts
    p = _moe_params(rng, cfg, cfg.d_model, cfg.d_ff)
    x = jnp.asarray(rng.randn(2, 32, cfg.d_model) * 0.5, jnp.float32)
    base = moe_ffn(p, x, cfg, kernel_impl="pallas")
    for perm in ([1, 0, 3, 2], [3, 2, 1, 0], [2, 0, 3, 1]):
        em = jnp.asarray(perm, jnp.float32)
        got = moe_ffn(p, x, cfg, kernel_impl="pallas", expert_map=em)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(base[0]))
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(base[1]))
        assert float(got[3]) == float(base[3])
    assert E == 4


# ---------------------------------------------------------------------------
# expert layout / re-layout planning (pure host)
# ---------------------------------------------------------------------------

def test_build_relayout_interleaves_hot_and_cold():
    cur = el.ExpertLayout.identity(4)
    plan = el.build_relayout([100, 2, 3, 1], cur, watermark=2.0,
                             min_tokens=16, iteration=7)
    assert plan is not None and plan.iteration == 7
    # hot->cold ranking [0,2,1,3] zipped from both ends: physical order
    # (hot, coldest, 2nd-hot, 2nd-cold) = logical experts (0, 3, 2, 1)
    assert plan.new.placement == (0, 3, 2, 1)
    assert plan.moved_experts == 2
    assert plan.skew == pytest.approx(100 / 26.5)
    # guards: window too small / skew under watermark / already placed
    assert el.build_relayout([100, 2, 3, 1], cur, watermark=2.0,
                             min_tokens=1000, iteration=0) is None
    assert el.build_relayout([10, 9, 11, 10], cur, watermark=2.0,
                             min_tokens=1, iteration=0) is None
    assert el.build_relayout([100, 2, 3, 1], plan.new, watermark=2.0,
                             min_tokens=16, iteration=8) is None


def test_expert_migration_roundtrip_bit_identical():
    """A re-layout is the standard migration gather over a [1, E] grid;
    applying plan then its inverse restores every per-expert leaf bitwise."""
    rng = np.random.RandomState(5)
    old = el.ExpertLayout.identity(4)
    new = el.ExpertLayout((2, 0, 3, 1), (1.0,) * 4)
    tree = {"a": jnp.asarray(rng.randn(4, 3, 5), jnp.float32),
            "b": jnp.asarray(rng.randn(4), jnp.float32)}
    fwd = el.apply_expert_plan(tree, el.as_migration_plan(old, new))
    # physical slot p now holds the state of logical expert new.inverse[p]
    inv = np.asarray(new.inverse)
    np.testing.assert_array_equal(np.asarray(fwd["a"]),
                                  np.asarray(tree["a"])[inv])
    back = el.apply_expert_plan(fwd, el.as_migration_plan(new, old))
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def test_controller_relayout_decision_flow():
    """decide() only stages a plan; the layout advances at commit (safe
    point), and a rebind (elastic resize) preserves it."""
    cfg = _moe_cfg()
    dcfg = DistConfig(num_stages=2, slot_slack=2, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig(kind="moe")
    ctrl = DynMoController(cfg, dcfg, dyncfg, ControllerConfig(
        method="partition", rebalance_every=1, expert_relayout=True,
        expert_watermark=1.5, expert_min_tokens=8))
    assert ctrl.expert_layout == el.ExpertLayout.identity(cfg.num_experts)
    L = cfg.total_blocks()
    prof = LayerProfile(
        time_per_layer=np.ones(L), param_bytes=np.ones(L),
        mem_per_stage=np.zeros(2),
        dyn_states=[LayerDynState() for _ in range(L)],
        expert_load=np.asarray([100.0, 2.0, 3.0, 1.0]),
        moe_drop_frac=0.125)
    _, ev = ctrl.decide(prof, 5)
    assert ev.relayout and ev.expert_skew > 1.5
    assert ev.expert_dropped == 0.125
    plan = ctrl.take_expert_relayout()
    assert plan is not None and ctrl.take_expert_relayout() is None
    assert ctrl.expert_layout.placement == plan.old.placement  # not yet
    ctrl.commit_relayout(plan)
    assert ctrl.expert_layout.placement == plan.new.placement
    assert len(ctrl.relayouts) == 1
    ctrl.rebind(dcfg, ctrl.lps)
    assert ctrl.expert_layout.placement == plan.new.placement
    # balanced load on the new layout: telemetry still flows, no new plan
    prof2 = LayerProfile(
        time_per_layer=np.ones(L), param_bytes=np.ones(L),
        mem_per_stage=np.zeros(2),
        dyn_states=[LayerDynState() for _ in range(L)],
        expert_load=np.asarray([26.0, 27.0, 26.0, 27.0]))
    _, ev2 = ctrl.decide(prof2, 6)
    assert not ev2.relayout and ev2.expert_skew == pytest.approx(27 / 26.5)


def test_expert_map_survives_elastic_resplit():
    """The expert_map dyn leaf rides the 4→2→4 stage resplit bit-exactly
    like every other [S, L_max] leaf (host-level resplit math)."""
    cfg = _moe_cfg()
    dcfg4 = DistConfig(num_stages=4, slot_slack=2, remat="none",
                       param_dtype="float32")
    dcfg2 = DistConfig(num_stages=2, slot_slack=2, remat="none",
                       param_dtype="float32")
    dyncfg = DynamicsConfig(kind="moe", expert_relayout=True)
    dyn = M.init_dyn(cfg, dcfg4, dyncfg)
    assert "expert_map" in dyn and dyn["expert_map"].shape[-1] == 4
    # a committed non-identity placement, mirrored into every live slot
    dyn = dict(dyn)
    dyn["expert_map"] = (dyn["expert_map"] * 0
                         + jnp.asarray([2.0, 0.0, 3.0, 1.0]))
    lps4 = [1, 1, 1, 1]
    base = _resplit_stage_tree(dyn, lps4, lps4, dcfg4.slots_for(cfg))
    params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg4)
    _, _, d2, _, lps2 = elastic_restore(cfg, dcfg4, dcfg2, params, None,
                                        base, lps4)
    assert d2["expert_map"].shape == (2, dcfg2.slots_for(cfg), 4)
    _, _, d4, _, lps4b = elastic_restore(cfg, dcfg2, dcfg4, params, None,
                                         d2, lps2)
    assert lps4b == lps4
    np.testing.assert_array_equal(np.asarray(d4["expert_map"]),
                                  np.asarray(base["expert_map"]))


# ---------------------------------------------------------------------------
# multi-device integration (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_moe_relayout_and_resize():
    """Real 4-device engine on the grouped pallas path (forward-only, so it
    runs on every jax): a live re-layout leaves the eval loss bit-identical,
    and the committed placement survives a 4→2→4 resize."""
    out = run_in_subprocess("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config, DistConfig
from repro.core import expert_layout as el
from repro.dynamics import DynamicsConfig
from repro.launch.engine import ElasticEngine
from repro.pipeline.pipeline import PipelineShapes

cfg = reduced_config(get_config("mixtral-8x7b"), num_layers=4, d_model=64,
                     d_ff=128)
dcfg = DistConfig(num_stages=4, slot_slack=2, remat="none",
                  param_dtype="float32", kernel_impl="pallas")
dyncfg = DynamicsConfig(kind="moe", expert_relayout=True)
engine = ElasticEngine(cfg, dcfg, dyncfg, PipelineShapes(2, 2, 32), data=1)
state = engine.init_state(jax.random.PRNGKey(0), with_opt=False)
assert "expert_map" in state.dyn
r = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(r.randint(0, cfg.vocab_size, (2, 2, 32)),
                               jnp.int32),
         "labels": jnp.asarray(r.randint(0, cfg.vocab_size, (2, 2, 32)),
                               jnp.int32),
         "label_mask": jnp.ones((2, 2, 32), jnp.float32)}
l0 = float(engine.eval_loss(state, batch))
# live re-layout at a safe point: only the expert_map dyn leaf moves
plan = el.build_relayout([90, 4, 5, 1], el.ExpertLayout.identity(4),
                         watermark=1.5, min_tokens=8, iteration=1)
assert plan is not None and plan.new.placement != (0, 1, 2, 3)
dyn = dict(state.dyn)
dyn["expert_map"] = (dyn["expert_map"] * 0
                     + jnp.asarray(plan.new.as_array()))
state.dyn = dyn
l1 = float(engine.eval_loss(state, batch))
assert l1 == l0, (l0, l1)                 # placement is bit-neutral
state2 = engine.resize(state, 2)
l2 = float(engine.eval_loss(state2, batch))
assert abs(l2 - l0) < 3e-3, (l0, l2)
state4 = engine.resize(state2, 4)
l4 = float(engine.eval_loss(state4, batch))
assert abs(l4 - l0) < 3e-3, (l0, l4)
em = np.asarray(state4.dyn["expert_map"])
S, L_max = em.shape[:2]
# every live slot still carries the committed placement after 4->2->4
tags = np.asarray(cfg.block_pattern())
from repro.configs.base import BLOCK_MOE
want = np.asarray(plan.new.placement, np.float32)
live = 0
for s_ in range(S):
    for l_ in range(L_max):
        if np.any(em[s_, l_] != 0):
            assert np.array_equal(em[s_, l_], want), (s_, l_, em[s_, l_])
            live += 1
assert live == int(np.sum(tags == BLOCK_MOE)), (live, tags)
print("PASS", l0, l2, l4)
""", devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
@requires_modern_shard_map       # reduced mixtral: MoE grad, see above
def test_session_relayout_is_loss_neutral():
    """The acceptance demo: a full Session train on the moe scenario with
    live re-layout ON fires at least one re-layout and produces the SAME
    loss sequence as re-layout OFF — no restart, no perturbation."""
    out = run_in_subprocess("""
import dataclasses
from repro.api.scenarios import scenario
from repro.api.session import Session

sp = scenario("moe")
sp = dataclasses.replace(
    sp, steps=12,
    parallel=dataclasses.replace(sp.parallel, kernel_impl="pallas"),
    dynamics=dataclasses.replace(sp.dynamics, expert_relayout=True,
                                 expert_watermark=1.01,
                                 expert_min_tokens=1))
with Session(sp) as s:
    on = s.train()
off_dyn = dataclasses.replace(sp.dynamics, expert_relayout=False)
with Session(dataclasses.replace(sp, dynamics=off_dyn)) as s:
    off = s.train()
assert len(on["relayouts"]) >= 1, on["relayouts"]
assert on["relayouts"][0]["moved_experts"] > 0
assert on["expert_layout"] is not None \\
    and on["expert_layout"] != [0, 1, 2, 3]
assert on["losses"] == off["losses"], (on["losses"], off["losses"])
assert on["expert_skew_last"] is not None and on["expert_skew_last"] >= 1.0
print("PASS", len(on["relayouts"]), on["expert_layout"])
""", devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_serve_moe_drop_telemetry():
    """Serving a MoE arch on the grouped path surfaces the capacity-drop
    fraction in the serve report (forward-only: runs on every jax)."""
    out = run_in_subprocess("""
from repro.api.session import Session
from repro.launch.serve import serve_spec

spec = serve_spec("mixtral-8x7b", stages=4, micro=2, mb_global=2,
                  prompt_len=8, gen=6, layers=4, d_model=64, requests=4,
                  kernel_impl="pallas")
with Session(spec) as s:
    rep = s.serve()
assert len(rep["completions"]) == 4, rep["completions"]
assert rep["moe_dropped_mean"] is not None
assert 0.0 <= rep["moe_dropped_mean"] < 1.0
print("PASS", rep["moe_dropped_mean"])
""", devices=4, timeout=900)
    assert "PASS" in out
