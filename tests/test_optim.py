"""Optimizer tests: descent, clipping, freeze masking, adafactor factoring."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim.optimizers import OptConfig, make_optimizer
from repro.optim.schedule import cosine_schedule


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
def test_descent_on_quadratic(name):
    cfg = OptConfig(name=name, lr=0.1, weight_decay=0.0, clip_norm=1e9)
    init_fn, update_fn = make_optimizer(cfg)
    params = {"stages": {"w": jnp.ones((2, 2, 4, 4)) * 3.0},
              "embed": jnp.ones((8, 4)) * 2.0}
    state = init_fn(params)

    def loss(p):
        return (jnp.sum(p["stages"]["w"] ** 2)
                + jnp.sum(p["embed"] ** 2))

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, gn = update_fn(g, state, params, 0.05)
    assert float(loss(params)) < 0.2 * l0, name


def test_freeze_mask_blocks_updates():
    cfg = OptConfig(name="adamw", lr=0.1, weight_decay=0.0)
    init_fn, update_fn = make_optimizer(cfg)
    params = {"stages": {"w": jnp.ones((2, 3, 4))}}
    state = init_fn(params)
    g = {"stages": {"w": jnp.ones((2, 3, 4))}}
    frozen = jnp.zeros((2, 3)).at[0, 1].set(1.0).at[1, 2].set(1.0)
    p2, state, _ = update_fn(g, state, params, 0.1, frozen=frozen)
    w2 = np.asarray(p2["stages"]["w"])
    assert (w2[0, 1] == 1.0).all() and (w2[1, 2] == 1.0).all()
    assert (w2[0, 0] != 1.0).all() and (w2[1, 0] != 1.0).all()


def test_adafactor_memory_is_factored():
    cfg = OptConfig(name="adafactor", adafactor_min_dim=4)
    init_fn, _ = make_optimizer(cfg)
    params = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((16,))}
    st = init_fn(params)
    assert st["f"]["w"]["vr"].shape == (256,)
    assert st["f"]["w"]["vc"].shape == (512,)
    assert st["f"]["b"]["v"].shape == (16,)
    # factored state is ~(m+n)/(m*n) of AdamW's
    factored = 256 + 512
    assert factored < 256 * 512 // 100


def test_grad_clipping():
    cfg = OptConfig(name="sgd", clip_norm=1.0)
    init_fn, update_fn = make_optimizer(cfg)
    params = {"w": jnp.zeros((4,))}
    st = init_fn(params)
    g = {"w": jnp.full((4,), 100.0)}
    p2, st, gn = update_fn(g, st, params, 1.0)
    assert float(gn) > 100.0
    assert np.abs(np.asarray(p2["w"])).max() <= 0.51   # clipped to norm 1


def test_cosine_schedule():
    lr0 = float(cosine_schedule(jnp.float32(0), 1000, 1e-3, warmup=100))
    lrw = float(cosine_schedule(jnp.float32(100), 1000, 1e-3, warmup=100))
    lre = float(cosine_schedule(jnp.float32(999), 1000, 1e-3, warmup=100))
    assert lr0 < lrw
    assert lre < 0.2 * lrw
