"""Re-packing tests (paper §3.4, Algorithm 2)."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # dep gated: fixed-seed sweep instead of shrinking
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.repack import repack_adjacent, repack_first_fit

mems = st.lists(st.floats(0.1, 10.0), min_size=2, max_size=16)


@settings(max_examples=100, deadline=None)
@given(mem=mems, cap=st.floats(1.0, 40.0))
def test_first_fit_invariants(mem, cap):
    nl = [4] * len(mem)
    plan = repack_first_fit(mem, nl, max_mem=cap)
    # memory capacity never exceeded on active workers
    for s, m in enumerate(plan.mem_usage):
        if plan.active_workers[s]:
            assert m < cap or m == mem[s]  # untouched worker may exceed cap
    # layers conserved
    assert sum(plan.layers_per_stage) == sum(nl)
    # inactive workers hold nothing
    for s, a in enumerate(plan.active_workers):
        if not a:
            assert plan.layers_per_stage[s] == 0
            assert plan.mem_usage[s] == 0.0
    # never increases worker count
    assert plan.num_active <= len(mem)


def test_first_fit_consolidates():
    plan = repack_first_fit([1.0, 1.0, 1.0, 1.0], [2, 2, 2, 2], max_mem=4.1)
    # 4 workers of mem 1 fit pairwise under 4.1 -> deep consolidation
    assert plan.num_active <= 2


def test_target_respected():
    plan = repack_first_fit([1.0] * 8, [1] * 8, max_mem=100.0,
                            target_num_workers=4)
    assert plan.num_active >= 4


@settings(max_examples=60, deadline=None)
@given(mem=mems, cap=st.floats(1.0, 40.0))
def test_adjacent_preserves_order(mem, cap):
    nl = [3] * len(mem)
    plan = repack_adjacent(mem, nl, max_mem=cap)
    assert sum(plan.layers_per_stage) == sum(nl)
    # adjacency: an emptied stage's layers went to a later active stage —
    # contiguous global order is preserved by construction (layers only move
    # to the next active neighbour)
    assert plan.num_active >= 1


def test_paper_repack_scenario():
    """Fig. 4: as pruning shrinks the model, 8 GPUs pack into fewer."""
    mem = [2.0] * 8          # after heavy pruning each stage uses 2 of 16GB
    plan = repack_first_fit(mem, [4] * 8, max_mem=16.0)
    assert plan.num_active <= 2   # 8x2GB packs into 1-2 workers
