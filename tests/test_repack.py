"""Re-packing tests (paper §3.4, Algorithm 2)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # dep gated: fixed-seed sweep instead of shrinking
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.repack import (REPACK_POLICIES, repack, repack_adjacent,
                               repack_first_fit)

mems = st.lists(st.floats(0.1, 10.0), min_size=2, max_size=16)


@settings(max_examples=100, deadline=None)
@given(mem=mems, cap=st.floats(1.0, 40.0))
def test_first_fit_invariants(mem, cap):
    nl = [4] * len(mem)
    plan = repack_first_fit(mem, nl, max_mem=cap)
    # memory capacity never exceeded on active workers
    for s, m in enumerate(plan.mem_usage):
        if plan.active_workers[s]:
            assert m < cap or m == mem[s]  # untouched worker may exceed cap
    # layers conserved
    assert sum(plan.layers_per_stage) == sum(nl)
    # inactive workers hold nothing
    for s, a in enumerate(plan.active_workers):
        if not a:
            assert plan.layers_per_stage[s] == 0
            assert plan.mem_usage[s] == 0.0
    # never increases worker count
    assert plan.num_active <= len(mem)


def test_first_fit_consolidates():
    plan = repack_first_fit([1.0, 1.0, 1.0, 1.0], [2, 2, 2, 2], max_mem=4.1)
    # 4 workers of mem 1 fit pairwise under 4.1 -> deep consolidation
    assert plan.num_active <= 2


def test_target_respected():
    plan = repack_first_fit([1.0] * 8, [1] * 8, max_mem=100.0,
                            target_num_workers=4)
    assert plan.num_active >= 4


@settings(max_examples=60, deadline=None)
@given(mem=mems, cap=st.floats(1.0, 40.0))
def test_adjacent_preserves_order(mem, cap):
    nl = [3] * len(mem)
    plan = repack_adjacent(mem, nl, max_mem=cap)
    assert sum(plan.layers_per_stage) == sum(nl)
    # adjacency: an emptied stage's layers went to a later active stage —
    # contiguous global order is preserved by construction (layers only move
    # to the next active neighbour)
    assert plan.num_active >= 1


def test_paper_repack_scenario():
    """Fig. 4: as pruning shrinks the model, 8 GPUs pack into fewer."""
    mem = [2.0] * 8          # after heavy pruning each stage uses 2 of 16GB
    plan = repack_first_fit(mem, [4] * 8, max_mem=16.0)
    assert plan.num_active <= 2   # 8x2GB packs into 1-2 workers


# -- selectable-policy invariants (engine resize input) ----------------------
@settings(max_examples=60, deadline=None)
@given(mem=mems, cap=st.floats(1.0, 40.0), target=st.integers(1, 4))
def test_policy_invariants(mem, cap, target):
    """The invariants the live resize path relies on, for every policy:
    layer conservation, memory cap respected on every merged-into worker,
    num_active consistency, target respected."""
    nl = [3] * len(mem)
    for policy in sorted(REPACK_POLICIES):
        plan = repack(policy, mem, nl, max_mem=cap,
                      target_num_workers=target)
        # num_active consistency: property == mask sum == nonzero stages
        assert plan.num_active == sum(plan.active_workers)
        assert plan.num_active == sum(1 for n_ in plan.layers_per_stage
                                      if n_)
        # layers conserved, compaction covers all of them
        assert sum(plan.layers_per_stage) == sum(nl)
        compact = [plan.layers_per_stage[s] for s in range(len(mem))
                   if plan.active_workers[s]]
        assert sum(compact) == sum(nl) and all(n_ > 0 for n_ in compact)
        # memory: inactive workers drained; any worker that RECEIVED layers
        # is under the cap (an untouched one may exceed it from the start)
        for s in range(len(mem)):
            if not plan.active_workers[s]:
                assert plan.mem_usage[s] == 0.0
                assert plan.layers_per_stage[s] == 0
            elif plan.mem_usage[s] > mem[s]:
                assert plan.mem_usage[s] < cap
        # never below the target worker count (nor above the input count)
        assert min(len(mem), target) <= plan.num_active <= len(mem)
        # transfers mirror the counts: every emptied stage's layers moved
        # at least once (chained merges re-move already-merged layers)
        assert len(plan.transfers) >= 3 * sum(
            1 for s in range(len(mem)) if not plan.active_workers[s])


@pytest.mark.parametrize("policy", sorted(REPACK_POLICIES))
def test_policy_respects_max_layers(policy):
    plan = repack(policy, [1.0] * 4, [4] * 4, max_mem=100.0,
                  target_num_workers=1, max_layers=8)
    assert max(plan.layers_per_stage) <= 8
    assert plan.num_active == 2      # 16 layers / 8-slot cap -> 2 workers


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        repack("best_fit", [1.0], [1], max_mem=1.0)
