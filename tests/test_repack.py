"""Re-packing tests (paper §3.4, Algorithm 2)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # dep gated: fixed-seed sweep instead of shrinking
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.repack import (REPACK_POLICIES, repack, repack_adjacent,
                               repack_first_fit)

mems = st.lists(st.floats(0.1, 10.0), min_size=2, max_size=16)


@settings(max_examples=100, deadline=None)
@given(mem=mems, cap=st.floats(1.0, 40.0))
def test_first_fit_invariants(mem, cap):
    nl = [4] * len(mem)
    plan = repack_first_fit(mem, nl, max_mem=cap)
    # memory capacity never exceeded on active workers
    for s, m in enumerate(plan.mem_usage):
        if plan.active_workers[s]:
            assert m < cap or m == mem[s]  # untouched worker may exceed cap
    # layers conserved
    assert sum(plan.layers_per_stage) == sum(nl)
    # inactive workers hold nothing
    for s, a in enumerate(plan.active_workers):
        if not a:
            assert plan.layers_per_stage[s] == 0
            assert plan.mem_usage[s] == 0.0
    # never increases worker count
    assert plan.num_active <= len(mem)


def test_first_fit_consolidates():
    plan = repack_first_fit([1.0, 1.0, 1.0, 1.0], [2, 2, 2, 2], max_mem=4.1)
    # 4 workers of mem 1 fit pairwise under 4.1 -> deep consolidation
    assert plan.num_active <= 2


def test_target_respected():
    plan = repack_first_fit([1.0] * 8, [1] * 8, max_mem=100.0,
                            target_num_workers=4)
    assert plan.num_active >= 4


@settings(max_examples=60, deadline=None)
@given(mem=mems, cap=st.floats(1.0, 40.0))
def test_adjacent_preserves_order(mem, cap):
    nl = [3] * len(mem)
    plan = repack_adjacent(mem, nl, max_mem=cap)
    assert sum(plan.layers_per_stage) == sum(nl)
    # adjacency: an emptied stage's layers went to a later active stage —
    # contiguous global order is preserved by construction (layers only move
    # to the next active neighbour)
    assert plan.num_active >= 1


def test_paper_repack_scenario():
    """Fig. 4: as pruning shrinks the model, 8 GPUs pack into fewer."""
    mem = [2.0] * 8          # after heavy pruning each stage uses 2 of 16GB
    plan = repack_first_fit(mem, [4] * 8, max_mem=16.0)
    assert plan.num_active <= 2   # 8x2GB packs into 1-2 workers


# -- selectable-policy invariants (engine resize input) ----------------------
@settings(max_examples=60, deadline=None)
@given(mem=mems, cap=st.floats(1.0, 40.0), target=st.integers(1, 4))
def test_policy_invariants(mem, cap, target):
    """The invariants the live resize path relies on, for every policy:
    layer conservation, memory cap respected on every merged-into worker,
    num_active consistency, target respected."""
    nl = [3] * len(mem)
    for policy in sorted(REPACK_POLICIES):
        plan = repack(policy, mem, nl, max_mem=cap,
                      target_num_workers=target)
        # num_active consistency: property == mask sum == nonzero stages
        assert plan.num_active == sum(plan.active_workers)
        assert plan.num_active == sum(1 for n_ in plan.layers_per_stage
                                      if n_)
        # layers conserved, compaction covers all of them
        assert sum(plan.layers_per_stage) == sum(nl)
        compact = [plan.layers_per_stage[s] for s in range(len(mem))
                   if plan.active_workers[s]]
        assert sum(compact) == sum(nl) and all(n_ > 0 for n_ in compact)
        # memory: inactive workers drained; any worker that RECEIVED layers
        # is under the cap (an untouched one may exceed it from the start)
        for s in range(len(mem)):
            if not plan.active_workers[s]:
                assert plan.mem_usage[s] == 0.0
                assert plan.layers_per_stage[s] == 0
            elif plan.mem_usage[s] > mem[s]:
                assert plan.mem_usage[s] < cap
        # never below the target worker count (nor above the input count)
        assert min(len(mem), target) <= plan.num_active <= len(mem)
        # transfers mirror the counts: every emptied stage's layers moved
        # at least once (chained merges re-move already-merged layers)
        assert len(plan.transfers) >= 3 * sum(
            1 for s in range(len(mem)) if not plan.active_workers[s])


@pytest.mark.parametrize("policy", sorted(REPACK_POLICIES))
def test_policy_respects_max_layers(policy):
    plan = repack(policy, [1.0] * 4, [4] * 4, max_mem=100.0,
                  target_num_workers=1, max_layers=8)
    assert max(plan.layers_per_stage) <= 8
    assert plan.num_active == 2      # 16 layers / 8-slot cap -> 2 workers


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        repack("best_fit", [1.0], [1], max_mem=1.0)


def test_repack_aware_resize_split_balances_time():
    """ROADMAP "repack-aware balancing": a ResizePlan's target split folds
    the balancer's time cost vector instead of shipping the merged counts
    verbatim — and falls back to the counts when balancing cannot help."""
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.core.controller import ControllerConfig, DynMoController
    from repro.core.cost_model import LayerDynState
    from repro.core.profiler import LayerProfile
    from repro.dynamics.config import DynamicsConfig

    cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                         d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                         vocab_size=128)
    dcfg = DistConfig(num_stages=4, slot_slack=4, remat="none",
                      param_dtype="float32")
    ccfg = ControllerConfig(method="partition", cost_by="time",
                            rebalance_every=1, imbalance_threshold=100.0,
                            repack=True, repack_policy="adjacent",
                            repack_mem_cap=1e9, repack_target=2)
    ctrl = DynMoController(cfg, dcfg, DynamicsConfig(), ccfg)
    states = [LayerDynState() for _ in range(8)]
    params = np.full(8, 1e6)

    # skewed times: adjacent merging of [2,2,2,2] gives [4,4] (bottleneck
    # 11), the balanced 2-split is [1,7] (bottleneck 8)
    times = np.array([8, 1, 1, 1, 1, 1, 1, 1], float)
    ctrl.decide(LayerProfile(times, params, np.zeros(4), states), 1)
    plan = ctrl.take_resize()
    assert plan is not None and plan.target_stages == 2
    assert plan.layers_per_stage == [1, 7], plan.layers_per_stage

    # uniform times: the merged counts are already optimal -> unchanged
    ctrl.rebind(dcfg, [2, 2, 2, 2])
    times = np.ones(8)
    ctrl.decide(LayerProfile(times, params, np.zeros(4), states), 2)
    plan = ctrl.take_resize()
    assert plan is not None and plan.layers_per_stage == [4, 4]

    # a tight per-worker memory cap must still bind the balanced split
    ccfg.repack_mem_cap = 6.5e6 * 5.0   # 6.5 layers' state per worker
    ctrl.rebind(dcfg, [2, 2, 2, 2])
    times = np.array([8, 1, 1, 1, 1, 1, 1, 1], float)
    ctrl.decide(LayerProfile(times, params, np.zeros(4), states), 3)
    plan = ctrl.take_resize()
    assert plan is not None
    assert max(plan.layers_per_stage) <= 6, plan.layers_per_stage


def test_repack_aware_split_rescues_over_budget_counts():
    """When the packing's counts regroup over the memory budget as a
    contiguous split, a memory-feasible balanced split must win even if
    its time bottleneck is no better — otherwise the consolidation would
    be dropped with a feasible split in hand."""
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.core.controller import ControllerConfig, DynMoController
    from repro.dynamics.config import DynamicsConfig

    cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                         d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                         vocab_size=128)
    dcfg = DistConfig(num_stages=4, slot_slack=4, remat="none",
                      param_dtype="float32")
    ctrl = DynMoController(cfg, dcfg, DynamicsConfig(),
                           ControllerConfig(method="partition"))
    costs = np.ones(8)
    mem = np.array([5, 1, 1, 1, 1, 1, 1, 1], float)
    # compact [4,4] groups 8|4 against a cap of 7.5 -> infeasible; the
    # balanced [3,5] (mem 7|5) is feasible despite a worse bottleneck
    out = ctrl._balance_resize_split(costs, mem, [4, 4], 2, 7.5)
    assert out == [3, 5], out
