"""Elastic serving subsystem tests.

Fast tests exercise the host-side logic (slot invariants, scheduler
bookkeeping, trace generation, autoscaler load signals).  Slow tests run
the real pipeline in subprocesses (multi-device): continuous batching vs
the one-shot serving path, staggered-vs-batched admission equivalence, and
the elastic shrink/grow cycle with bit-identical KV-cache preservation.
"""
import numpy as np
import pytest

from conftest import run_in_subprocess


# ---------------------------------------------------------------------------
# Slot manager
# ---------------------------------------------------------------------------
def test_slot_manager_invariants_random_walk():
    from repro.serve.slots import SlotManager

    rng = np.random.RandomState(0)
    sm = SlotManager(3, 4)
    live = {}
    next_rid = 0
    for step in range(500):
        op = rng.rand()
        if op < 0.45 and sm.num_free:
            lane = sm.alloc(next_rid)
            assert lane not in live
            live[lane] = next_rid
            next_rid += 1
        elif op < 0.8 and live:
            lane = list(live)[rng.randint(len(live))]
            rid = sm.free(lane)
            assert rid == live.pop(lane)
        else:
            perm = sm.defrag()
            if perm is not None:
                assert sorted(perm.tolist()) == list(range(sm.n_lanes))
                live = {i: live[int(src)] for i, src in enumerate(perm)
                        if int(src) in live}
                # compacted: live lanes form a prefix
                assert sorted(live) == list(range(len(live)))
        sm.check()
        assert sm.num_active == len(live)
        for lane, rid in live.items():
            assert sm.lane_of(rid) == lane
    # drain: every lane freed exactly once, none leaked
    for lane in list(live):
        sm.free(lane)
    assert sm.num_active == 0 and sm.num_free == sm.n_lanes


def test_slot_alloc_guards():
    from repro.serve.slots import SlotManager

    sm = SlotManager(1, 2)
    sm.alloc(7)
    with pytest.raises(ValueError):
        sm.alloc(7)                     # double-admission of one request
    sm.alloc(8)
    with pytest.raises(RuntimeError):
        sm.alloc(9)                     # no free lane
    with pytest.raises(ValueError):
        sm.free(5)                      # out-of-range / free lane


# ---------------------------------------------------------------------------
# Trace + queue
# ---------------------------------------------------------------------------
def test_trace_generator_deterministic_and_bounded():
    from repro.serve.requests import RequestQueue, make_trace

    kw = dict(prompt_len=16, max_gen=12, vocab_size=99, seed=5,
              min_prompt=4, burst_period=8, burst_len=2, burst_rate=3,
              lull_rate=1, early_exit_frac=0.5)
    a = make_trace(40, **kw)
    b = make_trace(40, **kw)
    assert [(r.arrival, r.plen, r.gen, r.kind) for r in a] \
        == [(r.arrival, r.plen, r.gen, r.kind) for r in b]
    assert all(4 <= r.plen <= 16 for r in a)
    assert all(1 <= r.gen <= 12 for r in a)
    ee = [r for r in a if r.kind == "early_exit"]
    assert ee and all(r.gen <= max(2, 12 // 4) for r in ee)
    assert any(r.arrival > 0 for r in a)          # actually bursty
    q = RequestQueue(a)
    q.poll(0)
    assert q.depth == sum(1 for r in a if r.arrival == 0)
    q.poll(10 ** 9)
    assert q.depth == len(a) and not q.exhausted
    while q.pop() is not None:
        pass
    assert q.exhausted


# ---------------------------------------------------------------------------
# Scheduler bookkeeping (fake model: ids fed back from a seeded rng)
# ---------------------------------------------------------------------------
def _drive(sched, vocab=50, seed=0, max_ticks=500):
    rng = np.random.RandomState(seed)
    m, B = sched.slots.num_micro, sched.slots.mb
    tick = 0
    while not sched.done and tick < max_ticks:
        adm = sched.plan_admissions(tick)
        if adm is not None:
            sched.note_prefill(adm, rng.randint(0, vocab, (m, B)), tick)
        dec = sched.plan_decode()
        if dec is not None:
            assert dec.pos[dec.active].min() >= 0
            assert dec.pos[dec.active].max() < sched.cache_len
            sched.note_decode(dec, rng.randint(0, vocab, (m, B)), tick)
        sched.maybe_defrag(tick)
        sched.slots.check()
        tick += 1
    return tick


def test_scheduler_completes_all_requests_and_respects_budgets():
    from repro.serve.requests import RequestQueue, make_trace
    from repro.serve.scheduler import Scheduler

    reqs = make_trace(23, prompt_len=8, max_gen=6, vocab_size=50, seed=2,
                      min_prompt=2, burst_period=5, burst_len=2,
                      burst_rate=4, lull_rate=0, early_exit_frac=0.3)
    sched = Scheduler(2, 3, 8, 12, RequestQueue(reqs), defrag_every=2)
    _drive(sched, seed=1)
    assert sched.done and len(sched.completions) == 23
    for r in sched.completions:
        assert 0 <= r.admitted <= r.finished
        assert len(r.tokens) == min(r.gen, 12 - r.plen + 1)
    # no lane left owned, nothing double-counted
    assert sched.slots.num_active == 0
    assert sorted(r.rid for r in sched.completions) == list(range(23))


def test_trace_zero_arrival_rate_rejected():
    from repro.serve.requests import make_trace

    with pytest.raises(ValueError):
        make_trace(4, prompt_len=8, max_gen=4, vocab_size=10,
                   burst_period=25, burst_len=0, lull_rate=0)


def test_scheduler_reuse_of_request_objects_is_clean():
    """Admission owns the runtime fields: driving the same Request objects
    through a second scheduler must not append onto the first run's
    tokens."""
    from repro.serve.requests import RequestQueue, make_trace
    from repro.serve.scheduler import Scheduler

    reqs = make_trace(5, prompt_len=6, max_gen=4, vocab_size=50, seed=3,
                      min_prompt=2)
    runs = []
    for _ in range(2):
        sched = Scheduler(1, 2, 6, 10, RequestQueue(reqs))
        _drive(sched, seed=9)
        runs.append({r.rid: list(r.tokens) for r in sched.completions})
    assert runs[0] == runs[1]


def test_scheduler_eos_vacates_lane_early():
    from repro.serve.requests import Request, RequestQueue
    from repro.serve.scheduler import Scheduler

    r = Request(rid=0, arrival=0, prompt=np.arange(4, dtype=np.int32),
                gen=50)
    sched = Scheduler(1, 1, 8, 64, RequestQueue([r]), eos_id=3)
    rng = np.random.RandomState(0)
    tick = 0
    while not sched.done and tick < 100:
        adm = sched.plan_admissions(tick)
        if adm is not None:
            sched.note_prefill(adm, np.zeros((1, 1), np.int64), tick)
        dec = sched.plan_decode()
        if dec is not None:
            ids = np.full((1, 1), 3 if tick == 5 else 9)
            sched.note_decode(dec, ids, tick)
        tick += 1
    assert sched.done
    assert sched.completions[0].tokens[-1] == 3
    assert len(sched.completions[0].tokens) == 6     # ticks 0..5, eos last


# ---------------------------------------------------------------------------
# Autoscaler load signals
# ---------------------------------------------------------------------------
def test_autoscaler_load_signals_hysteresis():
    from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig

    sc = Autoscaler(AutoscalerConfig(min_stages=2, max_stages=4, patience=3,
                                     cooldown=5, queue_high=4,
                                     occupancy_low=0.3))
    # pressure below patience -> nothing
    for t in range(2):
        d = sc.observe_load(t, 4, queue_depth=9, occupancy=1.0)
        assert d.action == "none"
    d = sc.observe_load(2, 4, queue_depth=9, occupancy=1.0)
    assert d.action == "none"          # at max_stages: no grow possible
    # same pressure at 3 stages: grows on the 3rd consecutive signal
    sc2 = Autoscaler(AutoscalerConfig(min_stages=2, max_stages=4, patience=3,
                                      cooldown=5, queue_high=4,
                                      occupancy_low=0.3))
    acts = [sc2.observe_load(t, 3, queue_depth=9, occupancy=1.0).action
            for t in range(3)]
    assert acts == ["none", "none", "grow"]
    sc2.note_resize(2, 4)
    # cooldown: drain signals inside it are ignored entirely
    for t in range(3, 7):
        assert sc2.observe_load(t, 4, queue_depth=0,
                                occupancy=0.0).action == "none"
    # after cooldown, sustained drain shrinks
    acts = [sc2.observe_load(t, 4, queue_depth=0, occupancy=0.0).action
            for t in range(7, 10)]
    assert acts == ["none", "none", "shrink"]
    # at min_stages a drain never shrinks further
    sc3 = Autoscaler(AutoscalerConfig(min_stages=2, max_stages=4, patience=1,
                                      cooldown=0, queue_high=4,
                                      occupancy_low=0.3))
    assert sc3.observe_load(0, 2, queue_depth=0,
                            occupancy=0.0).action == "none"


def test_autoscaler_latency_slo_signal():
    from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig

    sc = Autoscaler(AutoscalerConfig(min_stages=1, max_stages=4, patience=2,
                                     cooldown=0, queue_high=10 ** 9,
                                     latency_slo_s=0.1))
    acts = [sc.observe_load(t, 2, queue_depth=0, occupancy=1.0,
                            latency_s=0.5).action for t in range(2)]
    assert acts == ["none", "grow"]
    assert "latency" in sc.decisions[-1].reason


# ---------------------------------------------------------------------------
# Pipeline-level (slow, subprocess-isolated)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_continuous_batching_equals_one_shot_serving():
    """A full batch arriving at once through the continuous scheduler must
    reproduce run_serving's tokens exactly (same seed/prompts)."""
    out = run_in_subprocess("""
import numpy as np
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.pipeline.pipeline import PipelineShapes
from repro.serve import ElasticServer
from repro.serve.requests import Request
from repro.launch.serve import run_serving

micro, mbg, plen, gen = 2, 2, 8, 5
out = run_serving("smollm-360m", stages=4, micro=micro, mb_global=mbg,
                  prompt_len=plen, gen=gen, layers=8, d_model=64, seed=0)
ref = out["tokens"]
cfg = reduced_config(get_config("smollm-360m"), num_layers=8, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512)
dcfg = DistConfig(num_stages=4, slot_slack=2, remat="none",
                  param_dtype="float32")
shapes = PipelineShapes(num_micro=micro, mb_global=mbg, seq=plen,
                        cache_len=plen + gen)
rng = np.random.RandomState(0)
prompts = rng.randint(0, cfg.vocab_size, (micro, mbg, plen))
reqs = [Request(rid=i, arrival=0,
                prompt=prompts[i // mbg, i % mbg].astype(np.int32), gen=gen)
        for i in range(micro * mbg)]
srv = ElasticServer(cfg, dcfg, DynamicsConfig(), shapes, seed=0)
rep = srv.serve(reqs)
for i, c in enumerate(rep["completions"]):
    want = ref[i // mbg, i % mbg].tolist()
    assert want == c["tokens"], (i, want, c["tokens"])
srv.close()
print("PASS")
""", devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_staggered_admission_and_defrag_keep_tokens():
    """The same requests produce identical tokens whether they arrive all
    at once or staggered into a smaller batch (bootstrap decode for short
    prompts, lanes reused across completions), with and without defrag —
    continuous batching must be invisible to each request."""
    out = run_in_subprocess("""
import copy
import numpy as np
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.pipeline.pipeline import PipelineShapes
from repro.serve import ElasticServer
from repro.serve.requests import Request

cfg = reduced_config(get_config("smollm-360m"), num_layers=6, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
dcfg = DistConfig(num_stages=2, slot_slack=2, remat="none",
                  param_dtype="float32")
rng = np.random.RandomState(7)
plens = [8, 5, 3, 8, 6, 4]
gens  = [4, 3, 5, 2, 4, 3]
base = [Request(rid=i, arrival=0,
                prompt=rng.randint(0, 256, plens[i]).astype(np.int32),
                gen=gens[i]) for i in range(6)]

def serve(mb, arrivals, defrag):
    shapes = PipelineShapes(num_micro=1, mb_global=mb, seq=8, cache_len=16)
    srv = ElasticServer(cfg, dcfg, DynamicsConfig(), shapes, seed=0,
                        defrag_every=defrag)
    reqs = copy.deepcopy(base)
    for r, a in zip(reqs, arrivals):
        r.arrival = a
    rep = srv.serve(reqs)
    srv.close()
    return {c["rid"]: c["tokens"] for c in rep["completions"]}

wide = serve(6, [0] * 6, 0)                  # everyone fits at once
narrow = serve(2, [0, 0, 1, 2, 4, 5], 0)     # staggered through 2 lanes
defrag = serve(2, [0, 0, 1, 2, 4, 5], 1)     # + compaction every tick
assert wide == narrow, (wide, narrow)
assert wide == defrag, (wide, defrag)
print("PASS")
""", devices=2, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_elastic_serving_autoscale_cycle_token_identity():
    """The acceptance demo as a gate: a bursty trace drives at least one
    autoscale shrink (workers released via the JobManagerClient) and one
    grow-back; tokens are identical to the fixed-mesh run; and a live
    4->2->4 cache round-trip is bit-exact."""
    out = run_in_subprocess("""
import copy
import jax
import numpy as np
from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.pipeline.pipeline import PipelineShapes
from repro.serve import ElasticServer
from repro.serve.requests import Request

cfg = reduced_config(get_config("smollm-360m"), num_layers=8, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
dcfg = DistConfig(num_stages=4, slot_slack=2, remat="none",
                  param_dtype="float32")
shapes = PipelineShapes(num_micro=2, mb_global=2, seq=8, cache_len=24)
rng = np.random.RandomState(0)
prompt = lambda n: rng.randint(0, 256, n).astype(np.int32)
trace = [Request(rid=i, arrival=0, prompt=prompt(8), gen=2 + i % 3,
                 kind="early_exit") for i in range(6)]
trace += [Request(rid=6 + i, arrival=0, prompt=prompt(6), gen=16)
          for i in range(2)]
trace += [Request(rid=8 + i, arrival=30, prompt=prompt(8), gen=3)
          for i in range(6)]

def serve(autoscale):
    scaler = Autoscaler(AutoscalerConfig(
        min_stages=2, max_stages=4, patience=2, cooldown=3, queue_high=2,
        occupancy_low=0.6)) if autoscale else None
    srv = ElasticServer(cfg, dcfg, DynamicsConfig(), shapes, scaler=scaler,
                        min_stages=2, seed=0)
    rep = srv.serve(copy.deepcopy(trace), autoscale=autoscale)
    state, engine = srv.state, srv.engine
    return rep, state, engine, srv

el, state, engine, srv = serve(True)
fx, _, _, srv2 = serve(False)
kinds = [r["kind"] for r in el["resizes"]]
assert "shrink" in kinds and "grow" in kinds, kinds
assert any(e.startswith("release:") for e in el["pool_log"]), el["pool_log"]
assert any(e.startswith("grant:") for e in el["pool_log"]), el["pool_log"]
for a, b in zip(el["completions"], fx["completions"]):
    assert a["tokens"] == b["tokens"], (a, b)

# live cache round-trip: shrink to 2 and back must be bit-exact
lps0 = list(state.lps)
before = jax.tree.map(lambda a: np.asarray(a), state.cache)
s2 = engine.resize(state, 2)
s4 = engine.resize(s2, len(lps0), lps0)
after = jax.tree.map(lambda a: np.asarray(a), s4.cache)
for k in before:
    assert (before[k] == after[k]).all(), k
srv.close(); srv2.close()
print("PASS", kinds)
""", devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_measured_stage_times_reflect_load():
    """The engine's stage probe measures real per-stage wall times: a 7:1
    layer split must time the loaded stage slower, and the trainer path
    returns the measured vector."""
    out = run_in_subprocess("""
import jax
import numpy as np
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.launch.engine import ElasticEngine
from repro.pipeline.pipeline import PipelineShapes

cfg = reduced_config(get_config("smollm-360m"), num_layers=8, d_model=128,
                     num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256)
dcfg = DistConfig(num_stages=2, slot_slack=6, remat="none",
                  param_dtype="float32")
shapes = PipelineShapes(num_micro=2, mb_global=2, seq=64)
engine = ElasticEngine(cfg, dcfg, DynamicsConfig(), shapes)
state = engine.init_state(jax.random.PRNGKey(0))
batch = {"tokens": np.zeros((2, 2, 64), np.int32)}
t_even = engine.measure_stage_times(state, batch)
assert t_even.shape == (2,) and (t_even > 0).all()
skew = engine.resize(state, 2, [7, 1])
t_skew = engine.measure_stage_times(skew, batch)
assert t_skew[0] > t_skew[1], t_skew

from repro.launch.train import run_training
out = run_training("smollm-360m", steps=6, stages=2, layers=4, d_model=64,
                   seq=32, num_micro=2, mb_global=2, rebalance_every=3,
                   log_every=100, measure_stage_times=True)
mt = out["measured_stage_times"]
assert mt is not None and len(mt) == 2 and all(t > 0 for t in mt)
print("PASS", t_skew)
""", devices=2, timeout=900)
    assert "PASS" in out
