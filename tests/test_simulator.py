"""Pipeline simulator tests: closed-form GPipe checks + dynamism scenarios."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cost_model import LayerDynState, cost_vector
from repro.core.simulator import (TrainSimConfig, simulate_pipeline,
                                  simulate_training,
                                  stage_times_from_layers)
from repro.dynamics.config import DynamicsConfig
from repro.dynamics.trajectories import make_trajectory


def test_gpipe_closed_form():
    """Balanced stages: makespan = (m + S - 1)(f + b); bubble = (S-1)/(m+S-1)."""
    S, m, f, b = 4, 8, 1.0, 2.0
    r = simulate_pipeline([f] * S, [b] * S, m, schedule="gpipe")
    assert abs(r.makespan - (m + S - 1) * (f + b)) < 1e-9
    assert abs(r.bubble_ratio - (S - 1) / (m + S - 1)) < 1e-9


def test_1f1b_no_worse_than_gpipe():
    rng = np.random.RandomState(0)
    for _ in range(10):
        S = rng.randint(2, 8)
        m = rng.randint(2, 16)
        f = rng.rand(S) + 0.1
        b = 2 * (rng.rand(S) + 0.1)
        g = simulate_pipeline(f, b, m, schedule="gpipe")
        o = simulate_pipeline(f, b, m, schedule="1f1b")
        assert o.makespan <= g.makespan + 1e-9


def test_bottleneck_stage_dominates():
    """One hot stage should set the steady-state rate."""
    S, m = 4, 32
    f = np.array([1.0, 1.0, 4.0, 1.0])
    b = 2 * f
    r = simulate_pipeline(f, b, m, schedule="1f1b")
    # steady state >= m * (f+b) of the hottest stage
    assert r.makespan >= m * 6.0 * 2 - 1e-9


def test_balancing_improves_makespan():
    """Imbalanced per-layer costs: DynMo split beats uniform split."""
    from repro.core.balancer import balance, partition_balance
    rng = np.random.RandomState(1)
    layer_f = np.concatenate([np.full(16, 0.1), np.full(16, 1.0)])
    layer_b = 2 * layer_f
    uni = balance("uniform", layer_f + layer_b, 4).layers_per_stage
    opt = partition_balance(layer_f + layer_b, 4).layers_per_stage
    r_uni = simulate_pipeline(*stage_times_from_layers(layer_f, layer_b, uni),
                              16)
    r_opt = simulate_pipeline(*stage_times_from_layers(layer_f, layer_b, opt),
                              16)
    assert r_opt.makespan < 0.75 * r_uni.makespan
    assert r_opt.bubble_ratio < r_uni.bubble_ratio


@pytest.mark.parametrize("kind,arch,seq,min_speedup", [
    # floors are deliberately below the expected values (stochastic
    # trajectories); the paper-band comparison lives in
    # benchmarks/bench_throughput.py with the paper's baseline conventions.
    # MoE needs an actual MoE arch; sparse attention needs long sequences
    # (at 2k attention is <20% of a layer's FLOPs).
    ("early_exit", "gpt-paper-32l", 2048, 1.25),
    ("freezing", "gpt-paper-32l", 2048, 1.10),
    ("sparse_attention", "gpt-paper-32l", 16384, 1.03),
    ("pruning", "gpt-paper-32l", 2048, 1.05),
    ("moe", "mixtral-8x7b", 2048, 1.01),
    ("mod", "gpt-paper-32l", 2048, 1.04),
])
def test_dynmo_speedup_per_case(kind, arch, seq, min_speedup):
    """End-to-end sim: DynMo (best of partition/diffusion, by-time) vs
    static uniform running the SAME dynamic model; m = 4·S microbatches
    (paper's 4 per GPU — at m≈S the fill/drain phase dominates and layer
    migration cannot help; see EXPERIMENTS.md granularity discussion)."""
    cfg = get_config(arch)
    dyncfg = DynamicsConfig(kind=kind, prune_start_iter=1000,
                            prune_end_iter=6000)
    traj = make_trajectory(kind, cfg, dyncfg, total_iters=8000, seed=0)
    tokens = 64 * seq

    def layer_time_fn(k):
        states = traj(k)
        t = cost_vector(cfg, tokens // 8, seq, states, by="time")
        return t / 3.0, 2 * t / 3.0

    pbytes = cost_vector(cfg, tokens, seq, None, by="param") * 2
    S, m = 8, 32
    base = TrainSimConfig(num_stages=S, num_micro=m, tokens_per_iter=tokens,
                          iters=8000, sample_every=200, rebalance_every=0,
                          balancer="uniform")
    r0 = simulate_training(layer_time_fn, pbytes, base)
    best = 0.0
    for method in ("partition", "diffusion"):
        dynmo = TrainSimConfig(num_stages=S, num_micro=m,
                               tokens_per_iter=tokens, iters=8000,
                               sample_every=200, rebalance_every=200,
                               balancer=method, cost_by="time",
                               max_slots=16)
        r1 = simulate_training(layer_time_fn, pbytes, dynmo)
        best = max(best, r1.throughput / r0.throughput)
        # overhead stays single-digit percent (paper §3.3.1)
        assert r1.overhead_frac < 0.1, r1.overhead_frac
    assert best >= min_speedup, (kind, best)
