"""The observability layer (DESIGN.md §15): tracer golden fixture,
metrics snapshot golden, logical-clock determinism, the unified event
schema, the /metrics endpoint, and in-step vs probe stage-time parity."""
import json
import os
import sys
import urllib.request

import pytest

sys.path.insert(0, os.path.dirname(__file__))

from conftest import REPO, run_in_subprocess
from repro.obs.events import EVENT_SCHEMA, stamp_record
from repro.obs.metrics import (MetricsRegistry, scheduler_to_prometheus,
                               serve_metrics)
from repro.obs.trace import Tracer, current_tracer, set_current_tracer

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
TRACE_GOLDEN = os.path.join(GOLDEN_DIR, "trace_events.json")
METRICS_GOLDEN = os.path.join(GOLDEN_DIR, "metrics_snapshot.json")


# ---------------------------------------------------------------------------
# tracer: golden fixture + determinism
# ---------------------------------------------------------------------------
def _scripted_tracer() -> Tracer:
    """A fixed span scenario under an injected 1ms-per-call clock and
    pid=0 — everything but thread ids is deterministic."""
    t = [0.0]

    def clock():
        t[0] += 0.001
        return t[0]

    tr = Tracer("golden-run", clock=clock, pid=0, meta={"mode": "test"})
    with tr.span("train", steps=2):
        with tr.span("train.step", cat="step", step=0):
            pass
        ctx = tr.instant("checkpoint.saved", cat="checkpoint", step=0)
        sp = tr.span("resize.shrink", cat="resize",
                     parent_id=ctx["span_id"], target_stages=2)
        sp.end(stages=2)
    return tr


def _normalized_chrome(tr: Tracer) -> dict:
    """Thread ids and the wall-clock anchor are the only nondeterministic
    fields left; zero them for the byte-pinned comparison."""
    doc = tr.to_chrome()
    for ev in doc["traceEvents"]:
        ev["tid"] = 0
    doc["otherData"].pop("wall0", None)
    return doc


def test_trace_golden():
    """The Chrome trace-event export of the scripted scenario is pinned.
    If this fails you changed the trace schema — update DESIGN.md §15 and
    regenerate with ``PYTHONPATH=src python -c "import json, sys;
    sys.path.insert(0, 'tests'); from test_obs import _scripted_tracer,
    _normalized_chrome; json.dump(_normalized_chrome(_scripted_tracer()),
    open('tests/golden/trace_events.json', 'w'), indent=1)"``."""
    with open(TRACE_GOLDEN) as f:
        golden = json.load(f)
    assert _normalized_chrome(_scripted_tracer()) == golden


def test_trace_golden_validates():
    """The golden fixture passes the CI trace validator (so the validator
    and the exporter can't drift apart silently)."""
    sys.path.insert(0, os.path.join(REPO, "scripts"))
    import check_trace
    assert check_trace.main([TRACE_GOLDEN, "--expect-chain",
                             "checkpoint.saved,resize.shrink"]) == 0


def test_trace_event_sequence_deterministic():
    """Two runs of the same scenario produce the identical wall-free
    logical-clock sequence — the determinism contract fixed-seed session
    runs rely on."""
    a = _scripted_tracer().event_sequence()
    b = _scripted_tracer().event_sequence()
    assert a == b
    assert [lc for _, _, lc, _, _ in a] == sorted(
        lc for _, _, lc, _, _ in a), "logical clocks not monotone"


def test_span_nesting_and_cross_process_parenting():
    tr = Tracer("t1", pid=0)
    with tr.span("outer") as outer:
        with tr.span("inner") as inner:
            assert inner.parent_id == outer.span_id
        ctx = tr.instant("leaf")
    # a foreign process parents its span on the shipped ctx
    tr2 = Tracer("t2", pid=1)
    sp = tr2.span("remote", parent_id=ctx["span_id"])
    sp.end()
    ev = tr2.to_chrome()["traceEvents"][0]
    assert ev["args"]["parent_id"] == ctx["span_id"]
    assert ev["args"]["span_id"].startswith("t2.")


# ---------------------------------------------------------------------------
# unified event schema
# ---------------------------------------------------------------------------
def test_stamp_record_local_foreign_and_both():
    tr = Tracer("run-a", pid=0)
    # local tracer: fresh identity + logical clock
    rec = stamp_record({"x": 1}, source="session", kind="log", tracer=tr)
    assert rec["schema"] == EVENT_SCHEMA and rec["source"] == "session"
    assert rec["trace_id"] == "run-a" and isinstance(rec["lc"], int)
    assert "wall" in rec
    # foreign ctx only (e.g. the manager process): adopt the sender's ids
    ctx = tr.instant("rpc.steal")
    far = stamp_record({}, source="scheduler", kind="steal", ctx=ctx,
                       wall=False)
    assert far["trace_id"] == "run-a"
    assert far["parent_id"] == ctx["span_id"] and "wall" not in far
    # local tracer AND a foreign cause: keep identity, parent on the cause
    tr_b = Tracer("run-b", pid=0)
    both = stamp_record({}, source="session", kind="preempt", tracer=tr_b,
                        ctx=ctx)
    assert both["trace_id"] == "run-b"
    assert both["parent_id"] == ctx["span_id"]
    assert both["cause_trace_id"] == "run-a"


def test_current_tracer_is_process_global():
    tr = Tracer("global", pid=0)
    set_current_tracer(tr)
    try:
        assert current_tracer() is tr
        rec = stamp_record({}, source="fault", kind="rpc_loss")
        assert rec["trace_id"] == "global"
    finally:
        set_current_tracer(None)
    assert current_tracer() is None


# ---------------------------------------------------------------------------
# metrics: snapshot golden + exposition + endpoint
# ---------------------------------------------------------------------------
def _scripted_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.inc("dynmo_train_steps_total", 3, help="train steps", mode="train")
    reg.inc("dynmo_resizes_total", kind="shrink", policy="preempt")
    reg.set("dynmo_stages", 4, help="live stage count")
    reg.set("dynmo_stage_time_seconds", 0.25, stage="0", source="in_step")
    for v in (0.004, 0.04, 0.4, 4.0):
        reg.observe("dynmo_step_seconds", v, help="steady step seconds")
    return reg


def test_metrics_snapshot_golden():
    """The JSON snapshot (the CI artifact format) is pinned.  Regenerate
    with ``PYTHONPATH=src python -c "import sys; sys.path.insert(0,
    'tests'); from test_obs import _scripted_registry;
    _scripted_registry().save('tests/golden/metrics_snapshot.json')"``."""
    with open(METRICS_GOLDEN) as f:
        golden = json.load(f)
    assert _scripted_registry().snapshot() == golden


def test_prometheus_exposition():
    text = _scripted_registry().to_prometheus()
    assert "# TYPE dynmo_train_steps_total counter" in text
    assert 'dynmo_train_steps_total{mode="train"} 3' in text
    assert "# TYPE dynmo_stages gauge" in text
    assert "dynmo_stages 4" in text
    assert "# TYPE dynmo_step_seconds histogram" in text
    assert 'dynmo_step_seconds_bucket{le="0.005"} 1' in text
    assert 'dynmo_step_seconds_bucket{le="+Inf"} 4' in text
    assert "dynmo_step_seconds_count 4" in text
    assert text.endswith("\n")


def test_metrics_endpoint_serves_registry():
    reg = _scripted_registry()
    srv = serve_metrics(reg, 0)          # ephemeral port
    try:
        port = srv.server_address[1]
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=10) as r:
            assert "version=0.0.4" in r.headers["Content-Type"]
            body = r.read().decode()
        assert body == reg.to_prometheus()
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{port}/nope", timeout=10)
    finally:
        srv.shutdown()


def test_scheduler_to_prometheus_matches_events():
    """The manager's /metrics counters are derived from the SAME events
    list the metrics RPC verb returns — per-(tenant, event) counts always
    agree (the cluster_smoke gate, unit-sized)."""
    from repro.cluster.scheduler import ClusterScheduler, WorkerPool
    sched = ClusterScheduler(WorkerPool(4))
    sched.register("train", priority=0, workers=3)
    sched.register("serve", priority=10, workers=1)
    sched.steal("serve", 2)
    text = scheduler_to_prometheus(sched)
    for ev in sched.events:
        needle = (f'dynmo_scheduler_events_total{{event="{ev["ev"]}",'
                  f'tenant="{ev["tenant"]}"}}')
        assert needle in text, (needle, text)
    assert 'dynmo_workers_granted{tenant="serve"}' in text
    assert "dynmo_pool_active 4" in text
    # the events themselves carry the unified schema
    assert all(ev.get("schema") == EVENT_SCHEMA and ev.get("kind")
               for ev in sched.events)


# ---------------------------------------------------------------------------
# end-to-end: session wiring, determinism, in-step vs probe parity
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_session_obs_end_to_end_and_deterministic():
    """One subprocess, two identical fixed-seed training runs with the
    full obs stack on: the report's stage times come from the live step
    (source == in_step), the timing block splits warm-up from steady
    state, every event carries the unified schema, the exported trace
    validates in check_trace.py, and the two runs' logical-clock
    sequences are identical."""
    out = run_in_subprocess("""
import dataclasses, json, os
from repro.api import RunSpec, Session

def one_run(tag):
    spec = RunSpec.from_dict({
        "schema_version": 4,
        "model": {"arch": "smollm-360m", "layers": 8, "d_model": 64,
                  "num_heads": 4, "num_kv_heads": 2, "vocab_size": 256},
        "parallel": {"stages": 4, "num_micro": 4, "mb_global": 4,
                     "seq": 16},
        "controller": {"rebalance_every": 3},
        "obs": {"trace": True, "in_step_timing": True,
                "trace_out": f"/tmp/obs_e2e_{tag}.json",
                "metrics_out": f"/tmp/obs_m_{tag}.json"},
        "steps": 7, "log_every": 3})
    with Session(spec) as s:
        rep = s.train()
        seq = s.tracer.event_sequence()
    return rep, seq, [dataclasses.asdict(ev) for ev in s.events]

rep, seq_a, events = one_run("a")
assert rep["stage_time_source"] == "in_step", rep["stage_time_source"]
mt = rep["measured_stage_times"]
assert mt is not None and len(mt) == 4 and all(t > 0 for t in mt)
t = rep["timing"]
assert t["warmup_steps"] >= 1 and t["steady_steps"] >= 1
assert t["warmup_s"] > t["steady_step_mean_s"]   # compile >> one step
assert t["decide_s"] >= 0 and t["steady_tokens_per_s"] > 0
for ev in events:
    assert ev["schema"] == "obs.event/1" and ev["source"] == "session"
    assert ev["trace_id"] and ev["span_id"] and ev["lc"] is not None
snap = json.load(open("/tmp/obs_m_a.json"))
assert snap["schema"] == "obs.metrics/1"
names = {c["name"] for c in snap["counters"]}
assert "dynmo_train_steps_total" in names
assert any(h["name"] == "dynmo_step_seconds" and h["count"] >= 1
           for h in snap["histograms"])

import sys
sys.path.insert(0, os.path.join(%(repo)r, "scripts"))
import check_trace
assert check_trace.main(["/tmp/obs_e2e_a.json", "--expect-event", "train",
                         "--expect-event", "train.step",
                         "--expect-event", "controller.decide"]) == 0

_, seq_b, _ = one_run("b")
assert seq_a == seq_b, "fixed-seed logical-clock sequence diverged"
print("PASS", len(seq_a), "events")
""" % {"repo": REPO}, devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_in_step_times_agree_with_probe_ranking():
    """On a deliberately skewed [8, 1, 1, 1] split the in-step stamps and
    the isolation probe must agree on the stage-time RANKING (the
    controller consumes relative loads, not absolute seconds) — the
    acceptance criterion for replacing the probe on cadence."""
    out = run_in_subprocess("""
import jax
import numpy as np
from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.launch.engine import ElasticEngine
from repro.pipeline.pipeline import PipelineShapes

cfg = reduced_config(get_config("smollm-360m"), num_layers=11, d_model=128,
                     num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256)
dcfg = DistConfig(num_stages=4, slot_slack=6, remat="none",
                  param_dtype="float32")
shapes = PipelineShapes(num_micro=4, mb_global=4, seq=64)
engine = ElasticEngine(cfg, dcfg, DynamicsConfig(), shapes,
                       in_step_timing=True)
state = engine.init_state(jax.random.PRNGKey(0), lps=[8, 1, 1, 1])
from repro.data.loader import DataConfig, make_loader
loader = make_loader(cfg, DataConfig(num_micro=4, mb_global=4, seq=64))
batch = next(loader)
assert engine.in_step_stage_times(state) is None   # no window yet
for _ in range(4):
    loss, stats, gnorm = engine.step(state, batch, 1e-3)
jax.block_until_ready(loss)
in_step = np.asarray(engine.in_step_stage_times(state))
probe = np.asarray(engine.measure_stage_times(state, batch))
assert in_step.shape == (4,) and (in_step > 0).all(), in_step
# stage 0 carries 8 of 11 layers: both sources must call it slowest,
# and the full ranking must put it strictly above every 1-layer stage
assert in_step.argmax() == 0 and probe.argmax() == 0, (in_step, probe)
assert all(in_step[0] > in_step[i] for i in (1, 2, 3)), in_step
print("PASS in_step", in_step, "probe", probe)
""", devices=4, timeout=900)
    assert "PASS" in out
