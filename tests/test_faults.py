"""Fault-tolerance layer tests (DESIGN.md §12): deterministic fault plans
and the chaos injector, hardened file-RPC (same-seq retry, server-side
dedup/journal, circuit breaker), serving requeue/teacher-forced replay,
worker-pool spares, and the subprocess chaos soaks — kill -9 the job
manager mid-run, SIGKILL the trainer and ``Session.resume`` bit-identically,
and the train/serve parity runs the chaos CI job executes."""
import json
import os

import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.api.specs import FaultSpec
from repro.cluster.rpc import (CircuitBreaker, FileJobManager,
                               JobManagerUnavailable, spawn_file_manager)
from repro.faults import (ChaosFileJobManager, ChaosInjector, FaultEvent,
                          FaultPlan, resolve_plan)
from repro.runtime.fault_tolerance import WorkerPool
from repro.serve.requests import Request, RequestQueue
from repro.serve.scheduler import Scheduler


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------
def test_resolve_plan_pinned_fields_win():
    fs = FaultSpec(enabled=True, seed=3, worker_crash={5: 2},
                   manager_kill=4, manager_respawn=9, rpc_loss=0.2)
    plan = resolve_plan(fs, horizon=20, workers=4, file_manager=True)
    kinds = {(e.kind, e.at) for e in plan.events}
    assert ("worker_crash", 5) in kinds
    assert ("manager_kill", 4) in kinds and ("manager_respawn", 9) in kinds
    assert plan.rpc_loss == 0.2 and plan.any_rpc
    # events come out sorted by (at, kind)
    assert [e.at for e in plan.events] == sorted(e.at for e in plan.events)


def test_resolve_plan_auto_is_seeded_and_reproducible():
    fs = FaultSpec(enabled=True, seed=11, auto=True)
    a = resolve_plan(fs, horizon=40, workers=4, file_manager=True)
    b = resolve_plan(fs, horizon=40, workers=4, file_manager=True)
    assert a.to_dict() == b.to_dict()            # same seed, same schedule
    kinds = {e.kind for e in a.events}
    assert {"worker_crash", "manager_kill",
            "manager_respawn", "straggler_spike"} <= kinds
    assert a.rpc_loss > 0                        # auto turns on RPC chaos
    c = resolve_plan(FaultSpec(enabled=True, seed=12, auto=True),
                     horizon=40, workers=4, file_manager=True)
    assert c.to_dict() != a.to_dict()            # a new seed moves events
    # no file manager => no manager/rpc faults to derive
    d = resolve_plan(FaultSpec(enabled=True, seed=11, auto=True),
                     horizon=40, workers=4, file_manager=False)
    assert not any(e.kind.startswith("manager") for e in d.events)
    assert not d.any_rpc


def test_injector_fires_once_and_filters_heartbeats():
    plan = FaultPlan(events=[
        FaultEvent(at=3, kind="worker_crash", target=2),
        FaultEvent(at=5, kind="straggler_spike", target=-1, value=2.0),
        FaultEvent(at=7, kind="manager_kill")])
    inj = ChaosInjector(plan)
    calls = []
    inj.bind(kill_manager=lambda: calls.append("kill"))
    assert inj.on_step(0, workers=[0, 1, 2, 3]) == []
    fired = inj.on_step(3, workers=[0, 1, 2, 3])
    assert [e.kind for e in fired] == ["worker_crash"]
    assert inj.heartbeat_workers([0, 1, 2, 3]) == [0, 1, 3]
    assert inj.on_step(3, workers=[0, 1, 2, 3]) == []     # never refires
    assert inj.spike_for([0, 1, 3]) is None
    inj.on_step(5, workers=[0, 1, 3])
    assert inj.spike_for([0, 1, 3]) == [1.0, 1.0, 2.0]    # last stage hit
    inj.on_step(7)
    assert calls == ["kill"]
    assert [r.kind for r in inj.records] == [
        "worker_crash", "straggler_spike", "manager_kill"]


def test_injector_crash_skipped_when_worker_not_active():
    plan = FaultPlan(events=[FaultEvent(at=1, kind="worker_crash",
                                        target=9)])
    inj = ChaosInjector(plan)
    inj.on_step(1, workers=[0, 1, 2])
    assert [r.kind for r in inj.records] == ["worker_crash_skipped"]
    assert 9 not in inj.crashed


def test_injector_resume_semantics():
    plan = FaultPlan(events=[
        FaultEvent(at=2, kind="worker_crash", target=1),
        FaultEvent(at=6, kind="trainer_kill"),
        FaultEvent(at=8, kind="worker_crash", target=3)])
    inj = ChaosInjector(plan, start_step=7, resumed=True)
    # history replay: the pre-restart crash holds (worker 1 stays dead)
    assert inj.heartbeat_workers([0, 1, 2, 3]) == [0, 2, 3]
    # the kill that ended the previous life never refires
    died = []
    inj.bind(kill_self=lambda: died.append(1))
    assert inj.on_step(6) == []
    assert died == []
    # future events still fire
    assert [e.kind for e in inj.on_step(8, workers=[0, 2, 3])] \
        == ["worker_crash"]


# ---------------------------------------------------------------------------
# circuit breaker + file RPC hardening
# ---------------------------------------------------------------------------
def test_circuit_breaker_trips_probes_and_closes():
    br = CircuitBreaker(trip_after=2, probe_every=3)
    assert br.allow() and not br.open
    br.failure()
    assert br.allow() and not br.open            # one failure: still closed
    br.failure()
    assert br.open and br.trips == 1
    # every probe_every-th blocked call is let through as a probe
    assert [br.allow() for _ in range(6)] == [False, False, True,
                                              False, False, True]
    assert br.fast_fails == 4
    br.success()                                 # the probe succeeded
    assert not br.open and br.allow()


def test_rpc_retry_same_seq_recovers_total_loss(tmp_path):
    """rpc_loss=1.0 drops every FIRST delivery; the retry re-publishes the
    same sequence number and every op still succeeds exactly once."""
    root = str(tmp_path)
    proc = spawn_file_manager(root, workers=4, idle_timeout_s=60.0)
    try:
        jm = ChaosFileJobManager(root, FaultPlan(rpc_loss=1.0, seed=0),
                                 timeout_s=2.0, poll_s=0.005, retries=4,
                                 backoff_s=0.01)
        assert jm.release([3]) == [3]
        assert jm.request(1) == [3]
        assert jm.num_active == 4
        assert jm.rpc_stats["retries"] >= 2      # one per op so far
        assert jm.breaker.trips == 0             # retries absorbed the loss
        jm.close()
        assert proc.wait(timeout=20) == 0
    finally:
        if proc.poll() is None:
            proc.kill()


def test_rpc_dup_delivery_deduped_by_server(tmp_path):
    """rpc_dup=1.0 re-publishes every answered request; the server's seq
    journal must re-serve, never re-execute (active counts stay exact)."""
    root = str(tmp_path)
    proc = spawn_file_manager(root, workers=4, idle_timeout_s=60.0)
    try:
        jm = ChaosFileJobManager(root, FaultPlan(rpc_dup=1.0, seed=0),
                                 timeout_s=5.0, poll_s=0.005)
        assert jm.release([2]) == [2]
        assert jm.num_active == 3                # released once, not twice
        assert jm.request(4) == [2]              # only one worker to grant
        assert jm.num_active == 4
        jm.close()
        assert proc.wait(timeout=20) == 0
    finally:
        if proc.poll() is None:
            proc.kill()


def test_rpc_unavailable_trips_breaker_and_withdraws(tmp_path):
    """No server: the call burns its retry budget, raises, and the breaker
    opens — later calls fail fast.  Given-up req files are withdrawn so a
    late server can never execute them."""
    jm = FileJobManager(str(tmp_path), timeout_s=0.2, poll_s=0.02,
                        retries=2, backoff_s=0.01, breaker_after=2,
                        breaker_probe_every=4)
    for _ in range(2):
        with pytest.raises(JobManagerUnavailable):
            jm.request(1)
    assert jm.breaker.open and jm.breaker.trips == 1
    t0 = os.times()[4]
    with pytest.raises(JobManagerUnavailable):
        jm.release([1])                          # fast fail, no timeout burn
    assert os.times()[4] - t0 < 0.15
    assert jm.breaker.fast_fails >= 1
    assert not [n for n in os.listdir(str(tmp_path))
                if n.startswith("req-")]         # withdrawn on give-up
    assert jm.num_active == -1                   # telemetry degrades, no raise


def test_server_journal_survives_kill9_exactly_once(tmp_path):
    """Journal-before-publish: after the server is SIGKILLed and its
    response deleted (simulating loss), a respawned server re-serves the
    journaled answer for the same seq without re-executing the op."""
    root = str(tmp_path)
    proc = spawn_file_manager(root, workers=4, idle_timeout_s=60.0)
    try:
        jm = FileJobManager(root, timeout_s=10.0, poll_s=0.005)
        assert jm.release([1]) == [1]
        proc.kill()
        proc.wait()
        # the answer is lost in flight; the client will retry seq 1
        os.unlink(os.path.join(root, "resp-000001.json"))
        with open(os.path.join(root, "req-000001.json"), "w") as f:
            json.dump({"op": "release", "seq": 1, "workers": [1]}, f)
        proc = spawn_file_manager(root, workers=4, idle_timeout_s=60.0)
        out = jm._await(os.path.join(root, "resp-000001.json"),
                        deadline=os.times()[4] + 1e9, attempt=1)
        assert out["released"] == [1]            # journaled answer, and
        assert out["active"] == 3                # the op ran exactly once
        assert jm.num_active == 3
        jm.close()
        assert proc.wait(timeout=20) == 0
    finally:
        if proc.poll() is None:
            proc.kill()


# ---------------------------------------------------------------------------
# worker pool spares
# ---------------------------------------------------------------------------
def test_worker_pool_spares_mint_fresh_ids():
    pool = WorkerPool(4, spares=2)
    pool.fail(2)
    assert pool.request(1) == [4]                # never-seen id, not 2
    assert pool.request(2) == [5]                # spare budget caps at 2
    assert pool.request(1) == []
    pool.release([4])
    assert pool.request(1) == [4]                # released beats minting
    sd = pool.state_dict()
    back = WorkerPool.from_state(sd)
    assert back.state_dict() == sd
    assert back.request(1) == []                 # spare budget persisted


# ---------------------------------------------------------------------------
# serving requeue + teacher-forced replay (scheduler level, no engine)
# ---------------------------------------------------------------------------
def _mk_sched(reqs, num_micro=1, mb=2, prompt_len=4, cache_len=12):
    return Scheduler(num_micro, mb, prompt_len, cache_len,
                     RequestQueue(reqs))


def test_requeue_carries_tokens_and_replay_rebuilds():
    r0 = Request(rid=0, arrival=0, prompt=np.arange(4, dtype=np.int32),
                 gen=6)
    r1 = Request(rid=1, arrival=0, prompt=np.arange(2, dtype=np.int32),
                 gen=6)
    sched = _mk_sched([r0, r1])
    plan = sched.plan_admissions(0)
    assert {r.rid for _, r in plan.lanes} == {0, 1}
    # r0 is full-length: token 1 comes from the prefill argmax
    sched.note_prefill(plan, np.array([[100, 0]]), 0)
    assert r0.tokens == [100]
    # two decode ticks: both lanes emit
    dec = sched.plan_decode()
    sched.note_decode(dec, np.array([[101, 201]]), 1)
    assert r0.tokens == [100, 101] and r1.tokens == [201]
    # crash: everything in flight goes back to the FRONT of the queue
    requeued = sched.requeue_live(2)
    assert [r.rid for r in requeued] == [0, 1]
    assert list(sched.queue.pending)[0].rid == 0      # lane order kept
    assert r0.carried == [100, 101] and r0.requeues == 1
    assert sched.slots.num_active == 0 and not sched.live
    # re-admission rebuilds through decode ONLY: the prefill covers the
    # original prompt, every carried token is teacher-forced — the same
    # op sequence that produced the KV line the first time
    plan = sched.plan_admissions(3)
    lane0 = next(ln for ln, r in plan.lanes if r.rid == 0)
    lane1 = next(ln for ln, r in plan.lanes if r.rid == 1)
    assert plan.full_len_lanes == []             # argmax not re-taken
    assert sched.cur_tok[lane0] == 100           # full-length: resume at
    assert sched.pos[lane0] == 4                 # its first decode...
    assert list(sched.replay[lane0]) == [101]    # ...replaying the rest
    assert sched.cur_tok[lane1] == 1             # short: bootstrap decode
    assert sched.pos[lane1] == 1                 # re-feeds prompt[-1]
    assert list(sched.replay[lane1]) == [201]
    assert sched.gen_done[lane1] == 1            # carried token counted
    sched.note_prefill(plan, np.array([[0, 0]]), 3)
    assert r0.tokens == [100, 101]               # replay lanes take nothing
    # replay tick: emissions ignored, KNOWN tokens fed back
    dec = sched.plan_decode()
    sched.note_decode(dec, np.array([[77, 88]]), 4)
    assert r0.tokens == [100, 101]               # 77/88 never recorded
    assert r1.tokens == [201]
    assert lane0 not in sched.replay             # drained
    assert lane1 not in sched.replay
    # past the replay, new positions record again
    dec = sched.plan_decode()
    sched.note_decode(dec, np.array([[102, 202]]), 6)
    assert r0.tokens == [100, 101, 102]
    assert r1.tokens == [201, 202]
    assert int(sched.pos[lane0]) == 6            # 4 + 1 replay + 1 emit
    assert int(sched.pos[lane1]) == 3            # 1 + 1 replay + 1 emit
    assert sched.requeued_total == 2


def test_requeue_preserves_gen_budget_account():
    """A requeued request finishes after exactly ``gen`` total tokens —
    carried ones count against the budget."""
    r = Request(rid=0, arrival=0, prompt=np.arange(4, dtype=np.int32),
                gen=3)
    sched = _mk_sched([r], mb=1)
    plan = sched.plan_admissions(0)
    sched.note_prefill(plan, np.array([[50]]), 0)
    sched.note_decode(sched.plan_decode(), np.array([[51]]), 1)
    sched.requeue_live(2)
    plan = sched.plan_admissions(3)
    sched.note_prefill(plan, np.array([[0]]), 3)
    for ids in ([[51]], [[52]], [[53]]):
        if sched.done:
            break
        dec = sched.plan_decode()
        if dec is None:
            break
        sched.note_decode(dec, np.array(ids), 4)
    assert r.tokens == [50, 51, 52] and r.finished >= 0
    assert sched.done


# ---------------------------------------------------------------------------
# subprocess chaos soaks (the chaos CI job runs these same shapes)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_kill9_manager_mid_run_trainer_survives():
    """kill -9 the file job-manager mid-run: the trainer retries, trips the
    breaker, keeps training in degraded mode (deferred release/fail
    bookkeeping), reconnects when the manager respawns, and ends with the
    same loss trajectory as a fault-free run."""
    out = run_in_subprocess("""
        from repro.api import RunSpec, Session

        BASE = {
            "steps": 16, "seed": 5, "log_every": 1000,
            "model": {"arch": "smollm-360m", "layers": 8, "d_model": 64,
                      "num_heads": 4, "num_kv_heads": 2, "d_ff": 256,
                      "vocab_size": 512},
            "parallel": {"stages": 4, "num_micro": 2, "mb_global": 2,
                         "seq": 32, "remat": "none",
                         "param_dtype": "float32"},
            "cluster": {"job_manager": "file", "autoscale": True,
                        "heartbeat_timeout": 3.0, "rpc_timeout_s": 2.0,
                        "spares": 1},
        }
        with Session(RunSpec.from_dict(dict(BASE))) as s:
            rep_a = s.train()

        chaos = dict(BASE)
        chaos["faults"] = {"enabled": True, "seed": 1,
                           "worker_crash": {2: 2},
                           "manager_kill": 4, "manager_respawn": 8,
                           "rpc_loss": 0.3, "rpc_dup": 0.3}
        with Session(RunSpec.from_dict(chaos)) as s:
            rep_b = s.train()

        assert len(rep_b["losses"]) == 16
        diffs = [abs(a - b)
                 for a, b in zip(rep_a["losses"], rep_b["losses"])]
        assert max(diffs) < 3e-3, f"loss parity violated: {max(diffs)}"
        kinds = [f["kind"] for f in rep_b["faults"]]
        assert "manager_kill" in kinds and "manager_respawn" in kinds
        assert "worker_crash" in kinds
        assert any(r["kind"] == "evict" for r in rep_b["resizes"])
        st = rep_b["rpc"]["stats"]
        assert st["calls"] > 0 and st["timeouts"] > 0   # dead window hit
        print("KILL9 OK", max(diffs), st)
    """, devices=4)
    assert "KILL9 OK" in out


@pytest.mark.slow
def test_trainer_kill9_then_resume_bit_identical():
    """SIGKILL the trainer AFTER a safe point, ``Session.resume`` from the
    directory: the resumed run's losses equal the never-crashed run's
    bit-for-bit (same worlds, same loader stream, same RNG)."""
    out = run_in_subprocess("""
        import os, subprocess, sys, tempfile

        from repro.api import RunSpec, Session

        ck = tempfile.mkdtemp(prefix="safept_")
        BASE = {
            "steps": 12, "seed": 9, "log_every": 1000,
            "ckpt_dir": ck, "ckpt_every": 4,
            "model": {"arch": "smollm-360m", "layers": 8, "d_model": 64,
                      "num_heads": 4, "num_kv_heads": 2, "d_ff": 256,
                      "vocab_size": 512},
            "parallel": {"stages": 4, "num_micro": 2, "mb_global": 2,
                         "seq": 32, "remat": "none",
                         "param_dtype": "float32"},
        }
        with Session(RunSpec.from_dict(dict(BASE))) as s:
            rep_full = s.train()

        # the doomed run in ITS OWN process (inherits PYTHONPATH and the
        # forced-host XLA_FLAGS): chaos SIGKILLs it at step 9, two steps
        # after the step-7 safe point landed on disk
        doomed = dict(BASE, ckpt_dir=ck + "_killed",
                      faults={"enabled": True, "kill_at": 9})
        code = ("from repro.api import RunSpec, Session\\n"
                "with Session(RunSpec.from_dict(" + repr(doomed)
                + ")) as s:\\n"
                "    s.train()\\n"
                "raise SystemExit('unreachable: kill_at did not fire')")
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True, timeout=600)
        assert proc.returncode == -9, (proc.returncode, proc.stderr[-2000:])

        with Session.resume(ck + "_killed") as s:
            rep_res = s.train()
        assert rep_res["start_step"] == 8            # newest safe point: 7
        tail = rep_full["losses"][8:]
        assert rep_res["losses"] == tail, (rep_res["losses"], tail)
        print("RESUME OK", rep_res["losses"][-1])
    """, devices=4)
    assert "RESUME OK" in out


@pytest.mark.slow
def test_chaos_serve_token_identity():
    """Crash a serving worker mid-flight: every in-flight request is
    requeued with its generated prefix carried, the evicted world shrinks,
    and the degraded run completes the EXACT same token set as the
    fault-free run — zero lost requests."""
    out = run_in_subprocess("""
        from repro.api import RunSpec, Session

        BASE = {
            "seed": 3,
            "model": {"arch": "smollm-360m", "layers": 8, "d_model": 64,
                      "num_heads": 4, "num_kv_heads": 2, "d_ff": 256,
                      "vocab_size": 512},
            "parallel": {"stages": 4, "num_micro": 2, "mb_global": 2,
                         "seq": 16, "remat": "none",
                         "param_dtype": "float32"},
            "serve": {"requests": 10, "prompt_len": 16, "gen": 12,
                      "min_prompt": 4, "burst_period": 6, "burst_len": 2,
                      "burst_rate": 3, "lull_rate": 1},
            "cluster": {"job_manager": "inproc", "autoscale": False,
                        "spares": 1},
        }
        with Session(RunSpec.from_dict(dict(BASE))) as s:
            rep_a = s.serve()
        tok_a = {c["rid"]: c["tokens"] for c in rep_a["completions"]}

        chaos = dict(BASE)
        chaos["faults"] = {"enabled": True, "seed": 7,
                           "worker_crash": {4: 2}}
        with Session(RunSpec.from_dict(chaos)) as s:
            rep_b = s.serve()
        tok_b = {c["rid"]: c["tokens"] for c in rep_b["completions"]}

        assert set(tok_b) == set(tok_a), "lost requests"
        bad = [rid for rid in tok_a if tok_a[rid] != tok_b[rid]]
        assert not bad, f"token mismatch on rids {bad}"
        assert rep_b["requeued_total"] > 0
        assert any(c["requeues"] > 0 for c in rep_b["completions"])
        assert any(r["kind"] == "evict" for r in rep_b["resizes"])
        print("SERVE CHAOS OK", rep_b["requeued_total"])
    """, devices=4)
    assert "SERVE CHAOS OK" in out
