"""DynMo controller tests: profile → decide → migrate loop (paper Fig. 2)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import DistConfig, get_config, reduced_config
from repro.core.balancer import imbalance, stage_loads
from repro.core.controller import ControllerConfig, DynMoController
from repro.core.profiler import LayerProfile, profile_from_stats
from repro.dynamics.config import DynamicsConfig
from repro.models import model as M


def _setup(stages=4, layers=8):
    cfg = reduced_config(get_config("smollm-360m"), num_layers=layers,
                         d_model=64, d_ff=128)
    dcfg = DistConfig(num_stages=stages, slot_slack=3, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig(kind="pruning")
    return cfg, dcfg, dyncfg


def test_controller_rebalances_on_imbalance():
    cfg, dcfg, dyncfg = _setup()
    ctrl = DynMoController(cfg, dcfg, dyncfg,
                           ControllerConfig(method="partition",
                                            rebalance_every=1))
    L = cfg.total_blocks()
    times = np.concatenate([np.full(L // 2, 0.1), np.full(L - L // 2, 1.0)])
    prof = LayerProfile(times, np.full(L, 1e6), np.zeros(dcfg.num_stages),
                        [None] * L)
    new_lps, ev = ctrl.decide(prof, iteration=1)
    assert ev.rebalanced
    assert ev.imbalance_after < ev.imbalance_before
    loads = stage_loads(times, new_lps)
    assert imbalance(loads) < 0.6


def test_controller_skips_when_balanced():
    cfg, dcfg, dyncfg = _setup()
    ctrl = DynMoController(cfg, dcfg, dyncfg,
                           ControllerConfig(method="diffusion",
                                            rebalance_every=1))
    L = cfg.total_blocks()
    prof = LayerProfile(np.ones(L), np.ones(L), np.zeros(4), [None] * L)
    new_lps, ev = ctrl.decide(prof, iteration=1)
    assert new_lps is None
    assert not ev.rebalanced


def test_controller_migration_preserves_loss():
    """Rebalance + migrate, then the reference loss must be unchanged —
    the paper's 'no impact on model accuracy' property."""
    cfg, dcfg, dyncfg = _setup()
    params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    rng = np.random.RandomState(0)
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)), jnp.int32)
    loss_before = M.reference_loss(cfg, dcfg, dyncfg, params, assignment,
                                   dyn, tok, tok)
    ctrl = DynMoController(cfg, dcfg, dyncfg,
                           ControllerConfig(method="partition",
                                            rebalance_every=1))
    L = cfg.total_blocks()
    times = np.concatenate([np.full(L - 2, 0.1), np.full(2, 2.0)])
    prof = LayerProfile(times, np.full(L, 1e6), np.zeros(4), [None] * L)
    new_lps, ev = ctrl.decide(prof, 1)
    assert new_lps is not None and new_lps != [2, 2, 2, 2]
    params2, _, dyn2, assignment2, _ = ctrl.apply(new_lps, params, None, dyn)
    loss_after = M.reference_loss(cfg, dcfg, dyncfg, params2, assignment2,
                                  dyn2, tok, tok)
    assert abs(float(loss_before) - float(loss_after)) < 1e-5


def test_profile_from_stats_folds_dynamism():
    cfg, dcfg, dyncfg = _setup()
    S, L_max = dcfg.num_stages, dcfg.slots_for(cfg)
    assignment = M.make_assignment(cfg, dcfg)
    tags = np.asarray(assignment["tags"])
    num_micro = 4
    stats = {
        "ff_active": np.where(tags != 0, num_micro * 0.5, 0.0),
        "attn_density": np.where(tags != 0, num_micro * 1.0, 0.0),
        "expert_load": np.zeros((S, L_max, 1)),
    }
    prof = profile_from_stats(cfg, stats, tags, num_micro, 1024, 64)
    assert len(prof.time_per_layer) == cfg.total_blocks()
    assert all(abs(ds.retained - 0.5) < 1e-6 for ds in prof.dyn_states)
    # halved FFN -> cheaper than full
    full = profile_from_stats(
        cfg, {**stats, "ff_active": np.where(tags != 0, num_micro, 0.0)},
        tags, num_micro, 1024, 64)
    assert prof.time_per_layer.sum() < full.time_per_layer.sum()


def test_straggler_triggers_ordinary_rebalance():
    """A persistently slow worker (1.5-2x) must read as load imbalance: the
    detector's relative slowdown folds into the time cost vector and the
    ordinary rebalance moves layers off the straggling stage."""
    from repro.runtime.fault_tolerance import StragglerDetector
    cfg, dcfg, dyncfg = _setup(layers=16)
    det = StragglerDetector(4, ema=0.5)
    ctrl = DynMoController(cfg, dcfg, dyncfg,
                           ControllerConfig(method="partition",
                                            rebalance_every=1),
                           straggler=det)
    L = cfg.total_blocks()
    prof = LayerProfile(np.ones(L), np.ones(L), np.zeros(4), [None] * L)
    # perfectly balanced layers, no straggler data yet: no rebalance
    new_lps, ev = ctrl.decide(prof, 1)
    assert new_lps is None and not ev.rebalanced
    base_lps = list(ctrl.lps)
    # stage 2's worker measures 2x slower than its modelled share (the
    # absolute scale is deliberately wrong by 7x — only relative skew
    # may matter)
    expected = np.asarray(stage_loads(np.ones(L), ctrl.lps))
    for _ in range(10):
        det.update(expected * np.array([1.0, 1.0, 2.0, 1.0]) * 7.0)
    new_lps, ev = ctrl.decide(prof, 2)
    assert ev.rebalanced and new_lps is not None
    assert ev.imbalance_after < ev.imbalance_before
    # the straggling stage sheds work under the straggler-adjusted costs
    slow = det.relative_slowdown(expected)
    adj = np.ones(L) * np.repeat(slow, base_lps)
    assert stage_loads(adj, new_lps)[2] < stage_loads(adj, base_lps)[2]


def test_straggler_detector_resets_on_rebind():
    from repro.runtime.fault_tolerance import StragglerDetector
    cfg, dcfg, dyncfg = _setup()
    det = StragglerDetector(4)
    ctrl = DynMoController(cfg, dcfg, dyncfg, ControllerConfig(),
                           straggler=det)
    det.update(np.ones(4))
    assert det.initialized
    import dataclasses as dc
    ctrl.rebind(dc.replace(dcfg, num_stages=2), [4, 4])
    assert not det.initialized and len(det.times) == 2


def test_controller_repack_path():
    cfg, dcfg, dyncfg = _setup(stages=4, layers=8)
    ctrl = DynMoController(
        cfg, dcfg, dyncfg,
        ControllerConfig(method="partition", rebalance_every=1, repack=True,
                         repack_mem_cap=1e9, repack_target=2))
    L = cfg.total_blocks()
    times = np.linspace(1.0, 2.0, L)
    prof = LayerProfile(times, np.full(L, 1e6), np.zeros(4), [None] * L)
    new_lps, ev = ctrl.decide(prof, 1)
    if new_lps is not None:
        assert ev.active_workers <= 4
