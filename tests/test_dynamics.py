"""Dynamism scheme tests: schedule math, trajectories, global block pruning
(Algorithm 1, TPU-adapted)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import DistConfig, get_config, reduced_config
from repro.dynamics.config import DynamicsConfig
from repro.dynamics.pruning import (block_magnitudes, global_block_prune,
                                    target_keep_blocks)
from repro.dynamics.trajectories import make_trajectory, zhu_gupta_sparsity
from repro.models import model as M
from repro.models.blocks import n_prune_blocks


def test_zhu_gupta_schedule():
    """Paper Eq. (3): cubic ramp from s_i to s_f between t0 and t1."""
    cfg = DynamicsConfig(prune_initial_sparsity=0.0,
                         prune_final_sparsity=0.9,
                         prune_start_iter=3000, prune_end_iter=7000)
    assert zhu_gupta_sparsity(0, cfg) == 0.0
    assert zhu_gupta_sparsity(2999, cfg) == 0.0
    assert zhu_gupta_sparsity(7000, cfg) == 0.9
    assert zhu_gupta_sparsity(10 ** 6, cfg) == 0.9
    mid = zhu_gupta_sparsity(5000, cfg)
    assert 0.0 < mid < 0.9
    # monotone non-decreasing
    vals = [zhu_gupta_sparsity(k, cfg) for k in range(3000, 7001, 100)]
    assert all(b >= a - 1e-12 for a, b in zip(vals, vals[1:]))
    # fast early, slow late (cubic): first quarter prunes more than last
    q1 = zhu_gupta_sparsity(4000, cfg) - zhu_gupta_sparsity(3000, cfg)
    q4 = zhu_gupta_sparsity(7000, cfg) - zhu_gupta_sparsity(6000, cfg)
    assert q1 > q4


@pytest.mark.parametrize("kind", ["pruning", "freezing", "sparse_attention",
                                  "early_exit", "moe", "mod"])
def test_trajectories_bounds(kind):
    mc = get_config("gpt-paper-32l")
    cfg = DynamicsConfig(kind=kind)
    traj = make_trajectory(kind, mc, cfg, total_iters=10000)
    for k in (0, 1000, 5000, 9999):
        states = traj(k)
        assert len(states) == mc.total_blocks()
        for ds in states:
            assert 0.0 < ds.retained <= 1.0
            assert 0.0 < ds.attn_density <= 1.0
            assert 0.0 < ds.token_frac <= 1.0
            assert 1.0 <= ds.expert_hot <= 4.0


def test_trajectory_creates_imbalance():
    """The whole point: dynamism must skew per-layer costs."""
    from repro.core.cost_model import cost_vector
    mc = get_config("gpt-paper-40l")
    cfg = DynamicsConfig(kind="early_exit")
    traj = make_trajectory("early_exit", mc, cfg)
    t = cost_vector(mc, 2048, 2048, traj(5000), by="time")
    assert t.max() / t.min() > 2.0


def test_global_block_prune_exact_topk():
    """Distributed block pruning == numpy global top-k oracle."""
    cfg = reduced_config(get_config("smollm-360m"), num_layers=6,
                         d_model=64, d_ff=256)
    dcfg = DistConfig(num_stages=3, slot_slack=1, param_dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    npb = n_prune_blocks(cfg)
    keep = 7
    mask = np.asarray(global_block_prune(cfg, params["stages"],
                                         assignment["tags"], keep))
    mag = np.array(block_magnitudes(cfg, params["stages"]))
    tags = np.asarray(assignment["tags"])
    mag[tags == 0] = -np.inf
    flat = mag.reshape(-1)
    thresh = np.sort(flat)[::-1][keep - 1]
    want = ((mag >= thresh) & np.isfinite(mag)).astype(np.float32)
    assert (mask == want).all()
    assert int(mask.sum()) == keep
    # pad slots always masked out
    assert (mask[tags == 0] == 0).all()


def test_target_keep_blocks():
    cfg = get_config("smollm-360m")
    L = cfg.total_blocks()
    npb = n_prune_blocks(cfg)
    assert target_keep_blocks(cfg, L, 0.0) == L * npb
    assert target_keep_blocks(cfg, L, 0.9) == max(
        L, int(round(L * npb * 0.1)))
