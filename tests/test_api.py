"""The RunSpec/Session front door: serialization round-trips, strict
validation, golden schema fixture, CLI precedence, legacy-kwarg shims, and
the config-path == legacy-path bit-identity acceptance criterion."""
import argparse
import glob
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

import numpy as np

from conftest import REPO, run_in_subprocess
from repro.api import (SCENARIOS, ClusterSpec, ControllerSpec, DynamicsSpec,
                       ModelSpec, ParallelSpec, RepackSpec, RunSpec,
                       ServeSpec, SpecError, scenario)
from repro.api.cli import (SERVE_ALIASES, TRAIN_ALIASES, TRAIN_CLI_DEFAULTS,
                           add_alias_flags, add_config_args, add_spec_flags,
                           build_spec)
from repro.api.specs import SCHEMA_VERSION

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN = os.path.join(GOLDEN_DIR, "runspec_default_v5.json")
GOLDEN_V1 = os.path.join(GOLDEN_DIR, "runspec_default_v1.json")
GOLDEN_V2 = os.path.join(GOLDEN_DIR, "runspec_default_v2.json")
GOLDEN_V3 = os.path.join(GOLDEN_DIR, "runspec_default_v3.json")
GOLDEN_V4 = os.path.join(GOLDEN_DIR, "runspec_default_v4.json")


# ---------------------------------------------------------------------------
# round-trips
# ---------------------------------------------------------------------------
def test_default_round_trip():
    spec = RunSpec()
    assert RunSpec.from_json(spec.to_json()) == spec


def test_scenario_round_trips():
    for name, spec in SCENARIOS.items():
        assert RunSpec.from_json(spec.to_json()) == spec, name


def test_populated_round_trip():
    """A spec touching every sub-spec with non-default values survives the
    JSON round trip exactly (including the int-keyed straggler map)."""
    spec = RunSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=4, d_model=96,
                        num_heads=8, num_kv_heads=4, d_ff=512,
                        vocab_size=1024),
        parallel=ParallelSpec(stages=8, num_micro=8, mb_global=2, seq=128,
                              slot_slack=1, remat="full",
                              param_dtype="bfloat16", kernel_impl="pallas"),
        dynamics=DynamicsSpec(kind="sparse_attention", sparse_block=16,
                              sparse_nbuckets=4),
        controller=ControllerSpec(
            balancer="partition", rebalance_every=3,
            repack=RepackSpec(enabled=True, policy="first_fit",
                              mem_cap=1.5, target=2),
            async_decide=True, async_drain=True,
            straggler={2: 1.5, 3: 1.25}, measure_stage_times=True),
        cluster=ClusterSpec(job_manager="file", job_manager_dir="/tmp/jm",
                            autoscale=True, autoscale_watermark=True,
                            heartbeat_timeout=5.0, simulate_recover=12),
        serve=ServeSpec(requests=32, prompt_len=16, gen=12, min_prompt=4,
                        burst_period=20, burst_len=5, burst_rate=6,
                        lull_rate=0, early_exit_frac=0.5, defrag_every=4,
                        min_stages=2, queue_high=3, occupancy_low=0.5,
                        patience=1, cooldown=2, latency_slo_s=0.25,
                        max_ticks=500),
        steps=64, seed=7, log_every=4, ckpt_dir="/tmp/ck")
    rt = RunSpec.from_json(spec.to_json())
    assert rt == spec
    assert rt.controller.straggler == {2: 1.5, 3: 1.25}   # int keys back


_MUTATIONS = [
    ("model.layers", [None, 2, 8, 16]),
    ("model.d_model", [32, 64, 256]),
    ("parallel.stages", [2, 4, 8, 16]),
    ("parallel.kernel_impl", ["reference", "scan", "pallas"]),
    ("parallel.param_dtype", ["float32", "bfloat16"]),
    ("dynamics.kind", ["none", "pruning", "freezing", "sparse_attention",
                       "early_exit", "mod", "moe"]),
    ("dynamics.prune_final_sparsity", [0.5, 0.9, 1.0]),
    ("controller.balancer", ["diffusion", "partition"]),
    ("controller.rebalance_every", [1, 5, 100]),
    ("controller.repack.policy", ["adjacent", "first_fit"]),
    ("controller.repack.mem_cap", [0.5, 1.1, 2.0]),
    ("cluster.job_manager", ["inproc", "file"]),
    ("cluster.heartbeat_timeout", [0.5, 3.0, 10.0]),
    ("serve.gen", [1, 8, 64]),
    ("serve.occupancy_low", [0.0, 0.35, 1.0]),
    ("steps", [1, 50, 1000]),
    ("seed", [0, 1, 123]),
]


@settings(max_examples=40)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_property_round_trip(seed):
    """Property-style: random dotted-override combinations round-trip
    through JSON to an equal spec."""
    rng = np.random.RandomState(seed)
    n = int(rng.randint(1, 8))
    idx = rng.choice(len(_MUTATIONS), size=n, replace=False)
    overrides = {}
    for i in idx:
        path, values = _MUTATIONS[int(i)]
        overrides[path] = values[int(rng.randint(len(values)))]
    try:
        spec = RunSpec().override(overrides)
    except SpecError:
        return           # the random combo violated a cross-field rule
    rt = RunSpec.from_json(spec.to_json())
    assert rt == spec, overrides
    for path, v in overrides.items():
        assert rt.get(path) == v, (path, overrides)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def test_unknown_key_is_an_error_with_path():
    with pytest.raises(SpecError) as e:
        RunSpec.from_dict({"controller": {"repack": {"polcy": "x"}}})
    msg = str(e.value)
    assert "spec.controller.repack" in msg and "polcy" in msg
    assert "policy" in msg            # the known keys are listed

    with pytest.raises(SpecError) as e:
        RunSpec.from_dict({"paralel": {}})
    assert "paralel" in str(e.value)


def test_choice_and_range_validation():
    with pytest.raises(SpecError, match="parallel.kernel_impl"):
        ParallelSpec(kernel_impl="cuda")
    with pytest.raises(SpecError, match="dynamics.kind"):
        DynamicsSpec(kind="quantization")
    with pytest.raises(SpecError, match="controller.balancer"):
        ControllerSpec(balancer="greedy")
    with pytest.raises(SpecError, match="parallel.stages"):
        ParallelSpec(stages=0)
    with pytest.raises(SpecError, match="serve.occupancy_low"):
        ServeSpec(occupancy_low=1.5)
    with pytest.raises(SpecError, match="cluster.job_manager"):
        ClusterSpec(job_manager="k8s")


def test_cross_field_validation_messages():
    # repack target must leave room to consolidate
    with pytest.raises(SpecError, match=r"controller\.repack\.target.*"
                                        r"parallel\.stages"):
        RunSpec(parallel=ParallelSpec(stages=2),
                controller=ControllerSpec(
                    repack=RepackSpec(enabled=True, target=2)))
    # ...but the same target is fine with repack disabled
    RunSpec(parallel=ParallelSpec(stages=2),
            controller=ControllerSpec(repack=RepackSpec(target=2)))
    with pytest.raises(SpecError, match=r"serve\.min_stages"):
        RunSpec(parallel=ParallelSpec(stages=2),
                serve=ServeSpec(min_stages=3))
    with pytest.raises(SpecError, match=r"simulate_recover.*autoscale"):
        RunSpec(cluster=ClusterSpec(simulate_recover=5))
    with pytest.raises(SpecError, match=r"straggler.*out of range"):
        RunSpec(parallel=ParallelSpec(stages=2),
                controller=ControllerSpec(straggler={5: 1.5}))


def test_schema_version_gate():
    d = RunSpec().to_dict()
    d["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(SpecError, match="schema"):
        RunSpec.from_dict(d)


def test_override_coercion_errors():
    with pytest.raises(SpecError, match="not a spec field"):
        RunSpec().override({"parallel.stage": 4})
    with pytest.raises(SpecError, match="expected an int"):
        RunSpec().override({"parallel.stages": "four"})
    with pytest.raises(SpecError, match="expected a bool"):
        RunSpec().override({"cluster.autoscale": "maybe"})
    # Optionals parse "none"
    assert RunSpec().override({"model.layers": "none"}).model.layers is None
    # straggler parses the CLI mini-grammar
    s = RunSpec().override({"controller.straggler": "1:1.5,2:2.0"})
    assert s.controller.straggler == {1: 1.5, 2: 2.0}


# ---------------------------------------------------------------------------
# golden schema fixture: changing the schema is a deliberate act
# ---------------------------------------------------------------------------
def test_golden_default_spec():
    """The serialized default RunSpec is pinned byte-for-byte.  If this
    fails you changed the spec schema: bump SCHEMA_VERSION if the change
    is breaking, add an upgrader for the old version, then regenerate the
    fixture with ``PYTHONPATH=src python -c "from repro.api import RunSpec;
    RunSpec().save('tests/golden/runspec_default_v5.json')"`` (keep the
    old-version goldens — they pin the upgraders' inputs forever)."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert RunSpec().to_dict() == golden
    assert RunSpec.from_dict(golden) == RunSpec()


def test_v1_config_loads_via_upgrader():
    """A v1 config (the frozen v1 golden) still loads: the v1->v2 upgrader
    stamps defaults for the fields v2 added (faults, ckpt_every, spares,
    watermark_clock, rpc_timeout_s) and the result round-trips as v2."""
    with open(GOLDEN_V1) as f:
        v1 = json.load(f)
    assert v1["schema_version"] == 1
    spec = RunSpec.from_dict(v1)
    assert spec == RunSpec()
    assert spec.to_dict()["schema_version"] == SCHEMA_VERSION
    # a populated v1 config keeps its values through the upgrade
    v1b = dict(v1, steps=7, cluster=dict(v1["cluster"], autoscale=True))
    up = RunSpec.from_dict(v1b)
    assert up.steps == 7 and up.cluster.autoscale
    assert up.faults.enabled is False and up.ckpt_every == 0


def test_v2_config_loads_via_upgrader():
    """A v2 config (the frozen v2 golden) still loads: the v2->v3 upgrader
    stamps the multi-tenant cluster defaults (tenant_id, priority,
    manager_url) and the result equals the default v3 spec."""
    with open(GOLDEN_V2) as f:
        v2 = json.load(f)
    assert v2["schema_version"] == 2
    assert "tenant_id" not in v2["cluster"]
    spec = RunSpec.from_dict(v2)
    assert spec == RunSpec()
    assert spec.cluster.tenant_id is None and spec.cluster.priority == 0
    # a populated v2 config keeps its values through the upgrade
    v2b = dict(v2, seed=5,
               cluster=dict(v2["cluster"], job_manager="file"))
    up = RunSpec.from_dict(v2b)
    assert up.seed == 5 and up.cluster.job_manager == "file"
    assert up.to_dict()["schema_version"] == SCHEMA_VERSION


def test_v3_config_loads_via_upgrader():
    """A v3 config (the frozen v3 golden) still loads: the v3->v4 upgrader
    stamps the observability defaults (obs.trace/trace_out/metrics_port/
    metrics_out/in_step_timing) and the result equals the default v4
    spec."""
    with open(GOLDEN_V3) as f:
        v3 = json.load(f)
    assert v3["schema_version"] == 3
    assert "obs" not in v3
    spec = RunSpec.from_dict(v3)
    assert spec == RunSpec()
    assert spec.obs.trace is False and spec.obs.in_step_timing is False
    assert spec.obs.metrics_port is None
    # a populated v3 config keeps its values through the upgrade
    v3b = dict(v3, steps=11,
               controller=dict(v3["controller"], rebalance_every=2))
    up = RunSpec.from_dict(v3b)
    assert up.steps == 11 and up.controller.rebalance_every == 2
    assert up.to_dict()["schema_version"] == SCHEMA_VERSION
    # the new flags resolve through the dotted-override grammar
    on = RunSpec.from_dict(v3b).override({"obs.trace": "true",
                                         "obs.in_step_timing": "true",
                                         "obs.metrics_port": "9109"})
    assert on.obs.trace and on.obs.in_step_timing
    assert on.obs.metrics_port == 9109


def test_v4_config_loads_via_upgrader():
    """A v4 config (the frozen v4 golden) still loads: the v4->v5 upgrader
    stamps the paged-KV serving defaults (serve.kv_page_size/kv_pool_pages/
    prefix_cache/temperature) and the result equals the default v5 spec —
    a v4 run stays dense + argmax, i.e. bit-exact."""
    with open(GOLDEN_V4) as f:
        v4 = json.load(f)
    assert v4["schema_version"] == 4
    assert "kv_page_size" not in v4["serve"]
    spec = RunSpec.from_dict(v4)
    assert spec == RunSpec()
    assert spec.serve.kv_page_size == 0 and not spec.serve.prefix_cache
    assert spec.serve.temperature == 0.0
    # a populated v4 config keeps its values through the upgrade
    v4b = dict(v4, steps=9, serve=dict(v4["serve"], gen=16))
    up = RunSpec.from_dict(v4b)
    assert up.steps == 9 and up.serve.gen == 16
    assert up.to_dict()["schema_version"] == SCHEMA_VERSION
    # the new flags resolve through the dotted-override grammar
    on = RunSpec.from_dict(v4b).override({"serve.kv_page_size": "8",
                                          "serve.prefix_cache": "true",
                                          "serve.temperature": "0.7"})
    assert on.serve.kv_page_size == 8 and on.serve.prefix_cache
    assert on.serve.temperature == 0.7


def test_paged_serve_spec_validation():
    """Paged-KV cross-field constraints fail at construction with the
    dotted path in the message."""
    base = RunSpec()
    # page size must tile the cache line (prompt_len + gen = 40 default)
    with pytest.raises(SpecError, match="serve.kv_page_size"):
        base.override({"serve.kv_page_size": 7})
    ok = base.override({"serve.kv_page_size": 8})
    assert ok.serve.kv_page_size == 8
    # prefix cache / pool sizing require the paged subsystem
    with pytest.raises(SpecError, match="serve.prefix_cache"):
        base.override({"serve.prefix_cache": True})
    with pytest.raises(SpecError, match="serve.kv_pool_pages"):
        base.override({"serve.kv_pool_pages": 64})
    with pytest.raises(SpecError, match="serve.temperature"):
        base.override({"serve.temperature": -0.5})


def test_chaos_flags_resolve_faults_spec():
    """--chaos/--chaos-seed/--ckpt-every land on the spec's fault and
    safe-point fields through the shared alias table."""
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    add_alias_flags(ap, TRAIN_ALIASES)
    add_spec_flags(ap)
    args = ap.parse_args(["--chaos", "--chaos-seed", "9", "--spares", "2",
                          "--ckpt-every", "5", "--ckpt-dir", "/tmp/ck",
                          "--autoscale", "--job-manager", "file",
                          "--set", "faults.worker_crash=2:1",
                          "--set", "faults.rpc_loss=0.25"])
    spec = build_spec(args, TRAIN_ALIASES)
    assert spec.faults.enabled and spec.faults.seed == 9
    assert spec.faults.worker_crash == {2: 1}
    assert spec.faults.rpc_loss == 0.25
    assert spec.cluster.spares == 2
    assert spec.ckpt_every == 5 and spec.ckpt_dir == "/tmp/ck"


def test_all_repo_configs_validate():
    """Every JSON under configs/ parses strictly; scenario files equal
    their registry presets (the config-check CI step runs the same)."""
    paths = sorted(glob.glob(os.path.join(REPO, "configs", "**", "*.json"),
                             recursive=True))
    assert paths, "no configs found"
    seen = set()
    for path in paths:
        spec = RunSpec.load(path)
        name = os.path.splitext(os.path.basename(path))[0]
        if os.path.basename(os.path.dirname(path)) == "scenarios":
            assert spec == SCENARIOS[name], (
                f"{path} drifted from the preset; run "
                f"scripts/gen_scenarios.py")
            seen.add(name)
    assert seen == set(SCENARIOS), f"missing scenario configs: " \
                                   f"{set(SCENARIOS) - seen}"


# ---------------------------------------------------------------------------
# CLI resolution (no jax, no devices: pure spec plumbing)
# ---------------------------------------------------------------------------
def _train_parser():
    import argparse
    ap = argparse.ArgumentParser()
    add_config_args(ap)
    add_alias_flags(ap, TRAIN_ALIASES)
    add_spec_flags(ap)
    return ap


def test_cli_precedence(tmp_path):
    cfg_path = tmp_path / "run.json"
    scenario("early_exit").save(str(cfg_path))
    ap = _train_parser()

    # config file is the source of truth (no historical CLI defaults)
    args = ap.parse_args(["--config", str(cfg_path)])
    spec = build_spec(args, TRAIN_ALIASES, cli_defaults=TRAIN_CLI_DEFAULTS)
    assert spec == scenario("early_exit")

    # explicit alias flags override the file
    args = ap.parse_args(["--config", str(cfg_path), "--stages", "2",
                          "--dynamism", "mod"])
    spec = build_spec(args, TRAIN_ALIASES, cli_defaults=TRAIN_CLI_DEFAULTS)
    assert spec.parallel.stages == 2 and spec.dynamics.kind == "mod"

    # --set beats everything, dotted flags work, types coerce
    args = ap.parse_args(["--config", str(cfg_path), "--stages", "2",
                          "--controller.repack.enabled", "true",
                          "--set", "parallel.stages=8",
                          "--set", "controller.repack.policy=first_fit"])
    spec = build_spec(args, TRAIN_ALIASES, cli_defaults=TRAIN_CLI_DEFAULTS)
    assert spec.parallel.stages == 8
    assert spec.controller.repack.enabled is True
    assert spec.controller.repack.policy == "first_fit"

    # without --config the historical train CLI defaults apply
    args = ap.parse_args([])
    spec = build_spec(args, TRAIN_ALIASES, cli_defaults=TRAIN_CLI_DEFAULTS)
    assert spec.model.layers == 8        # the old argparse default
    args = ap.parse_args(["--layers", "4"])
    spec = build_spec(args, TRAIN_ALIASES, cli_defaults=TRAIN_CLI_DEFAULTS)
    assert spec.model.layers == 4


def test_train_and_serve_clis_share_common_surface():
    """The drift class this PR retires: every shared alias resolves to the
    SAME spec path in both CLIs (--dynamism, --kernel-impl,
    --measure-stage-times, --job-manager, --seed, ...)."""
    train = {a.opt: a.path for a in TRAIN_ALIASES}
    serve = {a.opt: a.path for a in SERVE_ALIASES}
    for opt in ("--arch", "--layers", "--d-model", "--stages",
                "--mb-global", "--dynamism", "--kernel-impl",
                "--measure-stage-times", "--job-manager",
                "--job-manager-dir", "--seed", "--log-every"):
        assert opt in train and opt in serve, opt
        assert train[opt] == serve[opt], opt


# ---------------------------------------------------------------------------
# legacy kwarg shims
# ---------------------------------------------------------------------------
def test_train_spec_kwarg_mapping():
    from repro.launch.train import train_spec
    spec = train_spec("smollm-360m", steps=30, stages=4, layers=8,
                      d_model=128, seq=32, num_micro=4, mb_global=2,
                      dynamism="pruning", kernel_impl="pallas",
                      dyn_overrides=dict(sparse_block=16),
                      repack=True, repack_policy="first_fit",
                      repack_mem_cap=1.5, repack_target=2,
                      async_controller=True, autoscale=True,
                      simulate_recover=18, job_manager="file",
                      straggler={2: 1.5}, measure_stage_times=True)
    assert spec.model.arch == "smollm-360m" and spec.model.layers == 8
    assert spec.parallel.kernel_impl == "pallas"
    assert spec.dynamics.kind == "pruning"
    assert spec.dynamics.sparse_block == 16
    assert spec.controller.repack == RepackSpec(
        enabled=True, policy="first_fit", mem_cap=1.5, target=2)
    assert spec.controller.async_decide and spec.cluster.autoscale
    assert spec.cluster.simulate_recover == 18
    assert spec.cluster.job_manager == "file"
    assert spec.controller.straggler == {2: 1.5}
    assert spec.controller.measure_stage_times


def test_serve_spec_kwarg_mapping():
    from repro.launch.serve import serve_spec
    spec = serve_spec("smollm-360m", stages=4, micro=2, mb_global=2,
                      prompt_len=8, gen=10, layers=8, d_model=64,
                      requests=30, burst_period=25, burst_len=3,
                      burst_rate=6, lull_rate=0, early_exit_frac=0.5,
                      autoscale=True, min_stages=2, queue_high=2,
                      occupancy_low=0.6, patience=2, cooldown=3,
                      defrag_every=4, job_manager="file",
                      kernel_impl="reference", measure_stage_times=True)
    assert spec.parallel.num_micro == 2 and spec.parallel.stages == 4
    assert spec.parallel.kernel_impl == "reference"
    assert spec.serve.prompt_len == 8 and spec.serve.gen == 10
    assert spec.serve.min_stages == 2 and spec.serve.queue_high == 2
    assert spec.cluster.autoscale and spec.cluster.job_manager == "file"
    assert spec.controller.measure_stage_times


def test_grow_back_is_deprecated():
    import warnings as W

    from repro.launch.train import train_spec
    # the deprecation fires at Session.train() time (see the slow elastic
    # tests, which still exercise the shimmed path); building the spec
    # alone is silent
    with W.catch_warnings(record=True) as rec:
        W.simplefilter("always")
        spec = train_spec("smollm-360m", grow_back=6)
    assert spec.cluster.grow_back == 6
    assert not rec


# ---------------------------------------------------------------------------
# acceptance: config path == legacy kwarg path, bit-identical (subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_config_path_matches_legacy_path_bit_identical():
    """`--config configs/scenarios/early_exit.json`, the Session API, and
    the legacy run_training kwargs produce identical losses (the ISSUE 5
    acceptance criterion)."""
    out = run_in_subprocess("""
import os
from repro.api import RunSpec, Session
from repro.launch.train import run_training

path = os.path.join(%(repo)r, "configs", "scenarios", "early_exit.json")
spec = RunSpec.load(path)

with Session(spec) as s:
    via_config = s.train()
assert any(ev.kind == "log" for ev in s.events)
assert s.events[-1].kind == "train_summary"

via_legacy = run_training(
    "smollm-360m", steps=16, stages=4, layers=8, d_model=64, seq=32,
    num_micro=2, mb_global=2, dynamism="early_exit", rebalance_every=5,
    log_every=5)

assert via_config["losses"] == via_legacy["losses"], (
    via_config["losses"], via_legacy["losses"])
assert via_config["final_lps"] == via_legacy["final_lps"]
assert via_config["stages_history"] == via_legacy["stages_history"]
assert via_legacy["spec"] == spec.to_dict()   # the shim built THIS spec
print("PASS", via_config["losses"][0], "->", via_config["losses"][-1])
""" % {"repo": REPO}, devices=4, timeout=900)
    assert "PASS" in out


@pytest.mark.slow
def test_serve_session_matches_legacy_shim():
    """Session.serve on a serve_spec produces the same tokens as the
    legacy run_elastic_serving kwargs (and the serve CLI drift fixes —
    kernel_impl + measured stage times — reach the server)."""
    out = run_in_subprocess("""
from repro.api import Session
from repro.launch.serve import run_elastic_serving, serve_spec

kw = dict(stages=4, micro=2, mb_global=2, prompt_len=8, gen=6, layers=4,
          d_model=64, requests=8, seed=0, measure_stage_times=True)
spec = serve_spec("smollm-360m", **kw)
with Session(spec) as s:
    a = s.serve()
b = run_elastic_serving("smollm-360m", **kw)
ta = [(c["rid"], c["tokens"]) for c in a["completions"]]
tb = [(c["rid"], c["tokens"]) for c in b["completions"]]
assert ta == tb
mt = a["measured_stage_times"]
assert mt is not None and len(mt) == 4 and all(t > 0 for t in mt)
assert a["spec"] == spec.to_dict()
print("PASS", len(ta))
""", devices=4, timeout=900)
    assert "PASS" in out
