"""Data pipeline tests: tokenizer round-trips, loader shapes/determinism."""
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.loader import DataConfig, make_loader
from repro.data.synthetic import synthetic_corpus, zipf_token_stream
from repro.data.tokenizer import ByteTokenizer


def test_tokenizer_roundtrip():
    tok = ByteTokenizer.train([synthetic_corpus()], num_merges=64)
    for text in ("hello world", "dynmo rebalances layers",
                 "unicode: héllo wörld ☃"):
        ids = tok.encode(text, bos=True, eos=True)
        assert tok.decode(ids) == text
    assert tok.vocab_size > 259


def test_tokenizer_merges_compress():
    tok = ByteTokenizer.train([synthetic_corpus()], num_merges=128)
    raw = len(synthetic_corpus().encode())
    enc = len(tok.encode(synthetic_corpus(), bos=False))
    assert enc < raw * 0.8


def test_zipf_stream_structure():
    vs = 1000
    s = next(zipf_token_stream(vs, seed=0))
    assert s.min() >= 0 and s.max() < vs
    # Zipf marginal: low ids much more frequent (the bigram successor mix
    # spreads some mass to high ids by design — learnable structure)
    lo = np.mean(s < 10)
    hi = np.mean(s >= 900)
    assert lo > 2 * max(hi, 1e-6)


def test_loader_shapes_and_determinism():
    cfg = reduced_config(get_config("smollm-360m"))
    dc = DataConfig(num_micro=2, mb_global=4, seq=16, seed=3)
    b1 = next(make_loader(cfg, dc))
    b2 = next(make_loader(cfg, dc))
    assert b1["tokens"].shape == (2, 4, 16)
    assert (b1["tokens"] == b2["tokens"]).all()
    # labels are next-token shifted
    assert (b1["labels"][..., :-1] == b1["tokens"][..., 1:]).all()


def test_loader_resume():
    cfg = reduced_config(get_config("smollm-360m"))
    dc = DataConfig(num_micro=1, mb_global=2, seq=8, seed=5)
    it = make_loader(cfg, dc)
    batches = [next(it) for _ in range(4)]
    resumed = next(make_loader(cfg, dc, start_step=3))
    assert (batches[3]["tokens"] == resumed["tokens"]).all()


def test_vlm_and_encdec_inputs():
    vlm = reduced_config(get_config("internvl2-26b"))
    b = next(make_loader(vlm, DataConfig(1, 2, 8)))
    assert b["prefix_emb"].shape == (1, 2, vlm.num_patches, vlm.d_model)
    wsp = reduced_config(get_config("whisper-large-v3"))
    b = next(make_loader(wsp, DataConfig(1, 2, 8)))
    assert b["frames"].shape == (1, 2, wsp.encoder_seq, wsp.d_model)
