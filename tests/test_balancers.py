"""Balancer unit + property tests (paper §3.3, Lemmas 1 & 2)."""
import itertools

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # dep gated: fixed-seed sweep instead of shrinking
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.balancer import (balance, diffusion_balance, imbalance,
                                 partition_balance, stage_loads)

costs_strategy = st.lists(
    st.floats(min_value=0.01, max_value=100.0, allow_nan=False,
              allow_infinity=False),
    min_size=4, max_size=64)


def brute_force_bottleneck(costs, S):
    """Optimal contiguous-partition bottleneck by exhaustive search."""
    L = len(costs)
    best = float("inf")
    for cuts in itertools.combinations(range(1, L), S - 1):
        bounds = (0,) + cuts + (L,)
        bott = max(sum(costs[a:b]) for a, b in zip(bounds, bounds[1:]))
        best = min(best, bott)
    return best


@pytest.mark.parametrize("S", [2, 3, 4])
def test_partition_matches_bruteforce(S):
    rng = np.random.RandomState(0)
    for trial in range(20):
        L = rng.randint(S, 10)
        costs = rng.rand(L) + 0.05
        res = partition_balance(costs, S)
        want = brute_force_bottleneck(list(costs), min(S, L))
        assert res.bottleneck <= want + 1e-6, (trial, res, want)


@settings(max_examples=60, deadline=None)
@given(costs=costs_strategy, S=st.integers(2, 8))
def test_partition_properties(costs, S):
    res = partition_balance(costs, S)
    # covers all layers, non-negative
    assert sum(res.layers_per_stage) == len(costs)
    assert all(n >= 0 for n in res.layers_per_stage)
    # bottleneck consistent with its own split
    loads = stage_loads(costs, res.layers_per_stage)
    assert abs(loads.max() - res.bottleneck) < 1e-6
    # never worse than Megatron-uniform
    uni = balance("uniform", costs, S)
    assert res.bottleneck <= uni.bottleneck + 1e-9
    # bottleneck can never beat the trivial lower bounds
    assert res.bottleneck >= max(costs) - 1e-9
    assert res.bottleneck >= sum(costs) / S - 1e-6


@settings(max_examples=60, deadline=None)
@given(costs=costs_strategy, S=st.integers(2, 8))
def test_diffusion_properties(costs, S):
    res = diffusion_balance(costs, S)
    assert sum(res.layers_per_stage) == len(costs)
    uni = balance("uniform", costs, S)
    # diffusion never increases the bottleneck vs its uniform init
    assert res.bottleneck <= uni.bottleneck + 1e-9
    # Lemma 2: converges within the round bound (returned rounds are the
    # actual count; bound enforced internally)
    assert res.rounds < 10001


@settings(max_examples=30, deadline=None)
@given(costs=costs_strategy, S=st.integers(2, 6))
def test_diffusion_close_to_partition(costs, S):
    """Diffusion converges to within one max-layer-cost of the centralized
    optimum (single-layer moves can't split a layer)."""
    p = partition_balance(costs, S)
    d = diffusion_balance(costs, S)
    assert d.bottleneck <= p.bottleneck + max(costs) + 1e-6


def test_capacity_constraint_respected():
    costs = np.ones(16)
    res = partition_balance(costs, 4, max_slots=5)
    assert max(res.layers_per_stage) <= 5
    res = diffusion_balance(costs, 4, max_slots=5)
    assert max(res.layers_per_stage) <= 5


def test_memory_constraint_respected():
    costs = np.ones(12)
    mem = np.ones(12)
    res = partition_balance(costs, 4, mem=mem, mem_cap=4.0)
    loads = stage_loads(mem, res.layers_per_stage)
    assert loads.max() <= 4.0 + 1e-9


def test_imbalance_definition():
    # Eq. (2): (max-min)/mean
    assert imbalance([1.0, 1.0, 1.0]) == 0.0
    assert abs(imbalance([2.0, 1.0, 3.0]) - (2.0 / 2.0)) < 1e-9


def test_skewed_workload_rebalance():
    """The paper's core scenario: early layers frozen (cheap) -> uniform
    split leaves a big tail bottleneck; both balancers fix it."""
    costs = np.array([0.1] * 16 + [1.0] * 16)
    uni = balance("uniform", costs, 4)
    p = partition_balance(costs, 4)
    d = diffusion_balance(costs, 4)
    # integral-layer optimum here is 5.0 vs uniform 8.0 (0.625 ratio)
    assert p.bottleneck <= uni.bottleneck * 0.65
    assert d.bottleneck <= uni.bottleneck * 0.8
    assert p.imbalance < uni.imbalance
