"""Migration plan tests (paper §4.1 — layer moves preserve the model)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # dep gated: fixed-seed sweep instead of shrinking
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.migration import apply_plan, build_plan


def random_split(rng, L, S, L_max):
    cuts = sorted(rng.choice(range(L + 1), S - 1, replace=True))
    bounds = [0] + list(cuts) + [L]
    lps = [bounds[i + 1] - bounds[i] for i in range(S)]
    if max(lps) > L_max:
        return None
    return lps


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10 ** 6), L=st.integers(4, 40),
       S=st.integers(2, 8))
def test_plan_preserves_global_order(seed, L, S):
    rng = np.random.RandomState(seed)
    L_max = max(2, (L + S - 1) // S + 2)
    a = random_split(rng, L, S, L_max)
    b = random_split(rng, L, S, L_max)
    if a is None or b is None:
        return
    plan = build_plan(a, b, L_max)
    # payload: global layer ids laid out by split a
    payload = np.full((S, L_max), -1, np.int64)
    g = 0
    for s, n in enumerate(a):
        for l in range(n):
            payload[s, l] = g
            g += 1
    out = np.asarray(apply_plan(jnp.asarray(payload), plan))
    # destination layout must enumerate 0..L-1 in order under split b
    g = 0
    for s, n in enumerate(b):
        for l in range(n):
            assert out[s, l] == g, (out, a, b)
            g += 1
    # moved count consistency
    assert plan.moved_layers <= L


def test_identity_plan_moves_nothing():
    plan = build_plan([2, 2, 2], [2, 2, 2], 4)
    assert plan.moved_layers == 0


def test_capacity_guard():
    with pytest.raises(AssertionError):
        build_plan([2, 2, 2], [6, 0, 0], 4)


def test_apply_plan_zeroes_pads():
    plan = build_plan([3, 1], [1, 3], 4)
    x = jnp.arange(2 * 4 * 2).reshape(2, 4, 2).astype(jnp.float32)
    out = np.asarray(apply_plan(x, plan))
    assert (out[0, 1:] == 0).all()       # stage0 now has 1 layer
    assert (out[1, 3:] == 0).all()
