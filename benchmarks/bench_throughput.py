"""Figure 3: end-to-end training throughput for the six dynamic-model cases
across 6 balancers (2 static, 4 DynMo).  Speedup convention follows the
paper: best(DynMo param/time) / best(static Megatron-uniform, DeepSpeed-param)
— except sparse_attention and early_exit, whose paper baseline is the model
WITHOUT the dynamism (dense attention / no exits).

Paper headline bands: MoE 1.23×, pruning 3.18×, freezing 2.23×, sparse
attention 4.02×, early exit 4.52×, MoD 1.17×.  `--bubbles` also reports the
bubble-ratio reductions (MoE 25→8%, MoD 18→4%).
"""
from __future__ import annotations

from typing import Dict

from benchmarks.common import BALANCERS, CASE_ARCH, CASE_SETUP, sim_case

PAPER_SPEEDUPS = {
    "moe": 1.23, "pruning": 3.18, "freezing": 2.23,
    "sparse_attention": 4.02, "early_exit": 4.52, "mod": 1.17,
}
# static Megatron/DeepSpeed cannot exploit the dynamism: no CSR kernels for
# pruning, no backward-skip for freezing, dense attention, no early exits —
# exactly the paper's baselines (MoE/MoD dynamism is inherent to the model,
# so those baselines run it)
BASELINE_WITHOUT_DYNAMISM = {"sparse_attention", "early_exit", "pruning",
                             "freezing"}


def run(quick: bool = False) -> Dict:
    iters = 2000 if quick else 10000
    sample = 200 if quick else 100
    out: Dict = {}
    for kind, arch in CASE_ARCH.items():
        rows = {}
        for label, method, cost_by, rebalance in BALANCERS:
            dynamism_on = not (label in ("megatron-uniform",
                                         "deepspeed-param")
                               and kind in BASELINE_WITHOUT_DYNAMISM)
            r = sim_case(kind, arch, method, cost_by, rebalance,
                         dynamism_on=dynamism_on, sample_every=sample,
                         iters=iters)
            rows[label] = r
        static_best = max(rows["megatron-uniform"].throughput,
                          rows["deepspeed-param"].throughput)
        dynmo_best = max(rows[l].throughput for l in
                         ("partition:param", "partition:time",
                          "diffusion:param", "diffusion:time"))
        out[kind] = {
            "rows": {l: r.throughput for l, r in rows.items()},
            "speedup": dynmo_best / static_best,
            "steady_speedup": _steady_state_speedup(kind, arch, iters),
            "paper": PAPER_SPEEDUPS[kind],
            "overhead_frac": rows["diffusion:time"].overhead_frac,
            "bubble_static": rows["megatron-uniform"].avg_bubble,
            "bubble_dynmo": rows["diffusion:time"].avg_bubble,
        }
    return out


def _steady_state_speedup(kind: str, arch: str, iters: int) -> float:
    """Makespan ratio at developed dynamism (k = 0.9·iters): static baseline
    (without-dynamism convention where applicable) vs DynMo-balanced —
    the regime the paper's headline numbers describe."""
    import numpy as np
    from repro.configs import get_config
    from repro.core.balancer import balance, partition_balance
    from repro.core.cost_model import cost_vector
    from repro.core.simulator import (simulate_pipeline,
                                      stage_times_from_layers)
    from repro.dynamics.config import DynamicsConfig
    from repro.dynamics.trajectories import make_trajectory
    mc = get_config(arch)
    setup = CASE_SETUP[kind]
    S, seq = setup["stages"], setup.get("seq", 2048)
    m = 4 * S
    dyncfg = DynamicsConfig(kind=kind, prune_start_iter=int(0.3 * iters),
                            prune_end_iter=int(0.7 * iters),
                            prune_frequency=max(1, iters // 10))
    k = int(0.9 * iters)
    traj = make_trajectory(kind, mc, dyncfg, total_iters=iters)
    t_dyn = cost_vector(mc, 2 * seq, seq, traj(k), by="time")
    base_on = kind not in BASELINE_WITHOUT_DYNAMISM
    t_base = t_dyn if base_on else cost_vector(mc, 2 * seq, seq, None,
                                               by="time")
    L = mc.total_blocks()
    slots = max(2, (L + S - 1) // S + 4)
    lps_s = balance("uniform", t_base, S).layers_per_stage
    lps_d = partition_balance(t_dyn, S, max_slots=slots).layers_per_stage
    r_s = simulate_pipeline(*stage_times_from_layers(t_base / 3,
                                                     2 * t_base / 3, lps_s),
                            m)
    r_d = simulate_pipeline(*stage_times_from_layers(t_dyn / 3,
                                                     2 * t_dyn / 3, lps_d),
                            m)
    return r_s.makespan / r_d.makespan


def main(quick: bool = False):
    res = run(quick)
    print("name,us_per_call,derived")
    for kind, d in res.items():
        print(f"throughput_speedup_{kind},0,{d['speedup']:.3f}")
        print(f"throughput_steady_speedup_{kind},0,"
              f"{d['steady_speedup']:.3f}")
        print(f"throughput_paper_{kind},0,{d['paper']:.3f}")
        print(f"overhead_frac_{kind},0,{d['overhead_frac']:.4f}")
        print(f"bubble_static_{kind},0,{d['bubble_static']:.4f}")
        print(f"bubble_dynmo_{kind},0,{d['bubble_dynmo']:.4f}")
    return res


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
