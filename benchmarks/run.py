"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  python -m benchmarks.run [--quick] [--only idleness,throughput,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced iteration counts (CI mode)")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_idleness, bench_kernels, bench_overhead,
                            bench_repack, bench_roofline, bench_throughput)
    benches = {
        "idleness": bench_idleness.main,        # Fig. 1
        "throughput": bench_throughput.main,    # Fig. 3 (+ bubble ratios)
        "repack": bench_repack.main,            # Fig. 4 left
        "overhead": bench_overhead.main,        # Fig. 4 right
        "kernels": bench_kernels.main,          # §4.2.2 / §4.2.4
        "roofline": bench_roofline.main,        # EXPERIMENTS.md §Roofline
    }
    names = (args.only.split(",") if args.only else list(benches))
    for name in names:
        t0 = time.perf_counter()
        print(f"### bench:{name}")
        benches[name](quick=args.quick)
        print(f"### bench:{name} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
