"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV; benches that return their rows also
get a ``BENCH_<name>.json`` snapshot (perf-trajectory tracking).

  python -m benchmarks.run [--quick] [--only idleness,throughput,...]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced iteration counts (CI mode)")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_cluster, bench_elastic, bench_idleness,
                            bench_kernels, bench_moe, bench_overhead,
                            bench_repack, bench_roofline, bench_serve,
                            bench_throughput)
    benches = {
        "idleness": bench_idleness.main,        # Fig. 1
        "throughput": bench_throughput.main,    # Fig. 3 (+ bubble ratios)
        "repack": bench_repack.main,            # Fig. 4 left
        "overhead": bench_overhead.main,        # Fig. 4 right
        "controller": bench_overhead.main_controller,  # §3.3.1 async plane
        "obs": bench_overhead.main_obs,         # §15 observability overhead
        "kernels": bench_kernels.main,          # §4.2.2 / §4.2.4
        "moe": bench_moe.main,                  # expert-parallel grouped mm
        "roofline": bench_roofline.main,        # EXPERIMENTS.md §Roofline
        "elastic": bench_elastic.main,          # §3.4 live shrink (engine)
        "serve": bench_serve.main,              # elastic continuous batching
        "paged": bench_serve.main_paged,        # §16 paged KV vs dense lanes
        "cluster": bench_cluster.main,          # multi-tenant pool (§14)
    }
    names = (args.only.split(",") if args.only else list(benches))
    for name in names:
        t0 = time.perf_counter()
        print(f"### bench:{name}")
        rows = benches[name](quick=args.quick)
        # spec-registered benches return (rows, RunSpec-dict): the snapshot
        # records the exact spec that produced each number
        spec = None
        if isinstance(rows, tuple) and len(rows) == 2:
            rows, spec = rows
        # snapshot benches that return uniform (name, us, derived) rows
        if (isinstance(rows, list) and rows
                and all(isinstance(r, tuple) and len(r) == 3
                        and isinstance(r[0], str) for r in rows)):
            path = f"BENCH_{name}.json"
            entries = [{"name": n, "us_per_call": us, "derived": d}
                       for n, us, d in rows]
            with open(path, "w") as f:
                if spec is not None:
                    json.dump({"spec": spec, "rows": entries}, f, indent=1)
                else:
                    json.dump(entries, f, indent=1)
            print(f"### bench:{name} wrote {path}", file=sys.stderr)
        print(f"### bench:{name} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
