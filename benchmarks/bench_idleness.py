"""Figure 1: average GPU idleness (bubble ratio) under static assignment for
GPT models of varying depth × dynamism type."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.balancer import balance
from repro.core.cost_model import cost_vector
from repro.core.simulator import simulate_pipeline, stage_times_from_layers
from repro.dynamics.config import DynamicsConfig
from repro.dynamics.trajectories import make_trajectory

DEPTHS = [24, 32, 40, 48]
KINDS = ["moe", "pruning", "freezing", "sparse_attention", "early_exit",
         "mod"]


def run(quick: bool = False):
    rows = []
    S, m, seq = 8, 32, 2048
    for kind in KINDS:
        for depth in (DEPTHS[:2] if quick else DEPTHS):
            mc = get_config(f"gpt-paper-{depth}l")
            dyncfg = DynamicsConfig(kind=kind)
            traj = make_trajectory(kind, mc, dyncfg, total_iters=10000)
            # evaluate idleness at a representative late-dynamism moment
            states = traj(6000)
            t = cost_vector(mc, 2 * seq, seq, states, by="time")
            lps = balance("uniform", t, S).layers_per_stage
            r = simulate_pipeline(
                *stage_times_from_layers(t / 3, 2 * t / 3, lps), m)
            rows.append((kind, depth, r.bubble_ratio))
    return rows


def main(quick: bool = False):
    rows = run(quick)
    print("name,us_per_call,derived")
    for kind, depth, bubble in rows:
        print(f"idleness_{kind}_{depth}l,0,{bubble:.4f}")
    # sanity: paper reports 18%..5x idleness range; freezing ~40% at 40L
    d = {(k, dep): b for k, dep, b in rows}
    return d


if __name__ == "__main__":
    main()
