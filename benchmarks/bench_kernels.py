"""Kernel microbenchmarks (§4.2.2 / §4.2.4).

On this CPU container, interpret-mode wall time is not TPU time; the
*derived* column reports what matters for the roofline: the fraction of MXU
tile work the kernels actually skip at each sparsity (work ratio vs dense),
validated against per-tile counting, plus interpret-mode wall time as a
relative sanity check.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attention import block_sparse_attention
from repro.kernels.pruned_matmul import pruned_matmul


def _time(fn, *args, reps=2, **kw):
    fn(*args, **kw)[0].block_until_ready() if isinstance(
        fn(*args, **kw), tuple) else fn(*args, **kw).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = False):
    rng = np.random.RandomState(0)
    rows = []
    # block-sparse attention: work ratio = active (q,kv) tiles / causal tiles
    b, s, h, d, bq = 1, 256, 2, 64, 64
    q = jnp.asarray(rng.randn(b, s, h, d) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, d) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, d) * 0.3, jnp.float32)
    nb = s // bq
    causal_tiles = nb * (nb + 1) // 2
    for density in (1.0, 0.5, 0.25):
        mask_np = (rng.rand(b, h, nb, nb) <= density).astype(np.int32)
        tril = np.tril(np.ones((nb, nb), np.int32))
        active = int((mask_np * tril).sum()) / (b * h)
        us = _time(block_sparse_attention, q, k, v, jnp.asarray(mask_np),
                   causal=True, block_q=bq, block_k=bq, interpret=True)
        rows.append((f"bsa_tile_work_ratio_d{int(density*100)}", us,
                     active / causal_tiles))
    # pruned matmul: work ratio = kept blocks / all blocks
    M, K, N = 256, 512, 512
    x = jnp.asarray(rng.randn(M, K) * 0.2, jnp.float32)
    w = jnp.asarray(rng.randn(K, N) * 0.2, jnp.float32)
    for sparsity in (0.0, 0.5, 0.9):
        nbk = N // 128
        keep = max(1, int(round(nbk * (1 - sparsity))))
        mask = jnp.asarray([1] * keep + [0] * (nbk - keep), jnp.int32)
        us = _time(pruned_matmul, x, w, mask, mask_axis="n", interpret=True)
        rows.append((f"pruned_matmul_work_ratio_s{int(sparsity*100)}", us,
                     keep / nbk))
    return rows


def main(quick: bool = False):
    rows = run(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived:.4f}")
    return rows


if __name__ == "__main__":
    main()
