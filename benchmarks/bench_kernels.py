"""Kernel microbenchmarks (§4.2.2 / §4.2.4), forward AND backward.

On this CPU container, interpret-mode wall time is not TPU time; the
*derived* column reports what matters for the roofline: the fraction of MXU
tile work the kernels actually skip at each sparsity (work ratio vs dense),
for the forward pass and for the flash/pruned backward pass — the backward
reuses the forward's block mask (see kernels/*/backward.py), so its ratio
must track the forward's.  Interpret-mode wall time (fwd and fwd+bwd via
jax.value_and_grad) is kept as a relative sanity check.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attention import (attention_tile_work,
                                                  block_sparse_attention)
from repro.kernels.pruned_matmul import matmul_tile_work, pruned_matmul


def _time(fn, *args, reps=2, **kw):
    jax.tree.leaves(fn(*args, **kw))[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = False):
    rng = np.random.RandomState(0)
    rows = []
    # ---- block-sparse attention: active (q,kv) tiles / causal tiles ------
    b, s, h, d, bq = 1, 256, 2, 64, 64
    q = jnp.asarray(rng.randn(b, s, h, d) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, d) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, d) * 0.3, jnp.float32)
    nb = s // bq

    def attn_loss(q, k, v, mask):
        return jnp.sum(block_sparse_attention(
            q, k, v, mask, causal=True, block_q=bq, block_k=bq,
            interpret=True) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(attn_loss, argnums=(0, 1, 2)))
    for density in (1.0, 0.5, 0.25):
        mask_np = (rng.rand(b, h, nb, nb) <= density).astype(np.int32)
        mask = jnp.asarray(mask_np)
        work = attention_tile_work(mask_np, causal=True, block_q=bq,
                                   block_k=bq)
        us_f = _time(block_sparse_attention, q, k, v, mask, causal=True,
                     block_q=bq, block_k=bq, interpret=True)
        us_b = _time(grad_fn, q, k, v, mask)
        tag = f"d{int(density * 100)}"
        rows.append((f"bsa_fwd_work_ratio_{tag}", us_f,
                     work["fwd_active"] / work["fwd_total"]))
        rows.append((f"bsa_bwd_work_ratio_{tag}", us_b,
                     work["bwd_active"] / work["bwd_total"]))
    # ---- pruned matmul: kept blocks / all blocks -------------------------
    M, K, N = 256, 512, 512
    x = jnp.asarray(rng.randn(M, K) * 0.2, jnp.float32)
    w = jnp.asarray(rng.randn(K, N) * 0.2, jnp.float32)

    def pm_loss(x, w, mask):
        return jnp.sum(pruned_matmul(x, w, mask, mask_axis="n",
                                     interpret=True) ** 2)

    pm_grad = jax.jit(jax.value_and_grad(pm_loss, argnums=(0, 1)))
    for sparsity in (0.0, 0.5, 0.9):
        nbk = N // 128
        keep = max(1, int(round(nbk * (1 - sparsity))))
        mask = jnp.asarray([1] * keep + [0] * (nbk - keep), jnp.int32)
        work = matmul_tile_work(M, K, N, np.asarray(mask), mask_axis="n")
        us_f = _time(pruned_matmul, x, w, mask, mask_axis="n",
                     interpret=True)
        us_b = _time(pm_grad, x, w, mask)
        tag = f"s{int(sparsity * 100)}"
        rows.append((f"pruned_matmul_fwd_work_ratio_{tag}", us_f,
                     work["fwd_active"] / work["fwd_total"]))
        rows.append((f"pruned_matmul_bwd_work_ratio_{tag}", us_b,
                     work["bwd_active"] / work["bwd_total"]))
    return rows


def main(quick: bool = False):
    rows = run(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived:.4f}")
    return rows


if __name__ == "__main__":
    main()
