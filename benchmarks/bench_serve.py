"""Elastic serving benchmark: a bursty arrival trace served twice — once on
a fixed mesh, once with the autoscaler shrinking/growing the engine worlds —
with identical generated tokens (asserted).  Records tok/s and p50/p95
per-token latency overall, plus the tok/s comparison restricted to the
LOW-LOAD window (the elastic run's first shrink→grow span): the shrunk
pipeline pays ``num_micro + S' - 1`` ticks per decode instead of
``num_micro + S - 1``, so the elastic server clears the drained batch
faster *while holding fewer workers*.

Subprocess-isolated (XLA's host device count must be fixed pre-import).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

_CHILD = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import copy
import dataclasses
import json
import numpy as np
from repro.api import Session
from repro.launch.serve import serve_spec
from repro.serve.requests import Request

gen_long = %(gen_long)d
# the elastic run's spec; the fixed baseline is the same spec with
# autoscaling off (recorded in BENCH_serve.json)
spec = serve_spec("smollm-360m", stages=4, micro=2, mb_global=2,
                  prompt_len=8, gen=gen_long, layers=8,
                  d_model=%(d_model)d, autoscale=True, min_stages=2,
                  patience=2, cooldown=3, queue_high=2,
                  occupancy_low=0.6, seed=0)
vocab = 512
rng = np.random.RandomState(0)
prompt = lambda n: rng.randint(0, vocab, n).astype(np.int32)
# burst of short early-exit requests + a long tail that keeps decoding
# through the drained (shrunken) phase, then a second burst -> grow back
# (hand-built long-tail arrivals; not expressible as a make_trace spec)
trace = []
for i in range(6):
    trace.append(Request(rid=i, arrival=0, prompt=prompt(8),
                         gen=2 + i %% 3, kind="early_exit"))
for i in range(2):
    trace.append(Request(rid=6 + i, arrival=0, prompt=prompt(6),
                         gen=gen_long))
t2 = gen_long + 14
for i in range(6):
    trace.append(Request(rid=8 + i, arrival=t2 + i // 4, prompt=prompt(8),
                         gen=4))

def run(autoscale):
    sp = dataclasses.replace(spec, cluster=dataclasses.replace(
        spec.cluster, autoscale=autoscale))
    with Session(sp) as s:
        return s.serve(trace=copy.deepcopy(trace))

keep = ("completions", "resizes", "tick_wall_s", "tick_tokens",
        "stages_history", "pool_log", "total_tokens", "wall_s",
        "tokens_per_s", "latency_p50_s", "latency_p95_s",
        "autoscale_decisions")
el = run(True)
fx = run(False)
out = {"elastic": {k: el[k] for k in keep},
       "fixed": {k: fx[k] for k in keep},
       "spec": spec.to_dict()}
print("BENCH_JSON " + json.dumps(out))
"""


def _run_child(gen_long: int, d_model: int) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD % {
            "gen_long": gen_long, "d_model": d_model}],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": SRC, "REPRO_TRAIN_DEVICES": "4"})
    if proc.returncode != 0:
        raise RuntimeError(f"serve bench child failed:\n"
                           f"{proc.stdout}\n{proc.stderr[-3000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("BENCH_JSON "):
            return json.loads(line[len("BENCH_JSON "):])
    raise RuntimeError(f"no BENCH_JSON in child output:\n{proc.stdout}")


def _window_tps(rep: dict, lo: int, hi: int) -> float:
    toks = sum(rep["tick_tokens"][lo:hi])
    wall = sum(rep["tick_wall_s"][lo:hi])
    return toks / max(1e-9, wall)


def run(quick: bool = False):
    out = _run_child(gen_long=20 if quick else 32,
                     d_model=64 if quick else 128)
    el, fx = out["elastic"], out["fixed"]
    # generated tokens must be identical request-for-request
    for a, b in zip(el["completions"], fx["completions"]):
        if a["tokens"] != b["tokens"]:
            raise RuntimeError(f"token mismatch rid {a['rid']}: "
                               f"{a['tokens']} vs {b['tokens']}")
    assert el["total_tokens"] == fx["total_tokens"]
    shrinks = [r for r in el["resizes"] if r["kind"] == "shrink"]
    grows = [r for r in el["resizes"] if r["kind"] == "grow"]
    if not shrinks:
        raise RuntimeError(f"no autoscale shrink fired: {el['resizes']}")
    # low-load window: after the LAST shrink settles (skip the fresh
    # world's compile ticks) until just before the grow-back burst (whose
    # admission prefill compiles too); idle lull ticks inside contribute
    # ~0 wall and 0 tokens to both runs alike
    lo = shrinks[-1]["step"] + 3
    hi = grows[0]["step"] - 2 if grows else len(el["tick_wall_s"])
    if hi - lo < 3:
        raise RuntimeError(
            f"low-load window too short ({lo}..{hi}); resizes "
            f"{[(r['kind'], r['step']) for r in el['resizes']]}")
    el_low = _window_tps(el, lo, hi)
    fx_low = _window_tps(fx, lo, hi)
    released = sum(1 for e in el["pool_log"] if e.startswith("release:"))
    rows = [
        ("serve_total_tokens", 0.0, float(el["total_tokens"])),
        ("serve_token_identity", 0.0, 1.0),
        ("serve_shrinks", 0.0, float(len(shrinks))),
        ("serve_grows", 0.0, float(len(grows))),
        ("serve_released_workers", 0.0, float(released)),
        ("serve_tok_s_elastic", 0.0, el["tokens_per_s"]),
        ("serve_tok_s_fixed", 0.0, fx["tokens_per_s"]),
        ("serve_tok_s_elastic_low_load", 0.0, el_low),
        ("serve_tok_s_fixed_low_load", 0.0, fx_low),
        ("serve_low_load_speedup", 0.0, el_low / max(1e-9, fx_low)),
        ("serve_p50_latency_ms_elastic", el["latency_p50_s"] * 1e6,
         el["latency_p50_s"] * 1e3),
        ("serve_p95_latency_ms_elastic", el["latency_p95_s"] * 1e6,
         el["latency_p95_s"] * 1e3),
        ("serve_p50_latency_ms_fixed", fx["latency_p50_s"] * 1e6,
         fx["latency_p50_s"] * 1e3),
        ("serve_p95_latency_ms_fixed", fx["latency_p95_s"] * 1e6,
         fx["latency_p95_s"] * 1e3),
    ]
    return rows, out["spec"]


def main(quick: bool = False):
    rows, spec = run(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.3f}")
    # (rows, spec): run.py snapshots BENCH_serve.json with the exact
    # RunSpec that produced these numbers
    return rows, spec


# ---------------------------------------------------------------------------
# Paged-KV headline: dense vs paged at the SAME KV byte budget
# ---------------------------------------------------------------------------
_CHILD_PAGED = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import copy
import json
import numpy as np
from repro.api import Session
from repro.launch.serve import serve_spec
from repro.serve.requests import Request

# one KV byte budget, two memory models.  Dense binds a full
# prompt_len+gen cache line to every lane: 4 lanes x 16 tokens = 64 token
# slots.  Paged gets a 16-page x 4-token pool — the SAME 64 token slots —
# but serves an 8-lane batch shape, admitting as many concurrent requests
# as actually-touched pages (short gens + shared prompt prefixes) fit.
page, cache = 4, 16
dense = serve_spec("smollm-360m", stages=4, micro=2, mb_global=2,
                   prompt_len=8, gen=8, layers=%(layers)d,
                   d_model=%(d_model)d, seed=0)
paged = serve_spec("smollm-360m", stages=4, micro=2, mb_global=4,
                   prompt_len=8, gen=8, layers=%(layers)d,
                   d_model=%(d_model)d, seed=0, kv_page_size=page,
                   kv_pool_pages=16, prefix_cache=True)
rng = np.random.RandomState(0)
shared = rng.randint(0, 512, 8).astype(np.int32)   # two full prompt pages
trace = []
for i in range(%(requests)d):
    trace.append(Request(rid=i, arrival=i // 8, prompt=shared.copy(),
                         gen=3 + i %% 2))

def run(sp):
    with Session(sp) as s:
        return s.serve(trace=copy.deepcopy(trace))

keep = ("completions", "total_tokens", "tokens_per_s", "peak_live_lanes",
        "peak_live_pages", "kv_pages_total", "kv_page_size", "prefix_hits",
        "cow_forks", "page_tile_live", "page_tile_total", "ticks")
dn = run(dense)
pg = run(paged)
out = {"dense": {k: dn[k] for k in keep},
       "paged": {k: pg[k] for k in keep},
       "prompt_pages_requested": sum(len(r.prompt) // page for r in trace),
       "spec": paged.to_dict()}
print("BENCH_JSON " + json.dumps(out))
"""


def _run_paged_child(requests: int, layers: int, d_model: int) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD_PAGED % {
            "requests": requests, "layers": layers, "d_model": d_model}],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": SRC, "REPRO_TRAIN_DEVICES": "4"})
    if proc.returncode != 0:
        raise RuntimeError(f"paged bench child failed:\n"
                           f"{proc.stdout}\n{proc.stderr[-3000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("BENCH_JSON "):
            return json.loads(line[len("BENCH_JSON "):])
    raise RuntimeError(f"no BENCH_JSON in child output:\n{proc.stdout}")


def run_paged(quick: bool = False):
    out = _run_paged_child(requests=12 if quick else 16,
                           layers=4 if quick else 8,
                           d_model=64 if quick else 128)
    dn, pg = out["dense"], out["paged"]
    # tokens are identical request-for-request: the memory model (and the
    # wider paged batch shape) must be invisible to every request
    td = {c["rid"]: c["tokens"] for c in dn["completions"]}
    tp = {c["rid"]: c["tokens"] for c in pg["completions"]}
    if td != tp:
        bad = [r for r in td if td[r] != tp.get(r)]
        raise RuntimeError(f"paged/dense token mismatch on rids {bad}")
    # THE headline: at the same KV byte budget, paging + prefix sharing
    # must hold strictly more requests in flight than dense lanes can
    if pg["peak_live_lanes"] <= dn["peak_live_lanes"]:
        raise RuntimeError(
            f"paged peak lanes {pg['peak_live_lanes']} not above dense "
            f"{dn['peak_live_lanes']} at equal KV bytes")
    hit_rate = out["prefix_hits_rate"] = (
        pg["prefix_hits"] / max(1, out["prompt_pages_requested"]))
    tile_frac = pg["page_tile_live"] / max(1, pg["page_tile_total"])
    rows = [
        ("paged_token_identity", 0.0, 1.0),
        ("paged_kv_token_slots", 0.0,
         float(pg["kv_pages_total"] * pg["kv_page_size"])),
        ("paged_peak_lanes", 0.0, float(pg["peak_live_lanes"])),
        ("dense_peak_lanes", 0.0, float(dn["peak_live_lanes"])),
        ("paged_lane_gain", 0.0,
         pg["peak_live_lanes"] / max(1, dn["peak_live_lanes"])),
        ("paged_peak_live_pages", 0.0, float(pg["peak_live_pages"])),
        ("paged_prefix_hits", 0.0, float(pg["prefix_hits"])),
        ("paged_prefix_hit_rate", 0.0, hit_rate),
        ("paged_cow_forks", 0.0, float(pg["cow_forks"])),
        # count-gating: fraction of page-table tiles that cost MXU work
        ("paged_tile_live_frac", 0.0, tile_frac),
        ("paged_ticks", 0.0, float(pg["ticks"])),
        ("dense_ticks", 0.0, float(dn["ticks"])),
        ("paged_tok_s", 0.0, pg["tokens_per_s"]),
        ("dense_tok_s", 0.0, dn["tokens_per_s"]),
    ]
    return rows, out["spec"]


def main_paged(quick: bool = False):
    rows, spec = run_paged(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.3f}")
    return rows, spec


if __name__ == "__main__":
    if "--paged" in sys.argv:
        main_paged(quick="--quick" in sys.argv)
    else:
        main(quick="--quick" in sys.argv)
