"""Live elasticity benchmark (paper §3.4 end-to-end): real pipelined
training on 4 forced host devices with pruning + repack enabled; records
tokens/s and per-step wall time before/after the engine's in-process 4→2
shrink, the schedule tick counts, and the released-worker count.

Runs the trainer in a subprocess because XLA's host device count must be
fixed before jax initializes — the bench harness itself keeps 1 device.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

_CHILD = """
import json
from repro.api import Session
from repro.launch.train import train_spec
spec = train_spec(
    "smollm-360m", steps=%(steps)d, stages=4, layers=8, d_model=128,
    seq=32, num_micro=%(micro)d, mb_global=2, dynamism="pruning",
    repack=True, rebalance_every=5, log_every=1000)
with Session(spec) as s:
    out = s.train()
print("BENCH_JSON " + json.dumps({
    "losses": out["losses"],
    "step_times": out["step_times"],
    "stages_history": out["stages_history"],
    "resizes": out["resizes"],
    "pool_log": out["pool_log"],
    "tokens_per_step": out["tokens_per_step"],
    "final_stages": out["final_stages"],
    "spec": spec.to_dict(),
}))
"""


def _run_child(steps: int, micro: int) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD % {"steps": steps, "micro": micro}],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": SRC, "REPRO_TRAIN_DEVICES": "4"})
    if proc.returncode != 0:
        raise RuntimeError(f"elastic bench child failed:\n"
                           f"{proc.stdout}\n{proc.stderr[-3000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("BENCH_JSON "):
            return json.loads(line[len("BENCH_JSON "):])
    raise RuntimeError(f"no BENCH_JSON in child output:\n{proc.stdout}")


def _mean(xs):
    return sum(xs) / max(1, len(xs))


def run(quick: bool = False):
    steps = 24 if quick else 40
    micro = 8                      # bubble (m+S-1)/m visible: 11 vs 9 ticks
    out = _run_child(steps, micro)
    hist = out["stages_history"]
    times = out["step_times"]
    tps = out["tokens_per_step"]
    shrinks = [r for r in out["resizes"] if r["kind"] == "shrink"]
    if not shrinks:
        raise RuntimeError(f"no shrink happened in {steps} steps: {hist}")
    rz = shrinks[0]
    cut = rz["step"] + 1           # first post-shrink step index
    # drop compile steps: the first 2 of the run, the first 1 after resize
    pre = times[2:cut]
    post = times[cut + 1:]
    if not pre or not post:
        raise RuntimeError(
            f"shrink at step {rz['step']} leaves no comparable window "
            f"(pre={len(pre)} post={len(post)} of {len(times)} steps); "
            f"raise steps")
    released = sum(1 for e in out["pool_log"] if e.startswith("release:"))
    rows = [
        ("elastic_ticks_pre_shrink", 0.0, float(rz["ticks_before"])),
        ("elastic_ticks_post_shrink", 0.0, float(rz["ticks_after"])),
        ("elastic_released_workers", 0.0, float(released)),
        ("elastic_resize_ms", rz["seconds"] * 1e6, rz["seconds"] * 1e3),
        ("elastic_step_ms_pre", _mean(pre) * 1e6, _mean(pre) * 1e3),
        ("elastic_step_ms_post", _mean(post) * 1e6, _mean(post) * 1e3),
        ("elastic_tokens_per_s_pre", _mean(pre) * 1e6, tps / _mean(pre)),
        ("elastic_tokens_per_s_post", _mean(post) * 1e6, tps / _mean(post)),
        ("elastic_speedup_post_over_pre", 0.0, _mean(pre) / _mean(post)),
        ("elastic_loss_drop_across_shrink", 0.0,
         out["losses"][max(0, cut - 2)] - out["losses"][-1]),
    ]
    return rows, out["spec"]


def main(quick: bool = False):
    rows, spec = run(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.3f}")
    # (rows, spec): run.py snapshots BENCH_elastic.json with the exact
    # RunSpec that produced these numbers
    return rows, spec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
