"""Figure 4 (left): re-packing GPT layers onto fewer GPUs as gradual pruning
shrinks the model — throughput/GPU and average GPU count over training."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.balancer import balance, stage_loads
from repro.core.cost_model import cost_vector
from repro.core.repack import repack_adjacent
from repro.core.simulator import simulate_pipeline, stage_times_from_layers
from repro.dynamics.config import DynamicsConfig
from repro.dynamics.trajectories import make_trajectory

DEPTHS = [24, 32, 40]


def run(quick: bool = False):
    rows = []
    S, m, seq = 8, 32, 2048
    dyncfg = DynamicsConfig(kind="pruning", prune_start_iter=3000,
                            prune_end_iter=7000)
    for depth in (DEPTHS[:2] if quick else DEPTHS):
        mc = get_config(f"gpt-paper-{depth}l")
        traj = make_trajectory("pruning", mc, dyncfg, total_iters=10000)
        pbytes = cost_vector(mc, 2 * seq, seq, None, by="param") * 2
        mem_budget = pbytes.sum() * 5.0 / S * 2.2   # per-worker capacity
        gpus_used, thr = [], []
        for k in range(0, 10000, 500):
            states = traj(k)
            t = cost_vector(mc, 2 * seq, seq, states, by="time")
            mem = pbytes * 5.0 * np.array(
                [max(0.25, s_.retained) for s_ in states])
            lps = balance("partition", t, S,
                          max_slots=depth).layers_per_stage
            plan = repack_adjacent(stage_loads(mem, lps), lps, mem_budget)
            lps = plan.layers_per_stage
            active = [s for s in range(S) if plan.active_workers[s]]
            f, b = stage_times_from_layers(t / 3, 2 * t / 3, lps)
            r = simulate_pipeline(f, b, m)
            gpus_used.append(plan.num_active)
            thr.append(m * 2 * seq / r.makespan)
        rows.append((depth, float(np.mean(gpus_used)),
                     float(np.mean(thr)),
                     float(np.mean(thr) / np.mean(gpus_used))))
    return rows


def main(quick: bool = False):
    rows = run(quick)
    print("name,us_per_call,derived")
    for depth, gpus, thr, tpg in rows:
        print(f"repack_avg_gpus_{depth}l,0,{gpus:.2f}")
        print(f"repack_throughput_per_gpu_{depth}l,0,{tpg:.1f}")
    return rows


if __name__ == "__main__":
    main()
