"""Multi-tenant cluster benchmark (DESIGN.md §14): one 6-worker pool shared
by a training tenant (priority 0, elastic 2..4 stages) and a serving tenant
(priority 10, elastic 2..4 stages) under a diurnal request trace — versus a
STATIC SPLIT of the same hardware (train pinned to 2, serve owning 4, no
worker ever crossing the fence).

Both runs serve the identical trace.  In the shared run the serve bursts
steal training workers through the HTTP cluster scheduler (the trainer
shrinks at its next safe point) and the lulls yield them back (the trainer
absorbs); the scheduler's wall-stamped grant timeline integrates to the
pool-utilization headline.  The static run wastes exactly what the paper
predicts: the serve lull capacity is stranded (nobody can take it) and the
trainer can never burst above its fixed half.

Records train tokens/s, serve p95 token latency, and time-weighted pool
utilization for both layouts -> BENCH_cluster.json.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

POOL = 6          # 4 train + 2 serve at rest; serve bursts to 4

_TRAIN_CHILD = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import json
from repro.api import Session
from repro.launch.train import train_spec

spec = train_spec("smollm-360m", steps=%(steps)d, stages=4, layers=8,
                  d_model=%(d_model)d, seq=32, num_micro=2, mb_global=2,
                  dynamism="none", rebalance_every=4, log_every=1000,
                  repack_target=2, job_manager=%(jm)r,
                  manager_url=%(url)r, tenant_id=%(tenant)r, priority=0)
with Session(spec) as s:
    rep = s.train()
toks = 2 * 2 * 32 * len(rep["losses"])
print("BENCH_JSON " + json.dumps({
    "tokens_per_s": toks / rep["wall_s"], "wall_s": rep["wall_s"],
    "steps": len(rep["losses"]), "stages_history": rep["stages_history"],
    "resizes": [(r["kind"], r["step"], r["from_stages"], r["to_stages"])
                for r in rep["resizes"]],
    "event_kinds": [ev.kind for ev in s.events],
    "spec": spec.to_dict()}))
"""

_SERVE_CHILD = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import json
from repro.api import Session
from repro.launch.serve import serve_spec

spec = serve_spec("smollm-360m", stages=4, micro=2, mb_global=2,
                  prompt_len=8, gen=%(gen)d, layers=8, d_model=%(d_model)d,
                  requests=%(requests)d, burst_period=24, burst_len=6,
                  burst_rate=4, lull_rate=0, early_exit_frac=0.25,
                  autoscale=True, min_stages=2, queue_high=2,
                  occupancy_low=0.6, patience=2, cooldown=3,
                  latency_slo_s=0.5, job_manager=%(jm)r,
                  manager_url=%(url)r, tenant_id=%(tenant)r, priority=10)
with Session(spec) as s:
    rep = s.serve()
print("BENCH_JSON " + json.dumps({
    "tokens_per_s": rep["tokens_per_s"], "wall_s": rep["wall_s"],
    "latency_p50_s": rep["latency_p50_s"],
    "latency_p95_s": rep["latency_p95_s"],
    "stages_history": rep["stages_history"],
    "tick_wall_s": rep["tick_wall_s"],
    "resizes": [(r["kind"], r["step"], r["from_stages"], r["to_stages"])
                for r in rep["resizes"]],
    "urgent_grows": sum(1 for d in rep["autoscale_decisions"]
                        if d["action"] == "grow" and d.get("urgent")),
    "event_kinds": [ev.kind for ev in s.events],
    "spec": spec.to_dict()}))
"""


def _spawn(code: str, **fmt) -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, "-c", code % fmt],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env={**os.environ, "PYTHONPATH": SRC, "REPRO_TRAIN_DEVICES": "4"})


def _collect(proc: subprocess.Popen, who: str, timeout: int = 1800) -> dict:
    out, _ = proc.communicate(timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"{who} child failed:\n{out[-4000:]}")
    for line in out.splitlines():
        if line.startswith("BENCH_JSON "):
            return json.loads(line[len("BENCH_JSON "):])
    raise RuntimeError(f"no BENCH_JSON from {who}:\n{out[-2000:]}")


def _utilization_from_timeline(events, t_lo: float, t_hi: float) -> float:
    """Time-weighted mean of (workers granted to any tenant) / pool size
    over [t_lo, t_hi], integrated from the scheduler's wall-stamped grant
    timeline."""
    if t_hi <= t_lo:
        return 0.0
    area = 0.0
    prev_t, prev_held = t_lo, 0
    for ev in sorted(events, key=lambda e: e["t"]):
        held = sum(ev["granted"].values())
        t = min(max(ev["t"], t_lo), t_hi)
        area += prev_held * (t - prev_t)
        prev_t, prev_held = t, held
    area += prev_held * (t_hi - prev_t)
    return area / ((t_hi - t_lo) * POOL)


def _wall_mean_stages(rep: dict) -> float:
    """Serve stage count weighted by per-tick wall time (ticks are wildly
    uneven: compiles vs steady decode)."""
    num = sum(s * w for s, w in zip(rep["stages_history"],
                                    rep["tick_wall_s"]))
    den = sum(rep["tick_wall_s"])
    return num / max(1e-9, den)


def _run_shared(steps: int, requests: int, gen: int, d_model: int):
    import tempfile
    import time

    from repro.cluster.http_rpc import HttpJobManager, spawn_http_manager
    run_dir = tempfile.mkdtemp(prefix="bench_cluster_")
    mgr, url = spawn_http_manager(run_dir, POOL, spares=0,
                                  idle_timeout_s=1800)
    try:
        kw = dict(jm="http", url=url, d_model=d_model)
        train = _spawn(_TRAIN_CHILD, steps=steps, tenant="train", **kw)
        serve = _spawn(_SERVE_CHILD, requests=requests, gen=gen,
                       tenant="serve", **kw)
        t_rep = _collect(train, "shared-train")
        s_rep = _collect(serve, "shared-serve")
        probe = HttpJobManager(url, client_id="bench-probe",
                               shutdown_on_close=True)
        events = probe.cluster_metrics()["events"]
        probe.close()
        mgr.wait(timeout=30)
    finally:
        if mgr.poll() is None:
            mgr.kill()
    # utilization over the contention window: first moment both tenants
    # hold workers -> the first deregistration (deregister pops the tenant
    # before recording its close-out yields, so the first snapshot with <2
    # tenants marks the end of two-tenant contention — the one-tenant tail
    # would otherwise read as stranded capacity nobody is contending for)
    t_first = {}
    for ev in events:
        if ev["ev"] == "grant" and ev["tenant"] not in t_first:
            t_first[ev["tenant"]] = ev["t"]
    t_lo = max(t_first.values()) if len(t_first) >= 2 else 0.0
    t_hi = max(e["t"] for e in events)
    for ev in sorted(events, key=lambda e: e["t"]):
        if ev["t"] > t_lo and len(ev["granted"]) < 2:
            t_hi = ev["t"]
            break
    util = _utilization_from_timeline(events, t_lo, t_hi)
    return t_rep, s_rep, util, events


def _run_static(steps: int, requests: int, gen: int, d_model: int):
    """The same workloads on a hard 2/4 split: each side owns a private
    in-process pool, so lull capacity is stranded by construction."""
    kw = dict(jm="inproc", url=None, tenant=None, d_model=d_model)
    train = _spawn(_TRAIN_CHILD.replace("stages=4", "stages=2"),
                   steps=steps, **kw)
    serve = _spawn(_SERVE_CHILD, requests=requests, gen=gen, **kw)
    t_rep = _collect(train, "static-train")
    s_rep = _collect(serve, "static-serve")
    # train side: 2 workers pinned, always "held"; serve side: holds its 4
    # only while scaled up — shrunk-away workers help nobody
    util = (2.0 + _wall_mean_stages(s_rep)) / POOL
    return t_rep, s_rep, util


def run(quick: bool = False):
    # the serve trace must SPAN the trainer's compile-gated timeline
    # (resizes land seconds apart on CPU): short traces drain before the
    # trainer's safe-point release and the steal/yield choreography never
    # completes, so the request counts here are wall-clock driven
    steps = 60 if quick else 120
    requests = 150 if quick else 300
    gen = 12 if quick else 16
    d_model = 64 if quick else 128
    sh_train, sh_serve, util_shared, events = _run_shared(
        steps, requests, gen, d_model)
    st_train, st_serve, util_static = _run_static(
        steps, requests, gen, d_model)

    steals = sum(1 for e in events if e["ev"] == "steal")
    yields = sum(1 for e in events if e["ev"] == "yield")
    if sh_serve["urgent_grows"] < 1:
        raise RuntimeError(
            f"no urgent grow (steal) fired in the shared run: "
            f"{sh_serve['resizes']}")
    if "preempt" not in sh_train["event_kinds"]:
        raise RuntimeError(
            f"the trainer never saw a preemption directive: "
            f"{sh_train['event_kinds']}")
    rows = [
        ("cluster_pool_workers", 0.0, float(POOL)),
        ("cluster_util_shared", 0.0, util_shared),
        ("cluster_util_static", 0.0, util_static),
        ("cluster_util_gain", 0.0, util_shared / max(1e-9, util_static)),
        ("cluster_train_tok_s_shared", 0.0, sh_train["tokens_per_s"]),
        ("cluster_train_tok_s_static", 0.0, st_train["tokens_per_s"]),
        ("cluster_serve_tok_s_shared", 0.0, sh_serve["tokens_per_s"]),
        ("cluster_serve_tok_s_static", 0.0, st_serve["tokens_per_s"]),
        ("cluster_serve_p95_ms_shared", sh_serve["latency_p95_s"] * 1e6,
         sh_serve["latency_p95_s"] * 1e3),
        ("cluster_serve_p95_ms_static", st_serve["latency_p95_s"] * 1e6,
         st_serve["latency_p95_s"] * 1e3),
        ("cluster_steals", 0.0, float(steals)),
        ("cluster_yields", 0.0, float(yields)),
        ("cluster_train_preempts", 0.0,
         float(sh_train["event_kinds"].count("preempt"))),
        ("cluster_train_absorbs", 0.0,
         float(sh_train["event_kinds"].count("absorb"))),
    ]
    spec = {"shared_train": sh_train["spec"],
            "shared_serve": sh_serve["spec"]}
    return rows, spec


def main(quick: bool = False):
    rows, spec = run(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.3f}")
    return rows, spec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
