"""Figure 4 (right): DynMo overhead breakdown — profiling, balancing
algorithm, layer migration — as a fraction of end-to-end training time.
Paper: single-digit percent across cases."""
from __future__ import annotations

from benchmarks.common import CASE_ARCH, sim_case


def run(quick: bool = False):
    iters = 2000 if quick else 10000
    out = {}
    for kind, arch in CASE_ARCH.items():
        r = sim_case(kind, arch, "diffusion", "time", True,
                     sample_every=200 if quick else 100, iters=iters)
        tot = max(1e-12, r.total_time)
        out[kind] = {
            "profile": r.overhead_breakdown["profile"] / tot,
            "algorithm": r.overhead_breakdown["algorithm"] / tot,
            "migration": r.overhead_breakdown["migration"] / tot,
            "total": r.overhead_frac,
        }
    return out


def main(quick: bool = False):
    res = run(quick)
    print("name,us_per_call,derived")
    for kind, d in res.items():
        for part in ("profile", "algorithm", "migration", "total"):
            print(f"overhead_{part}_{kind},0,{d[part]:.5f}")
    return res


if __name__ == "__main__":
    main()
