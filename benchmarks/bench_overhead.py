"""Figure 4 (right): DynMo overhead breakdown — profiling, balancing
algorithm, layer migration — as a fraction of end-to-end training time.
Paper: single-digit percent across cases.

Also home of the control-plane latency bench (``main_controller``,
BENCH_controller.json): per-step decision cost paid by the TRAINING thread,
inline vs async, at ``rebalance_every=1`` — the §3.3.1 acceptance number
(async train-thread cost ~ 0: publishing a snapshot is a pointer swap)."""
from __future__ import annotations

import time

from benchmarks.common import CASE_ARCH, sim_case


def run(quick: bool = False):
    iters = 2000 if quick else 10000
    out = {}
    for kind, arch in CASE_ARCH.items():
        r = sim_case(kind, arch, "diffusion", "time", True,
                     sample_every=200 if quick else 100, iters=iters)
        tot = max(1e-12, r.total_time)
        out[kind] = {
            "profile": r.overhead_breakdown["profile"] / tot,
            "algorithm": r.overhead_breakdown["algorithm"] / tot,
            "migration": r.overhead_breakdown["migration"] / tot,
            "total": r.overhead_frac,
        }
    return out


def main(quick: bool = False):
    res = run(quick)
    print("name,us_per_call,derived")
    for kind, d in res.items():
        for part in ("profile", "algorithm", "migration", "total"):
            print(f"overhead_{part}_{kind},0,{d[part]:.5f}")
    return res


# ---------------------------------------------------------------------------
# control-plane decision latency: inline vs async (per training step)
# ---------------------------------------------------------------------------
def run_controller(quick: bool = False):
    import numpy as np
    from repro.cluster.service import ControlPlane, StatsSnapshot
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.core.controller import ControllerConfig, DynMoController
    from repro.dynamics.config import DynamicsConfig
    from repro.models import model as M

    steps = 60 if quick else 400
    stages, layers = 8, 64
    cfg = reduced_config(get_config("smollm-360m"), num_layers=layers,
                         d_model=64, d_ff=2048)
    dcfg = DistConfig(num_stages=stages, slot_slack=3, remat="none",
                      param_dtype="float32")
    tags = np.asarray(M.make_assignment(cfg, dcfg)["tags"])
    live = tags != 0
    num_micro = 4
    rng = np.random.RandomState(0)

    def snapshot(it, epoch=0):
        grad = np.linspace(0.1, 1.0, stages)[:, None] * np.ones_like(
            tags, float)
        ff = np.where(live, num_micro * np.clip(
            grad + rng.uniform(-0.1, 0.1, tags.shape), 0.02, 1.0), 0.0)
        stats = {"ff_active": ff,
                 "attn_density": np.where(live, 0.2 * num_micro, 0.0),
                 "expert_load": np.zeros(tags.shape + (1,))}
        return StatsSnapshot(iteration=it, epoch=epoch, stats=stats,
                             tags=tags, num_micro=num_micro, tokens=8192,
                             seq=128)

    results = {}
    for mode in ("inline", "async"):
        ctrl = DynMoController(
            cfg, dcfg, DynamicsConfig(kind="pruning"),
            ControllerConfig(method="diffusion", rebalance_every=1))
        cp = ControlPlane(ctrl, async_mode=(mode == "async"))
        try:
            train_thread_s, decide_s = [], []
            for it in range(1, steps + 1):
                snap = snapshot(it)
                t0 = time.perf_counter()
                cp.publish(snap)                 # what the step pays
                train_thread_s.append(time.perf_counter() - t0)
                if mode == "async":
                    cp.drain()                   # decisions still complete
                plan = cp.poll(0)
                if plan is not None:
                    decide_s.append(plan.decide_s)
            results[mode] = (sum(train_thread_s) / steps,
                             sum(decide_s) / max(1, len(decide_s)))
            assert cp.decided == steps
        finally:
            cp.close()
    rows = []
    for mode, (tt, dd) in results.items():
        rows.append((f"controller_train_thread_{mode}", tt * 1e6, tt))
        rows.append((f"controller_decision_{mode}", dd * 1e6, dd))
    # the acceptance ratio: how much per-step decision latency the training
    # thread sheds by going async at rebalance_every=1
    rows.append(("controller_async_train_thread_reduction", 0.0,
                 results["inline"][0] / max(1e-12, results["async"][0])))
    return rows


def main_controller(quick: bool = False):
    rows = run_controller(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived:.9f}")
    return rows


# ---------------------------------------------------------------------------
# observability overhead: tracing / metrics / in-step timing on-vs-off
# ---------------------------------------------------------------------------
_OBS_CHILD = """
import json, statistics
from repro.api import RunSpec, Session

def steady(obs):
    spec = RunSpec.from_dict({
        "schema_version": 4,
        "model": {"arch": "smollm-360m", "layers": 8, "d_model": 64,
                  "num_heads": 4, "num_kv_heads": 2, "vocab_size": 256},
        "parallel": {"stages": 4, "num_micro": 4, "mb_global": 4,
                     "seq": 32},
        "controller": {"rebalance_every": 4},
        "obs": obs, "steps": %(steps)d, "log_every": 1000})
    with Session(spec) as s:
        rep = s.train()
    return rep["timing"]["steady_step_mean_s"], spec.to_dict()

base, spec = steady({})
trace, _ = steady({"trace": True})
instep, _ = steady({"in_step_timing": True})
print("BENCH_JSON " + json.dumps(
    {"baseline": base, "trace": trace, "in_step": instep, "spec": spec}))
"""


def run_obs(quick: bool = False):
    """Observability layer overhead (DESIGN.md §15 acceptance numbers).

    Host-side microbenches (span open/close, instant, counter inc,
    histogram observe, unified-event stamping) run inline; the per-step
    cost of tracing and in-step stage timing against a real pipelined
    trainer runs in a subprocess on 4 forced host devices (same idiom as
    ``bench_elastic``) — ``derived`` for the ``obs_step_*`` rows is the
    relative per-step overhead vs the all-off baseline."""
    import json as _json
    import os
    import subprocess
    import sys

    from repro.obs.events import stamp_record
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import Tracer

    iters = 20000 if quick else 200000
    rows = []

    tr = Tracer("bench")
    t0 = time.perf_counter()
    for i in range(iters):
        with tr.span("bench.span", step=i):
            pass
    dt = (time.perf_counter() - t0) / iters
    rows.append(("obs_span_open_close", dt * 1e6, dt))

    tr = Tracer("bench")
    t0 = time.perf_counter()
    for i in range(iters):
        tr.instant("bench.instant", step=i)
    dt = (time.perf_counter() - t0) / iters
    rows.append(("obs_instant", dt * 1e6, dt))

    reg = MetricsRegistry()
    t0 = time.perf_counter()
    for i in range(iters):
        reg.inc("bench_total", kind="x")
    dt = (time.perf_counter() - t0) / iters
    rows.append(("obs_metrics_inc", dt * 1e6, dt))

    t0 = time.perf_counter()
    for i in range(iters):
        reg.observe("bench_seconds", 0.01 * (i % 7))
    dt = (time.perf_counter() - t0) / iters
    rows.append(("obs_metrics_observe", dt * 1e6, dt))

    t0 = time.perf_counter()
    for i in range(iters):
        stamp_record({"step": i}, source="session", kind="log", tracer=tr)
    dt = (time.perf_counter() - t0) / iters
    rows.append(("obs_stamp_record", dt * 1e6, dt))

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(repo, "src")
    out = subprocess.run(
        [sys.executable, "-c", _OBS_CHILD % {"steps": 10 if quick else 24}],
        capture_output=True, text=True, timeout=1800,
        env={**os.environ, "PYTHONPATH": src, "REPRO_TRAIN_DEVICES": "4"})
    if out.returncode != 0:
        raise RuntimeError(f"obs step bench failed:\n{out.stdout[-2000:]}"
                           f"\n{out.stderr[-2000:]}")
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("BENCH_JSON ")][-1]
    d = _json.loads(line[len("BENCH_JSON "):])
    base = max(1e-12, d["baseline"])
    rows.append(("obs_step_baseline", d["baseline"] * 1e6, d["baseline"]))
    rows.append(("obs_step_trace_rel_overhead", d["trace"] * 1e6,
                 d["trace"] / base - 1.0))
    rows.append(("obs_step_in_step_timing_rel_overhead",
                 d["in_step"] * 1e6, d["in_step"] / base - 1.0))
    return rows, d["spec"]


def main_obs(quick: bool = False):
    rows, spec = run_obs(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived:.9f}")
    return rows, spec


if __name__ == "__main__":
    main()
    main_controller()
    main_obs()
