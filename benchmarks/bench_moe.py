"""Expert-parallel MoE benchmark: grouped ragged matmul vs capacity einsum.

On this CPU container, interpret-mode wall time is not TPU time; the
*derived* column reports what matters for the expert-parallel roofline:

  * ``gmm_{fwd,bwd}_work_<skew>``   — fraction of MXU row-tile work the
    grouped kernel runs at each routed-load skew (active/total tiles from
    ``grouped_tile_work``; the capacity einsum always pays 1.0).  Expert
    FLOP work must track routed load: hotter skews with empty experts
    skip more tiles.
  * ``expert_skew_<skew>``          — the max/mean load ratio of that
    routing pattern (what ``DynMoController`` watches against
    ``expert_watermark`` to trigger a LAER re-layout).
  * ``moe_ffn_{pallas,scan}``       — end-to-end block parity check: the
    derived column is each impl's capacity-drop fraction, which must be
    IDENTICAL (routing is shared; only expert compute differs).

Interpret-mode wall time (fwd and fwd+bwd) rides along as a relative
sanity check, as in bench_kernels.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.api.scenarios import scenario
from repro.configs import get_config, reduced_config
from repro.kernels.grouped_matmul import (grouped_matmul, grouped_matmul_ref,
                                          grouped_tile_work)
from repro.models.blocks import moe_ffn


def _time(fn, *args, reps=2, **kw):
    jax.tree.leaves(fn(*args, **kw))[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def _bench_spec():
    """The exact RunSpec these numbers correspond to: the moe scenario on
    the grouped pallas path with live expert re-layout enabled."""
    sp = scenario("moe")
    return dataclasses.replace(
        sp,
        parallel=dataclasses.replace(sp.parallel, kernel_impl="pallas"),
        dynamics=dataclasses.replace(sp.dynamics, expert_relayout=True))


def run(quick: bool = False):
    rng = np.random.RandomState(0)
    rows = []

    # ---- grouped matmul: tile work vs routed-load skew -------------------
    # G = b*E batch-major groups, E experts; counts are routed tokens kept
    # per (batch row, physical expert) — exactly what moe_ffn dispatches.
    b, E, cap, K, N = 2, 4, 64, 128, 128
    G = b * E
    skews = {
        "uniform": np.full(G, cap // 2),
        # one hot expert per batch row at capacity, the rest cold
        "hot": np.asarray([cap, 8, 8, 8] * b),
        # degenerate routing collapse: one expert takes every token
        "one_expert_all": np.asarray([cap, 0, 0, 0] * b),
        "half_empty": np.asarray([cap // 2, cap // 2, 0, 0] * b),
    }
    x = jnp.asarray(rng.randn(G, cap, K) * 0.2, jnp.float32)
    w = jnp.asarray(rng.randn(E, K, N) * 0.2, jnp.float32)

    def gm_loss(x, w, counts):
        return jnp.sum(grouped_matmul(x, w, counts, interpret=True) ** 2)

    gm_grad = jax.jit(jax.value_and_grad(gm_loss, argnums=(0, 1)))
    for tag, counts_np in skews.items():
        counts = jnp.asarray(counts_np, jnp.int32)
        work = grouped_tile_work(counts_np, cap)
        us_f = _time(grouped_matmul, x, w, counts, interpret=True)
        us_b = _time(gm_grad, x, w, counts)
        rows.append((f"gmm_fwd_work_{tag}", us_f,
                     work["fwd_active"] / work["fwd_total"]))
        rows.append((f"gmm_bwd_work_{tag}", us_b,
                     work["bwd_active"] / work["bwd_total"]))
        # per-logical-expert load (sum over batch rows), controller-style
        load = counts_np.reshape(b, E).sum(axis=0).astype(np.float64)
        rows.append((f"expert_skew_{tag}", 0.0,
                     float(load.max() / max(load.mean(), 1e-9))))
    # dense capacity-einsum baseline: always full-capacity FLOPs (ratio 1)
    us_ref = _time(jax.jit(grouped_matmul_ref), x, w,
                   jnp.asarray(skews["uniform"], jnp.int32))
    rows.append(("capacity_einsum_fwd_work", us_ref, 1.0))

    # ---- end-to-end moe_ffn: grouped path vs capacity oracle -------------
    cfg = reduced_config(get_config("mixtral-8x7b"), num_layers=2,
                         d_model=64, d_ff=128)
    # tighten capacity so drops actually occur: the derived column must
    # then agree between impls (routing is shared, drops are pre-dispatch)
    cfg = dataclasses.replace(cfg, moe_capacity_factor=1.0)
    mb, s, d = (1, 16, 64) if quick else (2, 32, 64)
    ff, Em = cfg.d_ff, cfg.num_experts
    p = {
        "router": jnp.asarray(rng.randn(d, Em) * 0.3, jnp.float32),
        "ewi": jnp.asarray(rng.randn(Em, d, ff) * 0.2, jnp.float32),
        "ewg": jnp.asarray(rng.randn(Em, d, ff) * 0.2, jnp.float32),
        "ewo": jnp.asarray(rng.randn(Em, ff, d) * 0.2, jnp.float32),
    }
    xb = jnp.asarray(rng.randn(mb, s, d) * 0.5, jnp.float32)
    for impl in ("scan", "pallas"):
        fn = jax.jit(lambda p, xb, impl=impl: moe_ffn(
            p, xb, cfg, kernel_impl=impl))
        us = _time(fn, p, xb)
        dropped = float(fn(p, xb)[3])
        rows.append((f"moe_ffn_{impl}", us, dropped))
    return rows, _bench_spec().to_dict()


def main(quick: bool = False):
    rows, spec = run(quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived:.4f}")
    return rows, spec
