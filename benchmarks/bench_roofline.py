"""Roofline aggregation: reads results/dryrun/*.json (produced by
repro.launch.dryrun) and emits the per-(arch × shape × mesh) three-term
table used by EXPERIMENTS.md §Roofline.  No compilation here."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(results_dir: str = RESULTS):
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as fh:
            cells.append(json.load(fh))
    return cells


def markdown_table(cells):
    lines = ["| arch | shape | mesh | peak GiB/chip | fits | t_comp (s) | "
             "t_mem HLO (s) | t_mem analytic (s) | t_coll (s) | bottleneck |"
             " useful | MFU≤ |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if "skipped" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | "
                         f"— | — | — | — | — | SKIP | — | — |")
            continue
        if "error" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | "
                         f"— | — | — | — | — | ERROR | — | — |")
            continue
        m = c["memory"]
        r = c.get("roofline", {})
        gib = m["peak_bytes_per_chip"] / 2 ** 30
        if r:
            tag = " (a)" if r.get("analytic") else ""
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | {gib:.2f} | "
                f"{'Y' if m['fits_16GB'] else 'N'} | "
                f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} | "
                f"{r.get('t_memory_analytic_s', 0):.4f} | "
                f"{r['t_collective_s']:.4f} | {r['bottleneck']}{tag} | "
                f"{r['useful_flops_ratio']:.2f} | {r['mfu_bound']:.3f} |")
        else:
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | {gib:.2f} | "
                f"{'Y' if m['fits_16GB'] else 'N'} | — | — | — | — | "
                f"(compile-only) | — | — |")
    return "\n".join(lines)


def main(quick: bool = False):
    cells = load_cells()
    print("name,us_per_call,derived")
    done = sum(1 for c in cells if "roofline" in c)
    compiled = sum(1 for c in cells if "memory" in c)
    skipped = sum(1 for c in cells if "skipped" in c)
    errors = sum(1 for c in cells if "error" in c)
    print(f"roofline_cells_with_terms,0,{done}")
    print(f"roofline_cells_compiled,0,{compiled}")
    print(f"roofline_cells_skipped,0,{skipped}")
    print(f"roofline_cells_errors,0,{errors}")
    return cells


if __name__ == "__main__":
    print(markdown_table(load_cells()))
