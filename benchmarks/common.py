"""Shared benchmark scaffolding: paper-scale simulator setups."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.configs import get_config
from repro.core.cost_model import cost_vector
from repro.core.simulator import TrainSimConfig, simulate_training
from repro.dynamics.config import DynamicsConfig
from repro.dynamics.trajectories import make_trajectory

# Paper §5: MoE/MoD on 128 GPUs (8 DP × 16 PP); pruning/freezing/sparse/EE
# on 720 GPUs (30 DP × 24 PP); 4 microbatches per GPU.  Sparse attention is
# evaluated at long sequence (its source technique targets long sequences;
# at 2k attention is <20% of layer FLOPs and no scheme could reach the
# paper's 4× — see EXPERIMENTS.md discussion).
CASE_SETUP = {
    "moe": dict(stages=16, dp=8, seq=2048),
    "mod": dict(stages=16, dp=8, seq=2048),
    "pruning": dict(stages=24, dp=30, seq=2048),
    "freezing": dict(stages=24, dp=30, seq=2048),
    "sparse_attention": dict(stages=24, dp=30, seq=16384),
    "early_exit": dict(stages=24, dp=30, seq=2048),
}
SEQ = 2048
ITERS = 10000


def sim_case(kind: str, arch: str, balancer: str, cost_by: str,
             rebalance: bool, dynamism_on: bool = True,
             repack: bool = False, sample_every: int = 100,
             iters: int = ITERS):
    """One end-to-end training simulation; returns TrainSimResult."""
    mc = get_config(arch)
    setup = CASE_SETUP[kind]
    S = setup["stages"]
    seq = setup.get("seq", SEQ)
    m = 4 * S                       # 4 microbatches per GPU (paper)
    tokens_iter = m * 2 * seq       # micro-batch size 2 (paper)
    # dynamism window scaled to the simulated horizon (paper: pruning
    # 3000..7000 of 10000 iters)
    dyncfg = DynamicsConfig(kind=kind,
                            prune_start_iter=int(0.3 * iters),
                            prune_end_iter=int(0.7 * iters),
                            prune_frequency=max(1, iters // 10))
    traj = make_trajectory(kind if dynamism_on else "none", mc, dyncfg,
                           total_iters=iters)
    tokens_per_micro = 2 * seq

    def layer_time_fn(k):
        t = cost_vector(mc, tokens_per_micro, seq, traj(k), by="time")
        return t / 3.0, 2.0 * t / 3.0

    pbytes = cost_vector(mc, tokens_per_micro, seq, None, by="param") * 2
    L = mc.total_blocks()
    # paper §3.3.1: per-iteration for MoE/MoD; every ~50 for freezing;
    # 100s for the content-dependent cases; 1000s for pruning
    reb_freq = {"moe": 1, "mod": 1, "freezing": 50,
                "sparse_attention": 100, "early_exit": 100,
                "pruning": 1000}[kind]
    cfg = TrainSimConfig(
        num_stages=S, num_micro=m, tokens_per_iter=tokens_iter,
        iters=iters, sample_every=sample_every,
        rebalance_every=reb_freq if rebalance else 0,
        balancer=balancer, cost_by=cost_by, schedule="1f1b",
        max_slots=max(2, (L + S - 1) // S + 4),
        repack=repack, repack_mem_cap=pbytes.sum() * 5.0 / S * 1.6,
        layer_mem=pbytes * 5.0)
    return simulate_training(layer_time_fn, pbytes, cfg)


CASE_ARCH = {
    "moe": "mixtral-8x7b",
    "mod": "gpt-paper-32l",
    "pruning": "gpt-paper-32l",
    "freezing": "gpt-paper-40l",
    "sparse_attention": "gpt-paper-32l",
    "early_exit": "gpt-paper-32l",
}

BALANCERS = [
    ("megatron-uniform", "uniform", "param", False),
    ("deepspeed-param", "dsparam", "param", False),
    ("partition:param", "partition", "param", True),
    ("partition:time", "partition", "time", True),
    ("diffusion:param", "diffusion", "param", True),
    ("diffusion:time", "diffusion", "time", True),
]
