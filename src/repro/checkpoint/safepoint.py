"""Safe-point checkpoints: everything a crashed trainer needs to resume
bit-identically (DESIGN.md §12).

A *safe point* extends the ordinary checkpoint shards (params + optimizer +
dynamism state, atomically published via write-temp-then-rename) with the
run's control-plane state in the index metadata:

  * the producing ``RunSpec`` (as a dict — ``Session.resume`` rebuilds the
    whole run from the checkpoint alone, no side-channel config);
  * the step, stage count, layer split, and stage→worker map;
  * the worker-pool topology (in-process pools directly; file-backed pools
    via the manager's own ``state.json`` journal);
  * autoscaler hysteresis state and the controller's repack latch.

Data-loader position and LR schedule are pure functions of (spec, step),
so restoring ``step`` restores them; model/optimizer tensors restore
bit-exactly from the npz shards.  ``Session.resume(dir)`` therefore
replays the exact trajectory the uninterrupted run would have taken.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional

from repro.checkpoint.checkpoint import (latest_index, load_checkpoint,
                                         save_checkpoint)


class SafepointManager:
    """Periodic safe points under ``path``; keeps the newest ``keep``."""

    def __init__(self, path: str, every: int, keep: int = 3):
        assert every > 0
        self.path, self.every, self.keep = path, every, keep
        self.saved: List[str] = []
        os.makedirs(path, exist_ok=True)

    def due(self, step: int) -> bool:
        return (step + 1) % self.every == 0

    def save(self, step: int, state, *, spec, engine,
             scaler=None, repack_enabled: Optional[bool] = None,
             jm_dir: Optional[str] = None) -> str:
        """Write the safe point for a fully-completed ``step``."""
        pool_state = None
        if engine.pool is not None:
            pool_state = engine.pool.state_dict()
        elif jm_dir is not None:
            # file-backed manager: the authoritative pool lives in the
            # server process; its journal (written before every response)
            # is exactly the topology we need
            sp = os.path.join(jm_dir, "state.json")
            if os.path.exists(sp):
                try:
                    with open(sp) as f:
                        pool_state = json.load(f)["pool"]
                except (json.JSONDecodeError, OSError, KeyError):
                    pool_state = None
        meta: Dict[str, Any] = {
            "kind": "safepoint",
            "spec": spec.to_dict(),
            "step": step,
            "stage_workers": [int(w) for w in engine.stage_workers],
            "epoch": int(engine.epoch),
            "pool": pool_state,
            "scaler": scaler.state_dict() if scaler is not None else None,
            "repack_enabled": repack_enabled,
        }
        out = save_checkpoint(self.path, step, state.params, state.opt_state,
                              state.dyn, state.lps, extra_meta=meta)
        self.saved.append(out)
        self._gc()
        return out

    def _gc(self) -> None:
        cands = sorted(d for d in os.listdir(self.path)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in cands[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, d), ignore_errors=True)


def peek(path: str, step: Optional[int] = None) -> Dict[str, Any]:
    """Index (with safepoint meta) of the newest complete safe point."""
    idx = latest_index(path, step)
    if idx is None:
        raise FileNotFoundError(f"no complete safe point under {path}")
    if idx.get("meta", {}).get("kind") != "safepoint":
        raise ValueError(
            f"checkpoint under {path} is not a safe point (plain "
            f"checkpoints lack the control-plane state resume needs)")
    return idx


def restore(path: str, templates, step: Optional[int] = None):
    """(params, opt_state, dyn, index) for the newest complete safe point."""
    return load_checkpoint(path, templates, step)
