"""Elastic restart (paper §3.4.2): re-packing coordinated with checkpointing.

Restoring onto a *different* stage count rebuilds the slot buffers: the
checkpoint's (layers-per-stage, stacked state) is flattened to global layer
order and re-split contiguously for the new mesh — "the model is reloaded
and re-shared among the workers during checkpoint recovery, so there is no
additional overhead for resharding" (paper).  Works for both shrink
(re-pack, released workers) and grow (recovered workers).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import DistConfig, ModelConfig
from repro.models.model import make_assignment, uniform_boundaries


def resplit_indices(old_lps: Sequence[int], new_lps: Sequence[int],
                    new_L_max: int
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side plan: for each destination slot of the new layout, the
    (src_stage, src_slot) it gathers from, plus a validity mask for PAD
    slots.  Tiny (S×L_max ints) — the *data* never round-trips.

    The index-map math is migration.build_plan's (it already supports a
    different source/destination stage count); this adapter only names the
    cross-stage-count use."""
    from repro.core.migration import build_plan
    plan = build_plan(old_lps, new_lps, new_L_max)
    return plan.src_stage, plan.src_slot, plan.valid


def _resplit_stage_tree(tree, old_lps: Sequence[int],
                        new_lps: Sequence[int], new_L_max: int):
    """Re-split [S_old, L_old, ...] stacked arrays to [S_new, L_new, ...]
    along global layer order.

    Device-side: the index map is planned on host (a few hundred ints) and
    the state moves via one gather per leaf (migration.apply_plan) — no
    numpy round-trip of the tensors, so a live shrink/grow never syncs
    weights to host memory.  PAD destination slots are zeroed (their tags
    mark them inactive)."""
    from repro.core.migration import apply_plan, build_plan
    return apply_plan(tree, build_plan(old_lps, new_lps, new_L_max))


def elastic_restore(cfg: ModelConfig, old_dcfg: DistConfig,
                    new_dcfg: DistConfig, params, opt_state, dyn,
                    old_lps: Sequence[int],
                    new_lps: Optional[Sequence[int]] = None):
    """Reshape checkpointed state from old stage layout to the new mesh.

    Returns (params, opt_state, dyn, assignment, new_lps)."""
    if new_lps is None:
        new_lps = uniform_boundaries(cfg.total_blocks(), new_dcfg.num_stages)
    L_new = new_dcfg.slots_for(cfg)
    params = dict(params)
    params["stages"] = _resplit_stage_tree(params["stages"], old_lps,
                                           new_lps, L_new)
    if opt_state is not None:
        opt_state = _reshape_opt(opt_state, old_lps, new_lps, L_new)
    dyn = _resplit_stage_tree(dyn, old_lps, new_lps, L_new)
    assignment = make_assignment(cfg, new_dcfg, new_lps)
    return params, opt_state, dyn, assignment, list(new_lps)


def _reshape_opt(opt_state, old_lps, new_lps, L_new):
    """Optimizer moments mirror the param tree: reshape the stages subtree,
    keep everything else (count, non-stage moments) — migration's opt walk
    with the cross-stage-count plan."""
    from repro.core.migration import _apply_plan_to_opt, build_plan
    return _apply_plan_to_opt(opt_state,
                              build_plan(old_lps, new_lps, L_new))
