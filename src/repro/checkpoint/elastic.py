"""Elastic restart (paper §3.4.2): re-packing coordinated with checkpointing.

Restoring onto a *different* stage count rebuilds the slot buffers: the
checkpoint's (layers-per-stage, stacked state) is flattened to global layer
order and re-split contiguously for the new mesh — "the model is reloaded
and re-shared among the workers during checkpoint recovery, so there is no
additional overhead for resharding" (paper).  Works for both shrink
(re-pack, released workers) and grow (recovered workers).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import DistConfig, ModelConfig
from repro.models.model import make_assignment, uniform_boundaries


def _resplit_stage_tree(tree, old_lps: Sequence[int],
                        new_lps: Sequence[int], new_L_max: int):
    """Re-split [S_old, L_old, ...] stacked arrays to [S_new, L_new, ...]
    along global layer order."""
    old_lps = list(map(int, old_lps))
    new_lps = list(map(int, new_lps))
    assert sum(old_lps) == sum(new_lps)

    def one(a):
        a = np.asarray(a)
        S_old, L_old = a.shape[0], a.shape[1]
        layers = []
        for s, n in enumerate(old_lps):
            for l in range(n):
                layers.append(a[s, l])
        out = np.zeros((len(new_lps), new_L_max) + a.shape[2:], a.dtype)
        g = 0
        for s, n in enumerate(new_lps):
            for l in range(n):
                out[s, l] = layers[g]
                g += 1
        return jnp.asarray(out)

    return jax.tree.map(one, tree)


def elastic_restore(cfg: ModelConfig, old_dcfg: DistConfig,
                    new_dcfg: DistConfig, params, opt_state, dyn,
                    old_lps: Sequence[int],
                    new_lps: Optional[Sequence[int]] = None):
    """Reshape checkpointed state from old stage layout to the new mesh.

    Returns (params, opt_state, dyn, assignment, new_lps)."""
    if new_lps is None:
        new_lps = uniform_boundaries(cfg.total_blocks(), new_dcfg.num_stages)
    L_new = new_dcfg.slots_for(cfg)
    params = dict(params)
    params["stages"] = _resplit_stage_tree(params["stages"], old_lps,
                                           new_lps, L_new)
    if opt_state is not None:
        opt_state = _reshape_opt(opt_state, old_lps, new_lps, L_new)
    dyn = _resplit_stage_tree(dyn, old_lps, new_lps, L_new)
    assignment = make_assignment(cfg, new_dcfg, new_lps)
    return params, opt_state, dyn, assignment, list(new_lps)


def _reshape_opt(opt_state, old_lps, new_lps, L_new):
    """Optimizer moments mirror the param tree: reshape the stages subtree,
    keep everything else (count, non-stage moments)."""
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "stages":
                    out[k] = _resplit_stage_tree(v, old_lps, new_lps, L_new)
                else:
                    out[k] = walk(v)
            return out
        return node
    return walk(opt_state)
