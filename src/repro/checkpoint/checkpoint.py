"""Fault-tolerant checkpointing.

Layout: one .npz shard per pipeline stage (stage-sharded state restores in
parallel and re-shards trivially on elastic restarts) + a msgpack metadata
index with step, layers-per-stage, configs, and integrity checksums.
Writes are atomic (tmp + rename); the manager keeps the last K checkpoints
and can always fall back to the newest complete one (torn writes are
detected via the index checksum).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Sequence, Tuple

import msgpack
import numpy as np

import jax
import jax.numpy as jnp


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree.structure(template), leaves)


def save_checkpoint(path: str, step: int, params, opt_state, dyn,
                    layers_per_stage: Sequence[int],
                    extra_meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomic save; returns the checkpoint directory."""
    ckdir = os.path.join(path, f"step_{step:08d}")
    tmp = ckdir + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    state = {"params": params, "opt": opt_state, "dyn": dyn}
    flat = _flatten_with_paths(state)
    # stage-sharded leaves (leading dim == num stages) go into per-stage
    # shards; the rest into a common shard
    S = len(layers_per_stage)
    common, per_stage = {}, [dict() for _ in range(S)]
    for k, v in flat.items():
        if v.ndim >= 1 and v.shape[0] == S and ("stages" in k or "dyn" in k
                                                or k.startswith("opt")):
            for s in range(S):
                per_stage[s][k] = v[s]
        else:
            common[k] = v
    np.savez(os.path.join(tmp, "common.npz"), **common)
    for s in range(S):
        np.savez(os.path.join(tmp, f"stage_{s:03d}.npz"), **per_stage[s])
    index = {
        "step": step,
        "layers_per_stage": list(map(int, layers_per_stage)),
        "num_stages": S,
        "files": ["common.npz"] + [f"stage_{s:03d}.npz" for s in range(S)],
        "meta": extra_meta or {},
    }
    digest = {}
    for f in index["files"]:
        with open(os.path.join(tmp, f), "rb") as fh:
            digest[f] = hashlib.sha256(fh.read()).hexdigest()
    index["sha256"] = digest
    with open(os.path.join(tmp, "index.msgpack"), "wb") as fh:
        fh.write(msgpack.packb(index))
    if os.path.exists(ckdir):
        shutil.rmtree(ckdir)
    os.rename(tmp, ckdir)
    return ckdir


def _verify(ckdir: str) -> Optional[Dict[str, Any]]:
    ipath = os.path.join(ckdir, "index.msgpack")
    if not os.path.exists(ipath):
        return None
    with open(ipath, "rb") as fh:
        index = msgpack.unpackb(fh.read(), strict_map_key=False)
    for f, want in index["sha256"].items():
        fp = os.path.join(ckdir, f)
        if not os.path.exists(fp):
            return None
        with open(fp, "rb") as fh:
            if hashlib.sha256(fh.read()).hexdigest() != want:
                return None
    return index


def latest_index(path: str, step: Optional[int] = None
                 ) -> Optional[Dict[str, Any]]:
    """Index of the newest *complete* checkpoint (or of ``step`` if given
    and complete) without loading any tensor data — the resume path reads
    this first to learn the stage count/layout it must build templates
    for.  Returns None when no complete checkpoint exists."""
    cands = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    if step is not None:
        cands = [d for d in cands if d == f"step_{step:08d}"] or cands
    for d in reversed(cands):
        index = _verify(os.path.join(path, d))
        if index is not None:
            return index
    return None


def load_checkpoint(path: str, templates: Tuple[Any, Any, Any],
                    step: Optional[int] = None):
    """Load (params, opt_state, dyn) matching the given templates.

    Falls back to the newest *complete* checkpoint when ``step`` is None or
    the requested one is torn."""
    cands = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    if step is not None:
        cands = [d for d in cands if d == f"step_{step:08d}"] or cands
    for d in reversed(cands):
        ckdir = os.path.join(path, d)
        index = _verify(ckdir)
        if index is None:
            continue
        flat = {}
        with np.load(os.path.join(ckdir, "common.npz")) as z:
            flat.update({k: z[k] for k in z.files})
        S = index["num_stages"]
        staged: Dict[str, List[np.ndarray]] = {}
        for s in range(S):
            with np.load(os.path.join(ckdir, f"stage_{s:03d}.npz")) as z:
                for k in z.files:
                    staged.setdefault(k, [None] * S)[s] = z[k]
        for k, parts in staged.items():
            flat[k] = np.stack(parts)
        state_t = {"params": templates[0], "opt": templates[1],
                   "dyn": templates[2]}
        state = _unflatten_like(state_t, flat)
        return (state["params"], state["opt"], state["dyn"], index)
    raise FileNotFoundError(f"no complete checkpoint under {path}")


class CheckpointManager:
    def __init__(self, path: str, keep: int = 3, every: int = 100):
        self.path, self.keep, self.every = path, keep, every
        os.makedirs(path, exist_ok=True)

    def maybe_save(self, step: int, params, opt_state, dyn,
                   layers_per_stage, extra_meta=None) -> Optional[str]:
        if step % self.every:
            return None
        out = save_checkpoint(self.path, step, params, opt_state, dyn,
                              layers_per_stage, extra_meta)
        self._gc()
        return out

    def _gc(self):
        cands = sorted(d for d in os.listdir(self.path)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in cands[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, d), ignore_errors=True)

    def restore(self, templates, step=None):
        return load_checkpoint(self.path, templates, step)
