from repro.checkpoint.checkpoint import (CheckpointManager, load_checkpoint,
                                         save_checkpoint)
from repro.checkpoint.elastic import elastic_restore

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "elastic_restore"]
