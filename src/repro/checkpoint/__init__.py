from repro.checkpoint.checkpoint import (CheckpointManager, latest_index,
                                         load_checkpoint, save_checkpoint)
from repro.checkpoint.elastic import elastic_restore
from repro.checkpoint.safepoint import SafepointManager

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "latest_index", "elastic_restore", "SafepointManager"]
