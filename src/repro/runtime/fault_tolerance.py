"""Fault tolerance & elasticity runtime.

At thousand-node scale, three mechanisms keep a DynMo job alive:

1. ``HeartbeatMonitor`` — per-worker liveness with configurable timeout; a
   missed heartbeat marks the worker dead and triggers the elastic-restart
   path (checkpoint restore onto the surviving mesh, repro.checkpoint.elastic).
2. ``StragglerDetector`` — per-stage step-time EMAs; a persistent slowdown
   (thermal throttle, noisy neighbor, flaky HBM) appears to DynMo *exactly*
   like load imbalance (paper §1: hardware-variability note), so the detector
   simply feeds a per-stage slowdown multiplier into the balancer's time
   vector and the ordinary rebalance absorbs the straggler.
3. ``WorkerPool`` — the job-manager interface: re-packing releases workers
   (paper §3.4.2, ECK-style), failures shrink the pool, recovered/granted
   workers grow it.  Here it is an in-process abstraction with the same API
   a k8s operator would expose (request / release / heartbeat).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Set

import numpy as np


class HeartbeatMonitor:
    def __init__(self, workers: int, timeout_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self._last = {w: clock() for w in range(workers)}
        self._lock = threading.Lock()
        self._failed: Set[int] = set()

    def beat(self, worker: int, at: Optional[float] = None) -> None:
        with self._lock:
            if worker in self._failed:
                return
            if worker not in self._last:
                # an unknown id must not silently grow the watch set — a
                # typo'd id would otherwise be tracked but never reported
                # failed for the real worker; ``revive`` is the only way to
                # (re-)register a worker after construction
                raise KeyError(
                    f"heartbeat from unregistered worker {worker!r} "
                    f"(known: {sorted(self._last)})")
            self._last[worker] = self.clock() if at is None else at

    def known_workers(self) -> Set[int]:
        with self._lock:
            return set(self._last)

    def failed_workers(self) -> Set[int]:
        now = self.clock()
        with self._lock:
            for w, t in self._last.items():
                if w not in self._failed and now - t > self.timeout:
                    self._failed.add(w)
            return set(self._failed)

    def expire(self, worker: int) -> None:
        """Mark a worker gone without waiting out the timeout — used when
        it leaves deliberately (released back to the job manager) rather
        than by crashing.  ``revive`` is the symmetric re-registration."""
        with self._lock:
            if worker in self._last:
                self._failed.add(worker)

    def revive(self, worker: int) -> None:
        with self._lock:
            self._failed.discard(worker)
            self._last[worker] = self.clock()


class StragglerDetector:
    """EMA of per-stage step times; exposes slowdown multipliers ≥ 1 that
    the controller multiplies into the by-time cost vector."""

    def __init__(self, num_stages: int, ema: float = 0.9,
                 threshold: float = 1.15):
        self.ema = ema
        self.threshold = threshold
        self.times = np.zeros(num_stages)
        self.initialized = False

    def reset(self, num_stages: int) -> None:
        """Forget the EMAs — required after an elastic resize (the stage
        set itself changed, old per-stage times are meaningless)."""
        self.times = np.zeros(num_stages)
        self.initialized = False

    def update(self, stage_times: np.ndarray) -> None:
        stage_times = np.asarray(stage_times, dtype=np.float64)
        if stage_times.shape != self.times.shape:
            self.reset(len(stage_times))
        if not self.initialized:
            self.times = stage_times.copy()
            self.initialized = True
        else:
            self.times = self.ema * self.times + (1 - self.ema) * stage_times

    def slowdown(self, expected: np.ndarray) -> np.ndarray:
        """Per-stage multiplier: measured / expected, clipped at 1 from
        below; > threshold flags a straggler."""
        expected = np.maximum(np.asarray(expected, dtype=np.float64), 1e-12)
        if not self.initialized:
            return np.ones_like(expected)
        return np.maximum(1.0, self.times / expected)

    def relative_slowdown(self, expected: np.ndarray) -> np.ndarray:
        """Scale-free variant of ``slowdown``: rescales ``expected`` to the
        measured total first, so a uniform calibration error in the cost
        model (absolute seconds off by a constant factor) does not read as
        every stage straggling — only *relative* skew between stages
        survives.  This is the multiplier the controller folds into the
        balancer's time cost vector."""
        expected = np.maximum(np.asarray(expected, dtype=np.float64), 1e-12)
        if not self.initialized:
            return np.ones_like(expected)
        scale = self.times.sum() / expected.sum()
        if scale <= 0:
            return np.ones_like(expected)
        return np.maximum(1.0, self.times / (expected * scale))

    def stragglers(self, expected: np.ndarray) -> List[int]:
        s = self.slowdown(expected)
        return [int(i) for i in np.nonzero(s > self.threshold)[0]]


@dataclasses.dataclass
class WorkerPool:
    """Job-manager facing pool (k8s/ECK stand-in).  DynMo's re-packing calls
    ``release``; failures call ``fail``; elastic growth calls ``request``.

    ``spares`` models the cluster provisioning *fresh* machines: when a
    ``request`` cannot be met from previously released workers, up to
    ``spares`` brand-new worker ids (never seen before — a NEW process, not
    a revived one) are minted.  The engine must treat such ids as unknown
    hardware and bind devices for them (DESIGN.md §12)."""
    total: int
    active: Optional[Set[int]] = None
    spares: int = 0

    def __post_init__(self):
        if self.active is None:
            self.active = set(range(self.total))
        self.released: Set[int] = set()
        self.dead: Set[int] = set()
        self.provisioned: Set[int] = set()
        self._next_id = (max(self.active) + 1 if self.active
                         else self.total)
        self.log: List[str] = []
        self._hooks: List[Callable[[str, int], None]] = []

    def subscribe(self, hook: Callable[[str, int], None]) -> None:
        """Register a release/acquire observer ``hook(event, worker)`` with
        event in {"release", "fail", "grant"} — the elastic engine subscribes
        to mirror pool transitions into its ``pool_events`` log; a k8s
        operator would translate them into scale-down/scale-up RPCs."""
        self._hooks.append(hook)

    def unsubscribe(self, hook: Callable[[str, int], None]) -> None:
        """Remove a hook (engines on a shared pool must detach on close so
        the pool doesn't pin them alive)."""
        if hook in self._hooks:
            self._hooks.remove(hook)

    def _notify(self, event: str, worker: int) -> None:
        self.log.append(f"{event}:{worker}")
        for h in self._hooks:
            h(event, worker)

    def release(self, workers) -> None:
        for w in workers:
            if w in self.active:
                self.active.discard(w)
                self.released.add(w)
                self._notify("release", w)

    def fail(self, worker: int) -> None:
        # a machine can die while idle too: scrub it from *every* live set,
        # not just active, or a later request() would re-grant a dead id
        # (the double-grant bug — see check_consistent)
        self.active.discard(worker)
        self.released.discard(worker)
        self.dead.add(worker)
        self._notify("fail", worker)

    def grant(self, workers) -> List[int]:
        """Promote specific *released* worker ids back to active — the
        cluster scheduler hands a preemption victim's workers to the
        stealing tenant by id, not by count."""
        granted = []
        for w in workers:
            if w in self.released:
                self.released.discard(w)
                self.active.add(w)
                granted.append(w)
                self._notify("grant", w)
            elif w not in self.active:
                raise ValueError(f"grant of unknown/dead worker {w}")
        return granted

    def request(self, n: int, exclude=()) -> List[int]:
        grant = []
        skip = set(exclude)
        for w in sorted(self.released):
            if len(grant) == n:
                break
            if w in skip:  # reserved for another tenant's pending steal
                continue
            grant.append(w)
        for w in grant:
            self.released.discard(w)
            self.active.add(w)
            self._notify("grant", w)
        # released workers exhausted: provision fresh machines from the
        # spare budget — each arrives as a NEVER-seen worker id
        while len(grant) < n and len(self.provisioned) < self.spares:
            w = self._next_id
            self._next_id += 1
            self.provisioned.add(w)
            self.active.add(w)
            grant.append(w)
            self._notify("grant", w)
        return grant

    def check_consistent(self) -> None:
        """Every worker id lives in exactly one of active/released/dead —
        overlap means some path can hand the same machine to two owners.
        Cheap (sets are small); callers with correctness at stake run it
        after every transition."""
        for a, b in (("active", "released"), ("active", "dead"),
                     ("released", "dead")):
            both = getattr(self, a) & getattr(self, b)
            if both:
                raise AssertionError(
                    f"worker(s) {sorted(both)} in both {a} and {b}")

    @property
    def num_active(self) -> int:
        return len(self.active)

    # -- persistence (job-manager journal / trainer safe points) -----------
    def state_dict(self) -> dict:
        return {"total": self.total, "spares": self.spares,
                "active": sorted(self.active),
                "released": sorted(self.released),
                "dead": sorted(self.dead),
                "provisioned": sorted(self.provisioned),
                "next_id": self._next_id}

    @classmethod
    def from_state(cls, sd: dict) -> "WorkerPool":
        pool = cls(int(sd["total"]), active=set(sd["active"]),
                   spares=int(sd.get("spares", 0)))
        pool.released = set(sd["released"])
        pool.dead = set(sd["dead"])
        pool.provisioned = set(sd.get("provisioned", []))
        pool._next_id = int(sd["next_id"])
        return pool
