"""Gradient compression for the data-parallel reduce (distributed-optimization
trick for 1000+-node scale).

Two codecs with error feedback:
  * top-k sparsification (indices + values; k as a fraction),
  * int8 linear quantization (per-tensor scale).

``compressed_psum`` wraps a psum over a named axis: quantize → psum →
dequantize; with top-k the all-reduce becomes a dense psum over the
scattered-back sparse tensor (TPU collectives are dense — the win is the
bf16→int8 byte ratio or the k/N sparsity inside a scatter; documented).
Error feedback state makes both codecs convergence-safe (residual carried to
the next step).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def compress_topk(g: jax.Array, frac: float = 0.05
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (values, indices, residual).  Flattens g."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    picked = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(g.shape)
    return picked, idx, residual


def decompress_topk(vals: jax.Array, idx: jax.Array, shape,
                    dtype=jnp.float32) -> jax.Array:
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    flat = flat.at[idx].add(vals)
    return flat.reshape(shape).astype(dtype)


def int8_quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(g: jax.Array, axis_name: str, method: str = "int8",
                    err: Optional[jax.Array] = None, frac: float = 0.05
                    ) -> Tuple[jax.Array, jax.Array]:
    """psum with lossy compression + error feedback.

    Returns (reduced, new_error).  ``err`` is the carried residual."""
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    if method == "int8":
        q, scale = int8_quantize(gf)
        # scale must be common across ranks: take the max scale
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        red_q = jax.lax.psum(q.astype(jnp.int32), axis_name)
        red = red_q.astype(jnp.float32) * scale
        new_err = gf - q.astype(jnp.float32) * scale
    elif method == "topk":
        vals, idx, new_err = compress_topk(gf, frac)
        sparse = decompress_topk(vals, idx, gf.shape)
        red = jax.lax.psum(sparse, axis_name)
    else:
        red = jax.lax.psum(gf, axis_name)
        new_err = jnp.zeros_like(gf)
    return red.astype(g.dtype), new_err
