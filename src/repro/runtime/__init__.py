from repro.runtime.fault_tolerance import (HeartbeatMonitor, StragglerDetector,
                                           WorkerPool)
from repro.runtime.compression import (compress_topk, decompress_topk,
                                       int8_quantize, int8_dequantize,
                                       compressed_psum)

__all__ = ["HeartbeatMonitor", "StragglerDetector", "WorkerPool",
           "compress_topk", "decompress_topk", "int8_quantize",
           "int8_dequantize", "compressed_psum"]
