"""Unified slot-block layer.

Every architecture is expressed as a sequence of *blocks* drawn from a small
type set (configs.base.BLOCK_*).  A pipeline stage owns ``L_max`` slots; each
slot holds the **union** of the arch's per-type parameter fields plus a
runtime type tag, so the layer→stage assignment can change at runtime
(DynMo migration) without recompilation.

Public interface
  slot_param_spec(cfg)            -> {field: ShapeDtypeStruct}   (per slot)
  shared_param_spec(cfg)          -> {field: ShapeDtypeStruct}   (per model)
  slot_cache_spec(cfg, mb, clen)  -> {field: ShapeDtypeStruct}   (per slot)
  init_slot / init_shared         -> concrete params
  apply_block(...)                -> (y, new_cache, stats)

``mode`` is static ("train" | "prefill" | "decode"); the block type tag is a
runtime int32 — multi-type archs dispatch with lax.switch.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    BLOCK_DEC, BLOCK_DENSE, BLOCK_ENC, BLOCK_HYBRID_ATTN, BLOCK_MAMBA,
    BLOCK_MLSTM, BLOCK_MOE, BLOCK_PAD, BLOCK_SLSTM, ModelConfig,
)
from repro.models import mamba as mamba_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (
    apply_rope, decode_attention, expand_ff_mask as _expand_ff_mask,
    flash_attention, gelu_mlp, pin_batch, rms_norm, swiglu,
)

PRUNE_BLOCK = 128      # block-structured pruning granularity (MXU tile width)
MAMBA_HEAD = 64
MOE_CAPACITY_FACTOR = 1.25


# ---------------------------------------------------------------------------
# Dimension helpers
# ---------------------------------------------------------------------------
def _dims(cfg: ModelConfig) -> Dict[str, int]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    d_in = 2 * d
    return dict(
        d=d, hd=hd, nq=cfg.num_heads, nkv=cfg.num_kv_heads, ff=cfg.d_ff,
        d_in=d_in, nh_m=max(1, d_in // MAMBA_HEAD),
        conv_dim=d_in + 2 * cfg.ssm_state,
        nh_x=cfg.num_heads, dh_x=d_in // max(1, cfg.num_heads),
        st=cfg.ssm_state, E=cfg.num_experts,
    )


def prunable_dim(cfg: ModelConfig) -> int:
    """Feature dimension subject to block-structured pruning."""
    if cfg.d_ff > 0:
        return cfg.d_ff
    return 2 * 2 * cfg.d_model       # mLSTM up-projection (2*d_in)


def n_prune_blocks(cfg: ModelConfig) -> int:
    return max(1, prunable_dim(cfg) // PRUNE_BLOCK)


def block_type_set(cfg: ModelConfig) -> Tuple[int, ...]:
    return tuple(sorted(set(cfg.block_pattern())))


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def slot_param_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    m = _dims(cfg)
    types = block_type_set(cfg)
    spec: Dict[str, Any] = {}
    d, hd, nq, nkv, ff = m["d"], m["hd"], m["nq"], m["nkv"], m["ff"]
    if BLOCK_DENSE in types or BLOCK_MOE in types:
        spec.update(
            attn_norm=_sds([d], dtype), wq=_sds([d, nq * hd], dtype),
            wk=_sds([d, nkv * hd], dtype), wv=_sds([d, nkv * hd], dtype),
            wo=_sds([nq * hd, d], dtype), ffn_norm=_sds([d], dtype))
    if BLOCK_DENSE in types:
        spec.update(wi=_sds([d, ff], dtype), wg=_sds([d, ff], dtype),
                    wof=_sds([ff, d], dtype))
    if BLOCK_MOE in types:
        E = m["E"]
        spec.update(router=_sds([d, E], jnp.float32),
                    ewi=_sds([E, d, ff], dtype), ewg=_sds([E, d, ff], dtype),
                    ewo=_sds([E, ff, d], dtype))
    if BLOCK_MAMBA in types or BLOCK_HYBRID_ATTN in types:
        d_in, nh, cdim, st = m["d_in"], m["nh_m"], m["conv_dim"], m["st"]
        spec.update(
            m_norm=_sds([d], dtype),
            m_in=_sds([d, 2 * d_in + 2 * st + nh], dtype),
            m_convw=_sds([cfg.d_conv, cdim], dtype),
            m_convb=_sds([cdim], dtype),
            m_Alog=_sds([nh], jnp.float32), m_D=_sds([nh], jnp.float32),
            m_dtb=_sds([nh], jnp.float32), m_out=_sds([d_in, d], dtype))
    if BLOCK_MLSTM in types:
        d_in, nh, dh = m["d_in"], m["nh_x"], m["dh_x"]
        spec.update(
            x_norm=_sds([d], dtype), x_up=_sds([d, 2 * d_in], dtype),
            x_q=_sds([nh, dh, dh], dtype), x_k=_sds([nh, dh, dh], dtype),
            x_v=_sds([nh, dh, dh], dtype),
            x_ig=_sds([d_in, nh], jnp.float32),
            x_fg=_sds([d_in, nh], jnp.float32),
            x_down=_sds([d_in, d], dtype), x_gnorm=_sds([d_in], dtype))
    if BLOCK_SLSTM in types:
        ffp = max(PRUNE_BLOCK, (4 * d // 3) // PRUNE_BLOCK * PRUNE_BLOCK)
        spec.update(
            s_norm=_sds([d], dtype), s_wx=_sds([d, 4 * d], dtype),
            s_r=_sds([4, d], jnp.float32), s_out=_sds([d, d], dtype),
            s_fnorm=_sds([d], dtype), s_up=_sds([d, 2 * ffp], dtype),
            s_down=_sds([ffp, d], dtype))
    if BLOCK_ENC in types:
        spec.update(
            e_ln1=_sds([d], dtype), e_ln1b=_sds([d], dtype),
            e_wq=_sds([d, nq * hd], dtype), e_bq=_sds([nq * hd], dtype),
            e_wk=_sds([d, nkv * hd], dtype),
            e_wv=_sds([d, nkv * hd], dtype), e_bv=_sds([nkv * hd], dtype),
            e_wo=_sds([nq * hd, d], dtype), e_bo=_sds([d], dtype),
            e_ln2=_sds([d], dtype), e_ln2b=_sds([d], dtype),
            e_w1=_sds([d, ff], dtype), e_b1=_sds([ff], dtype),
            e_w2=_sds([ff, d], dtype), e_b2=_sds([d], dtype))
    if BLOCK_DEC in types:
        spec.update(
            d_ln1=_sds([d], dtype), d_ln1b=_sds([d], dtype),
            d_wq=_sds([d, nq * hd], dtype), d_bq=_sds([nq * hd], dtype),
            d_wk=_sds([d, nkv * hd], dtype),
            d_wv=_sds([d, nkv * hd], dtype), d_bv=_sds([nkv * hd], dtype),
            d_wo=_sds([nq * hd, d], dtype), d_bo=_sds([d], dtype),
            d_ln2=_sds([d], dtype), d_ln2b=_sds([d], dtype),
            c_wq=_sds([d, nq * hd], dtype), c_bq=_sds([nq * hd], dtype),
            c_wk=_sds([d, nkv * hd], dtype),
            c_wv=_sds([d, nkv * hd], dtype), c_bv=_sds([nkv * hd], dtype),
            c_wo=_sds([nq * hd, d], dtype), c_bo=_sds([d], dtype),
            d_ln3=_sds([d], dtype), d_ln3b=_sds([d], dtype),
            d_w1=_sds([d, ff], dtype), d_b1=_sds([ff], dtype),
            d_w2=_sds([ff, d], dtype), d_b2=_sds([d], dtype))
    return spec


def shared_param_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Model-level (non-slot) params beyond embed/head/final_norm."""
    m = _dims(cfg)
    spec: Dict[str, Any] = {}
    if cfg.family == "hybrid" and cfg.shared_attn_period:
        d, hd, nq, nkv = m["d"], m["hd"], m["nq"], m["nkv"]
        spec.update(
            ga_norm=_sds([d], dtype), ga_wq=_sds([d, nq * hd], dtype),
            ga_wk=_sds([d, nkv * hd], dtype), ga_wv=_sds([d, nkv * hd], dtype),
            ga_wo=_sds([nq * hd, d], dtype))
    if cfg.is_encdec:
        spec.update(dec_pos=_sds([cfg.max_seq_len, m["d"]], dtype))
    return spec


def slot_cache_spec(cfg: ModelConfig, mb: int, cache_len: int,
                    dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Per-slot decode cache (union over the arch's type set).

    ``cache_len``: cache capacity.  Sliding-window archs get a ring buffer of
    min(cache_len, window)."""
    m = _dims(cfg)
    types = block_type_set(cfg)
    spec: Dict[str, Any] = {}
    nkv, hd = m["nkv"], m["hd"]
    cap = cache_len
    if cfg.sliding_window:
        cap = min(cache_len, cfg.sliding_window)
    if any(t in types for t in (BLOCK_DENSE, BLOCK_MOE, BLOCK_HYBRID_ATTN,
                                BLOCK_DEC, BLOCK_ENC)):
        spec.update(k=_sds([mb, cap, nkv, hd], dtype),
                    v=_sds([mb, cap, nkv, hd], dtype))
    if BLOCK_DEC in types:
        spec.update(ck=_sds([mb, cfg.encoder_seq, nkv, hd], dtype),
                    cv=_sds([mb, cfg.encoder_seq, nkv, hd], dtype))
    if BLOCK_MAMBA in types or BLOCK_HYBRID_ATTN in types:
        spec.update(
            conv=_sds([mb, cfg.d_conv - 1, m["conv_dim"]], dtype),
            ssm=_sds([mb, m["nh_m"], MAMBA_HEAD, m["st"]], jnp.float32))
    if BLOCK_MLSTM in types:
        nh, dh = m["nh_x"], m["dh_x"]
        spec.update(xC=_sds([mb, nh, dh, dh], jnp.float32),
                    xn=_sds([mb, nh, dh], jnp.float32),
                    xm=_sds([mb, nh], jnp.float32))
    if BLOCK_SLSTM in types:
        d = m["d"]
        spec.update(sc=_sds([mb, d], jnp.float32),
                    sn=_sds([mb, d], jnp.float32),
                    sm=_sds([mb, d], jnp.float32),
                    sh=_sds([mb, d], jnp.float32))
    return spec


def paged_slot_cache_spec(cfg: ModelConfig, pool_pages: int, page_size: int,
                          dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Per-slot block-paged decode cache: one physical K/V block pool shared
    by every lane of the slot, indexed through per-lane page tables.

    Layout ``[pool_pages + 1, page_size, n_kv, head_dim]`` — the final block
    is the trash block absorbing count-gated writes (invalid micro ticks,
    unmapped lanes).  Only attention-pure decoder archs page their cache:
    recurrent state (mamba/xlstm) is O(1) per lane and sliding-window caches
    are already rings.
    """
    m = _dims(cfg)
    types = set(block_type_set(cfg))
    if not types <= {BLOCK_DENSE, BLOCK_MOE}:
        raise ValueError(
            f"paged KV requires an attention-only arch, got types {types}")
    if cfg.sliding_window:
        raise ValueError("paged KV does not support sliding-window caches")
    nkv, hd = m["nkv"], m["hd"]
    return dict(kp=_sds([pool_pages + 1, page_size, nkv, hd], dtype),
                vp=_sds([pool_pages + 1, page_size, nkv, hd], dtype))


def stats_spec(cfg: ModelConfig) -> Dict[str, Any]:
    E = max(1, cfg.num_experts)
    return dict(expert_load=_sds([E], jnp.float32),
                moe_dropped=_sds([], jnp.float32),
                ff_active=_sds([], jnp.float32),
                attn_density=_sds([], jnp.float32))


def _zero_stats(cfg: ModelConfig) -> Dict[str, jax.Array]:
    return {k: jnp.zeros(v.shape, v.dtype)
            for k, v in stats_spec(cfg).items()}


def init_slot(rng: jax.Array, cfg: ModelConfig,
              dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    spec = slot_param_spec(cfg, dtype)
    out = {}
    keys = jax.random.split(rng, len(spec))
    for k_, (name, sds) in zip(keys, sorted(spec.items())):
        if name.endswith(("norm", "gnorm", "fnorm")) or name.startswith(
                ("e_ln", "d_ln")) and not name.endswith("b"):
            out[name] = jnp.ones(sds.shape, sds.dtype)
        elif name.endswith(("b", "_bq", "_bv", "_bo")) or name in (
                "m_convb", "m_dtb"):
            out[name] = jnp.zeros(sds.shape, sds.dtype)
        elif name == "m_Alog":
            out[name] = jnp.log(jnp.linspace(1.0, 16.0, sds.shape[0]))
        elif name == "m_D":
            out[name] = jnp.ones(sds.shape, sds.dtype)
        elif name == "s_r":
            out[name] = jnp.zeros(sds.shape, sds.dtype)
        elif name in ("x_ig", "x_fg"):
            base = 3.0 if name == "x_fg" else -1.0
            out[name] = (jax.random.normal(k_, sds.shape, sds.dtype) * 0.02
                         + base)
        else:
            fan_in = sds.shape[-2] if len(sds.shape) >= 2 else sds.shape[-1]
            out[name] = (jax.random.normal(k_, sds.shape, jnp.float32)
                         * (0.02 if fan_in <= 0 else fan_in ** -0.5)
                         ).astype(sds.dtype)
    return out


def init_shared(rng: jax.Array, cfg: ModelConfig,
                dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    spec = shared_param_spec(cfg, dtype)
    out = {}
    keys = jax.random.split(rng, max(1, len(spec)))
    for k_, (name, sds) in zip(keys, sorted(spec.items())):
        if name.endswith("norm"):
            out[name] = jnp.ones(sds.shape, sds.dtype)
        else:
            fan_in = sds.shape[-2] if len(sds.shape) >= 2 else sds.shape[-1]
            out[name] = (jax.random.normal(k_, sds.shape, jnp.float32)
                         * fan_in ** -0.5).astype(sds.dtype)
    return out


# ---------------------------------------------------------------------------
# Hash-based dynamic block sparsity (paper §2.4 / §4.2.4, TPU-adapted)
# ---------------------------------------------------------------------------
def hash_block_mask(x, *, nbuckets: int, block: int, causal: bool = True):
    """Content-based block mask from sign-random-projection hashing.

    x: [b, s, d].  Tokens are bucketed by the hash of their block-mean hidden
    state; attention is restricted to (q-block, kv-block) pairs whose buckets
    match, plus the local diagonal band (exactness of nearby context).
    Returns mask [b, 1, nqb, nkb] float and the achieved density.
    """
    b, s, d = x.shape
    nb = max(1, s // block)
    xb = x[:, :nb * block].reshape(b, nb, block, d).mean(axis=2)
    xb = xb.astype(jnp.float32)
    nbits = max(1, int(nbuckets - 1).bit_length())
    # fixed pseudo-random projection (deterministic across steps)
    proj = jax.random.normal(jax.random.PRNGKey(17), (d, nbits), jnp.float32)
    bits = (xb @ proj) > 0                                     # [b, nb, nbits]
    bucket = jnp.sum(bits * (2 ** jnp.arange(nbits)), axis=-1) % nbuckets
    same = bucket[:, :, None] == bucket[:, None, :]            # [b, nb, nb]
    band = jnp.abs(jnp.arange(nb)[:, None] - jnp.arange(nb)[None, :]) <= 1
    mask = same | band[None]
    if causal:
        mask &= (jnp.arange(nb)[:, None] >= jnp.arange(nb)[None, :])
        denom = jnp.sum(jnp.tril(jnp.ones((nb, nb))))
    else:
        denom = float(nb * nb)
    density = jnp.sum(mask.astype(jnp.float32), axis=(1, 2)).mean() / denom
    return mask[:, None].astype(jnp.float32), density


# ---------------------------------------------------------------------------
# Attention core shared by dense/moe/hybrid/whisper blocks
# ---------------------------------------------------------------------------
def _attn_fwd(x, wq, wk, wv, wo, *, cfg, mode, cache, pos,
              rope: bool = True, causal: bool = True,
              block_mask=None, bq=None, bv=None, bo=None,
              kv_override=None, cache_keys=("k", "v"), dyncfg=None,
              kernel_impl: str = "scan"):
    """GQA attention with optional RoPE/SWA/bias/cache.  x: [mb, s, d];
    pos: [s] absolute positions (train/prefill) or scalar (decode).
    Returns (out, new_cache, density)."""
    m = _dims(cfg)
    nq, nkv, hd = m["nq"], m["nkv"], m["hd"]
    b, s, _ = x.shape
    density = jnp.float32(1.0)
    kv_block = 512
    if (dyncfg is not None and dyncfg.uses_sparse_attention
            and mode != "decode" and block_mask is None
            and s >= 2 * dyncfg.sparse_block):
        block_mask, density = hash_block_mask(
            x, nbuckets=dyncfg.sparse_nbuckets, block=dyncfg.sparse_block,
            causal=causal)
        kv_block = dyncfg.sparse_block
    q = (x @ wq)
    if bq is not None:
        q = q + bq
    q = q.reshape(b, s, nq, hd)
    if kv_override is not None:
        xkv = kv_override
    else:
        xkv = x
    k = (xkv @ wk).reshape(b, xkv.shape[1], nkv, hd)
    v = (xkv @ wv)
    if bv is not None:
        v = v + bv
    v = v.reshape(b, xkv.shape[1], nkv, hd)

    new_cache = cache
    if mode == "decode" and cache is not None and "kp" in cache:
        # block-paged cache: one physical pool per slot, per-lane page
        # tables.  Write the new K/V through the table (gated writes land in
        # the trash block), then attend by gathering blocks.
        kp, vp = cache["kp"], cache["vp"]
        pt = cache["pt"]                      # [b, J] int32, -1 = unmapped
        wok = cache["wok"]                    # scalar: tick carries live data
        page = kp.shape[1]
        trash = kp.shape[0] - 1
        cap = pt.shape[1] * page
        pvec = jnp.broadcast_to(jnp.reshape(pos, (-1,)), (b,))
        if rope:
            q = apply_rope(q, pvec[:, None], cfg.rope_theta)
            k = apply_rope(k, pvec[:, None], cfg.rope_theta)
        pw = jnp.minimum(pvec, cap - 1)
        lanes = jnp.arange(b)
        blk = pt[lanes, pw // page]
        ok = (wok > 0) & (blk >= 0)
        blk_eff = jnp.where(ok, blk, trash)
        off = pw % page
        kp = kp.at[blk_eff, off].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[blk_eff, off].set(v[:, 0].astype(vp.dtype))
        clen = jnp.minimum(pvec + 1, cap)
        if kernel_impl == "pallas":
            from repro.kernels.paged_attention import paged_attention
            interpret = jax.default_backend() != "tpu"
            out = paged_attention(q, kp, vp, pt, clen, interpret=interpret)
        else:
            from repro.kernels.paged_attention import paged_attention_ref
            out = paged_attention_ref(q, kp, vp, pt, clen)
        new_cache = dict(cache)
        new_cache["kp"] = kp
        new_cache["vp"] = vp
    elif mode == "decode":
        kc, vc = cache[cache_keys[0]], cache[cache_keys[1]]
        cap = kc.shape[1]
        if jnp.ndim(pos) == 0:
            # pos is a scalar: every lane at the same absolute position
            if rope:
                q = apply_rope(q, jnp.full((b, 1), pos), cfg.rope_theta)
                k = apply_rope(k, jnp.full((b, 1), pos), cfg.rope_theta)
            widx = jnp.mod(pos, cap) if cfg.sliding_window else jnp.minimum(
                pos, cap - 1)
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                              (0, widx, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                              (0, widx, 0, 0))
            clen = jnp.minimum(pos + 1, cap)
        else:
            # pos is a [b] vector: continuous batching — each request
            # writes its cache line and masks attention at its OWN position
            pvec = jnp.reshape(pos, (b,))
            if rope:
                q = apply_rope(q, pvec[:, None], cfg.rope_theta)
                k = apply_rope(k, pvec[:, None], cfg.rope_theta)
            widx = (jnp.mod(pvec, cap) if cfg.sliding_window
                    else jnp.minimum(pvec, cap - 1))
            lanes = jnp.arange(b)
            kc = kc.at[lanes, widx].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[lanes, widx].set(v[:, 0].astype(vc.dtype))
            clen = jnp.minimum(pvec + 1, cap)
        out = decode_attention(q, kc, vc, clen)
        new_cache = dict(cache)
        new_cache[cache_keys[0]] = kc
        new_cache[cache_keys[1]] = vc
    else:
        if rope:
            pq = jnp.broadcast_to(pos[None, :], (b, s))
            q = apply_rope(q, pq, cfg.rope_theta)
            pk = jnp.broadcast_to(pos[None, :xkv.shape[1]], (b, xkv.shape[1]))
            k = apply_rope(k, pk, cfg.rope_theta)
        out = flash_attention(q, k, v, causal=causal,
                              sliding_window=cfg.sliding_window,
                              block_mask=block_mask, kv_block=kv_block,
                              impl=kernel_impl)
        if mode == "prefill" and cache is not None:
            kc, vc = cache[cache_keys[0]], cache[cache_keys[1]]
            cap = kc.shape[1]
            new_cache = dict(cache)
            if cap >= s:
                new_cache[cache_keys[0]] = jax.lax.dynamic_update_slice(
                    kc, k.astype(kc.dtype), (0, 0, 0, 0))
                new_cache[cache_keys[1]] = jax.lax.dynamic_update_slice(
                    vc, v.astype(vc.dtype), (0, 0, 0, 0))
            else:                       # ring buffer: keep last `cap`
                new_cache[cache_keys[0]] = k[:, -cap:].astype(kc.dtype)
                new_cache[cache_keys[1]] = v[:, -cap:].astype(vc.dtype)
    out = pin_batch(out.reshape(b, out.shape[1], nq * hd) @ wo)
    if bo is not None:
        out = out + bo
    return out, new_cache, density


# ---------------------------------------------------------------------------
# MoE FFN (GShard-style capacity dispatch, cumsum position-in-expert)
# ---------------------------------------------------------------------------
def moe_ffn(p, x, cfg: ModelConfig, *, kernel_impl: str = "scan",
            expert_map=None):
    """x: [mb, s, d] -> (y, expert_load [E], aux_loss, dropped_frac).

    Top-k routing with capacity; dispatch is vmapped per batch row to keep
    sorting/scatters shard-local.  Routing (top-k, cumsum
    position-in-expert, capacity drops) is IDENTICAL for every impl —
    only the expert compute differs:

      "reference"/"scan": the dense GShard capacity einsum over the
        zero-padded [b, E, cap, d] buffer — every expert pays full
        capacity-sized FLOPs (the numeric oracle).
      "pallas": sort -> grouped ragged matmul -> unsort; each expert group
        costs row tiles proportional to its measured routed load (empty
        experts skip all tile work).  ``expert_map`` ([E] float, logical
        expert -> physical group; None = identity) permutes only the
        *physical group ordering* inside the kernel: per-token math is
        row-wise, so y is bit-identical under any placement — a live expert
        re-layout never perturbs training.  s == 1 (decode) takes the same
        path: the PR 1 dense fallback does not apply here.

    ``dropped_frac`` is the capacity-overflow drop fraction of routed
    (token, expert) pairs this call — same routing ⇒ same drops on every
    impl (asserted in tests)."""
    E, K = cfg.num_experts, cfg.experts_per_token
    b, s, d = x.shape
    cf = cfg.moe_capacity_factor or MOE_CAPACITY_FACTOR
    cap = int(cf * s * K / E + 0.999)
    cap = max(4, min(s, (cap + 3) // 4 * 4))

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # [b,s,E]
    w, sel = jax.lax.top_k(probs, K)                           # [b,s,K]
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)

    def route_row(selr, wr):
        # selr, wr: [s,K] -> flattened k-major routing decisions
        flat_e = selr.T.reshape(-1)                            # k-major [K*s]
        flat_t = jnp.tile(jnp.arange(s), (K,))
        flat_w = wr.T.reshape(-1)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # [K*s, E]
        pos = jnp.cumsum(oh, axis=0) - oh                      # exclusive
        pos = jnp.sum(pos * oh, axis=-1)                       # [K*s]
        keep = pos < cap
        return flat_e, flat_t, flat_w, pos, keep

    if kernel_impl == "pallas":
        from repro.kernels.grouped_matmul import grouped_matmul
        interpret = jax.default_backend() != "tpu"
        if expert_map is None:
            pm = jnp.arange(E, dtype=jnp.int32)
        else:
            pm = expert_map.astype(jnp.int32)                  # [E] perm

        def dispatch_row(xr, selr, wr):
            flat_e, flat_t, flat_w, pos, keep = route_row(selr, wr)
            phys = pm[flat_e]
            slot = jnp.where(keep, phys * cap + pos, E * cap)
            buf = jnp.zeros((E * cap + 1, d), xr.dtype)
            buf = buf.at[slot].add(xr[flat_t])
            cnt = jnp.sum(jax.nn.one_hot(phys, E, dtype=jnp.int32)
                          * keep[:, None], axis=0)             # [E] kept
            return buf[:E * cap].reshape(E, cap, d), cnt, \
                (flat_t, flat_w, slot, keep)

        buf, cnt, aux = jax.vmap(dispatch_row)(x, sel, w)      # [b,E,cap,d]
        xg = buf.reshape(b * E, cap, d)                        # batch-major
        counts = cnt.reshape(b * E)
        # physical group g (= bi*E + p) runs the LOGICAL expert mapped to
        # it: gather weights through the inverse placement
        inv = jnp.zeros((E,), jnp.int32).at[pm].set(
            jnp.arange(E, dtype=jnp.int32))
        gmm = lambda a, wg: grouped_matmul(a, wg, counts,
                                           interpret=interpret)
        h = gmm(xg, p["ewg"][inv])
        h = jax.nn.silu(h) * gmm(xg, p["ewi"][inv])
        out = gmm(h.astype(xg.dtype), p["ewo"][inv])
        out = out.reshape(b, E, cap, d)
    else:
        def dispatch_row(xr, selr, wr):
            flat_e, flat_t, flat_w, pos, keep = route_row(selr, wr)
            slot = jnp.where(keep, flat_e * cap + pos, E * cap)
            buf = jnp.zeros((E * cap + 1, d), xr.dtype)
            buf = buf.at[slot].add(xr[flat_t])
            buf = buf[:E * cap].reshape(E, cap, d)
            return buf, (flat_t, flat_w, slot, keep)

        buf, aux = jax.vmap(dispatch_row)(x, sel, w)           # [b,E,cap,d]
        h = jnp.einsum("becd,edf->becf", buf, p["ewg"])
        h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", buf, p["ewi"])
        out = jnp.einsum("becf,efd->becd", h, p["ewo"])        # [b,E,cap,d]

    def combine_row(outr, auxr):
        flat_t, flat_w, slot, keep = auxr
        outf = outr.reshape(E * cap, d)
        vals = outf[jnp.minimum(slot, E * cap - 1)]
        vals = vals * (flat_w * keep)[:, None].astype(vals.dtype)
        y = jnp.zeros((s, d), outr.dtype).at[flat_t].add(vals)
        return y

    y = jax.vmap(combine_row)(out, aux)
    load = jnp.sum(jax.nn.one_hot(sel, E), axis=(0, 1, 2))     # [E]
    # capacity-overflow drops: routed (token, expert) pairs past each
    # expert's cap (previously silent) — keep masks are identical across
    # impls, so this is impl-independent by construction
    keep_all = jax.vmap(lambda selr, wr: route_row(selr, wr)[4])(sel, w)
    dropped = 1.0 - jnp.mean(keep_all.astype(jnp.float32))
    # auxiliary load-balancing loss (Mixtral-style), returned via stats
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = load / jnp.maximum(jnp.sum(load), 1.0)
    aux_loss = E * jnp.sum(me * ce)
    return y, load, aux_loss, dropped


# ---------------------------------------------------------------------------
# Per-type block forward
# ---------------------------------------------------------------------------
def _dense_block(p, x, *, cfg, mode, cache, pos, dyn, dyncfg,
                 kernel_impl="scan"):
    h, cache, density = _attn_fwd(
        rms_norm(x, p["attn_norm"], cfg.norm_eps),
        p["wq"], p["wk"], p["wv"], p["wo"], cfg=cfg, mode=mode,
        cache=cache, pos=pos, dyncfg=dyncfg, kernel_impl=kernel_impl)
    x = x + h
    hn = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    # block-level mask: layers.swiglu expands for the dense impls and feeds
    # the pallas impl's tile gating directly
    ff_mask = dyn["ff_mask"] if cfg.d_ff else None
    x = x + swiglu(hn, p["wi"], p["wg"], p["wof"], ff_mask,
                   impl=kernel_impl)
    stats = _zero_stats(cfg)
    stats["ff_active"] = jnp.mean(dyn["ff_mask"])
    stats["attn_density"] = density
    return x, cache, stats, jnp.float32(0.0)


def _moe_block(p, x, *, cfg, mode, cache, pos, dyn, dyncfg,
               kernel_impl="scan"):
    h, cache, density = _attn_fwd(
        rms_norm(x, p["attn_norm"], cfg.norm_eps),
        p["wq"], p["wk"], p["wv"], p["wo"], cfg=cfg, mode=mode,
        cache=cache, pos=pos, dyncfg=dyncfg, kernel_impl=kernel_impl)
    x = x + h
    hn = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    y, load, aux_loss, dropped = moe_ffn(
        p, hn, cfg, kernel_impl=kernel_impl,
        expert_map=dyn.get("expert_map"))
    x = x + y
    stats = _zero_stats(cfg)
    stats["expert_load"] = load
    stats["moe_dropped"] = dropped
    stats["ff_active"] = jnp.float32(1.0)
    stats["attn_density"] = density
    return x, cache, stats, aux_loss


def _mamba_block(p, x, *, cfg, mode, cache, pos, dyn, shared=None,
                 with_shared_attn=False, dyncfg=None, kernel_impl="scan"):
    m = _dims(cfg)
    d_in, nh, st = m["d_in"], m["nh_m"], m["st"]
    b, s, _ = x.shape
    hn = rms_norm(x, p["m_norm"], cfg.norm_eps)
    proj = hn @ p["m_in"]                                      # [b,s,...]
    z, xs, B, C, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + st, 2 * d_in + 2 * st], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["m_dtb"])
    conv_in = jnp.concatenate([xs, B, C], axis=-1)
    if mode == "decode":
        conv_out, conv_state = mamba_lib.causal_conv(
            conv_in, p["m_convw"], p["m_convb"], state=cache["conv"])
    else:
        conv_out, conv_state = mamba_lib.causal_conv(
            conv_in, p["m_convw"], p["m_convb"])
    xs, B, C = jnp.split(conv_out, [d_in, d_in + st], axis=-1)
    xh = xs.reshape(b, s, nh, MAMBA_HEAD)
    if mode == "decode":
        y, ssm = mamba_lib.ssd_decode_step(
            xh[:, 0], dt[:, 0], p["m_Alog"], B[:, 0], C[:, 0], p["m_D"],
            cache["ssm"])
        y = y[:, None]
    else:
        init = None
        y, ssm = mamba_lib.ssd_chunked(xh, dt, p["m_Alog"], B, C, p["m_D"],
                                       init_state=init)
    y = y.reshape(b, s, d_in) * jax.nn.silu(z)
    x = x + y @ p["m_out"]
    new_cache = cache
    if mode in ("decode", "prefill") and cache is not None:
        new_cache = dict(cache)
        new_cache["conv"] = conv_state.astype(cache["conv"].dtype)
        new_cache["ssm"] = ssm
    if with_shared_attn:
        h, new_cache, _ = _attn_fwd(
            rms_norm(x, shared["ga_norm"], cfg.norm_eps),
            shared["ga_wq"], shared["ga_wk"], shared["ga_wv"],
            shared["ga_wo"], cfg=cfg, mode=mode,
            cache=new_cache, pos=pos, dyncfg=dyncfg,
            kernel_impl=kernel_impl)
        x = x + h
    stats = _zero_stats(cfg)
    stats["ff_active"] = jnp.float32(1.0)
    return x, new_cache, stats, jnp.float32(0.0)


def _mlstm_block(p, x, *, cfg, mode, cache, pos, dyn):
    m = _dims(cfg)
    d_in, nh, dh = m["d_in"], m["nh_x"], m["dh_x"]
    b, s, _ = x.shape
    hn = rms_norm(x, p["x_norm"], cfg.norm_eps)
    up = hn @ p["x_up"]
    u, z = jnp.split(up, 2, axis=-1)                           # [b,s,d_in]
    mask = _expand_ff_mask(dyn["ff_mask"], 2 * d_in)
    u = u * mask[:d_in].astype(u.dtype)
    z = z * mask[d_in:].astype(z.dtype)
    uh = u.reshape(b, s, nh, dh)
    q = jnp.einsum("bshd,hde->bshe", uh, p["x_q"])
    k = jnp.einsum("bshd,hde->bshe", uh, p["x_k"])
    v = jnp.einsum("bshd,hde->bshe", uh, p["x_v"])
    ig = u @ p["x_ig"].astype(u.dtype)
    fg = u @ p["x_fg"].astype(u.dtype)
    new_cache = cache
    if mode == "decode":
        h, C, n, mm = xlstm_lib.mlstm_decode_step(
            q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0],
            cache["xC"], cache["xn"], cache["xm"])
        h = h[:, None]
        new_cache = dict(cache)
        new_cache.update(xC=C, xn=n, xm=mm)
    else:
        if s <= 512:
            h = xlstm_lib.mlstm_parallel(q, k, v, ig, fg)
        else:
            h = xlstm_lib.mlstm_chunked(q, k, v, ig, fg)
        if mode == "prefill" and cache is not None:
            # rebuild state by chunked scan final state: cheap re-run of the
            # state recurrence (decode-accurate warm start)
            _, C, n, mm = _mlstm_final_state(q, k, v, ig, fg)
            new_cache = dict(cache)
            new_cache.update(xC=C, xn=n, xm=mm)
    h = h.reshape(b, s, d_in)
    h = rms_norm(h, p["x_gnorm"], cfg.norm_eps) * jax.nn.silu(z)
    x = x + h @ p["x_down"]
    stats = _zero_stats(cfg)
    stats["ff_active"] = jnp.mean(dyn["ff_mask"])
    return x, new_cache, stats, jnp.float32(0.0)


def _mlstm_final_state(q, k, v, ig, fg):
    b, s, nh, dh = q.shape

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        _, C, n, m = xlstm_lib.mlstm_decode_step(qt, kt, vt, it, ft, C, n, m)
        return (C, n, m), None

    C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    m0 = jnp.full((b, nh), -jnp.inf, jnp.float32)
    tr = lambda a: a.transpose(1, 0, *range(2, a.ndim))
    (C, n, m), _ = jax.lax.scan(step, (C0, n0, m0),
                                (tr(q), tr(k), tr(v), tr(ig), tr(fg)))
    return None, C, n, m


def _slstm_block(p, x, *, cfg, mode, cache, pos, dyn):
    b, s, d = x.shape
    hn = rms_norm(x, p["s_norm"], cfg.norm_eps)
    gates = (hn @ p["s_wx"]).reshape(b, s, 4, d)
    new_cache = cache
    if mode == "decode":
        init = (cache["sc"], cache["sn"], cache["sm"], cache["sh"])
        h, carry = xlstm_lib.slstm_scan(gates, p["s_r"], init=init)
        new_cache = dict(cache)
        new_cache.update(sc=carry[0], sn=carry[1], sm=carry[2], sh=carry[3])
    else:
        h, carry = xlstm_lib.slstm_scan(gates, p["s_r"])
        if mode == "prefill" and cache is not None:
            new_cache = dict(cache)
            new_cache.update(sc=carry[0], sn=carry[1], sm=carry[2],
                             sh=carry[3])
    x = x + h @ p["s_out"]
    hn = rms_norm(x, p["s_fnorm"], cfg.norm_eps)
    up = hn @ p["s_up"]
    a, g = jnp.split(up, 2, axis=-1)
    x = x + (jax.nn.silu(g) * a) @ p["s_down"]
    stats = _zero_stats(cfg)
    stats["ff_active"] = jnp.float32(1.0)
    return x, new_cache, stats, jnp.float32(0.0)


def _layer_norm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def _enc_block(p, x, *, cfg, mode, cache, pos, dyn, kernel_impl="scan"):
    h, _, _ = _attn_fwd(_layer_norm(x, p["e_ln1"], p["e_ln1b"], cfg.norm_eps),
                        p["e_wq"], p["e_wk"], p["e_wv"], p["e_wo"],
                        cfg=cfg, mode="train", cache=None,
                        pos=jnp.arange(x.shape[1]), rope=False,
                        causal=False, bq=p["e_bq"], bv=p["e_bv"],
                        bo=p["e_bo"], kernel_impl=kernel_impl)
    x = x + h
    hn = _layer_norm(x, p["e_ln2"], p["e_ln2b"], cfg.norm_eps)
    x = x + gelu_mlp(hn, p["e_w1"], p["e_b1"], p["e_w2"], p["e_b2"],
                     dyn["ff_mask"], impl=kernel_impl)
    stats = _zero_stats(cfg)
    stats["ff_active"] = jnp.mean(dyn["ff_mask"])
    return x, cache, stats, jnp.float32(0.0)


def _dec_block(p, x, *, cfg, mode, cache, pos, dyn, enc_out,
               kernel_impl="scan"):
    # self attention (causal, learned positions added at embedding)
    h, cache, _ = _attn_fwd(
        _layer_norm(x, p["d_ln1"], p["d_ln1b"], cfg.norm_eps),
        p["d_wq"], p["d_wk"], p["d_wv"], p["d_wo"],
        cfg=cfg, mode=mode, cache=cache, pos=pos, rope=False,
        causal=True, bq=p["d_bq"], bv=p["d_bv"], bo=p["d_bo"],
        kernel_impl=kernel_impl)
    x = x + h
    # cross attention
    hn = _layer_norm(x, p["d_ln2"], p["d_ln2b"], cfg.norm_eps)
    if mode == "decode":
        # cross K/V were cached at prefill
        m = _dims(cfg)
        q = (hn @ p["c_wq"] + p["c_bq"]).reshape(
            hn.shape[0], 1, m["nq"], m["hd"])
        out = decode_attention(q, cache["ck"], cache["cv"],
                               jnp.int32(cfg.encoder_seq))
        h = out.reshape(hn.shape[0], 1, m["nq"] * m["hd"]) @ p["c_wo"] \
            + p["c_bo"]
        new_cache = cache
    else:
        h, new_cache, _ = _attn_fwd(
            hn, p["c_wq"], p["c_wk"], p["c_wv"], p["c_wo"], cfg=cfg,
            mode=mode, cache=cache, pos=pos, rope=False, causal=False,
            bq=p["c_bq"], bv=p["c_bv"], bo=p["c_bo"], kv_override=enc_out,
            cache_keys=("ck", "cv"), kernel_impl=kernel_impl)
    x = x + h
    hn = _layer_norm(x, p["d_ln3"], p["d_ln3b"], cfg.norm_eps)
    x = x + gelu_mlp(hn, p["d_w1"], p["d_b1"], p["d_w2"], p["d_b2"],
                     dyn["ff_mask"], impl=kernel_impl)
    stats = _zero_stats(cfg)
    stats["ff_active"] = jnp.mean(dyn["ff_mask"])
    return x, new_cache, stats, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------
def apply_block(cfg: ModelConfig, dyncfg, mode: str, p, shared, carry, tag,
                dyn, cache, pos, *, kernel_impl: str = "scan"):
    """Apply one slot.  ``tag`` is a runtime int32 BLOCK_* type id.
    ``kernel_impl`` (DistConfig.kernel_impl, static) selects the attention /
    SwiGLU inner implementation — see layers.flash_attention.

    ``carry`` is the pipeline activation dict: {"x": [mb, s, d]} plus
    {"enc": [mb, enc_seq, d]} for encoder–decoder archs (the encoder stream
    rides the same carry so enc blocks can live on any stage).

    Returns (carry', new_cache, stats, aux_loss).  PAD slots are identity."""
    types = block_type_set(cfg)

    def branch(t):
        def fn(operands):
            p_, carry_, dyn_, cache_ = operands
            x_ = carry_["x"]
            if t == BLOCK_DENSE:
                y, c, s_, a = _dense_block(
                    p_, x_, cfg=cfg, mode=mode, cache=cache_, pos=pos,
                    dyn=dyn_, dyncfg=dyncfg, kernel_impl=kernel_impl)
            elif t == BLOCK_MOE:
                y, c, s_, a = _moe_block(
                    p_, x_, cfg=cfg, mode=mode, cache=cache_, pos=pos,
                    dyn=dyn_, dyncfg=dyncfg, kernel_impl=kernel_impl)
            elif t == BLOCK_MAMBA:
                y, c, s_, a = _mamba_block(
                    p_, x_, cfg=cfg, mode=mode, cache=cache_, pos=pos,
                    dyn=dyn_, shared=shared)
            elif t == BLOCK_HYBRID_ATTN:
                y, c, s_, a = _mamba_block(
                    p_, x_, cfg=cfg, mode=mode, cache=cache_, pos=pos,
                    dyn=dyn_, shared=shared, with_shared_attn=True,
                    dyncfg=dyncfg, kernel_impl=kernel_impl)
            elif t == BLOCK_MLSTM:
                y, c, s_, a = _mlstm_block(
                    p_, x_, cfg=cfg, mode=mode, cache=cache_, pos=pos,
                    dyn=dyn_)
            elif t == BLOCK_SLSTM:
                y, c, s_, a = _slstm_block(
                    p_, x_, cfg=cfg, mode=mode, cache=cache_, pos=pos,
                    dyn=dyn_)
            elif t == BLOCK_ENC:
                if mode == "decode" or "enc" not in carry_:
                    return carry_, cache_, _zero_stats(cfg), jnp.float32(0.0)
                e, c, s_, a = _enc_block(
                    p_, carry_["enc"], cfg=cfg, mode=mode, cache=cache_,
                    pos=pos, dyn=dyn_, kernel_impl=kernel_impl)
                return {**carry_, "enc": e}, c, s_, a
            elif t == BLOCK_DEC:
                y, c, s_, a = _dec_block(
                    p_, x_, cfg=cfg, mode=mode, cache=cache_, pos=pos,
                    dyn=dyn_, enc_out=carry_.get("enc"),
                    kernel_impl=kernel_impl)
            else:
                raise ValueError(t)
            # shared params are f32 (boundary-psum dtype rule); keep the
            # pipeline carry in its configured dtype
            return {**carry_, "x": y.astype(x_.dtype)}, c, s_, a
        return fn

    def pad_fn(operands):
        p_, carry_, dyn_, cache_ = operands
        return carry_, cache_, _zero_stats(cfg), jnp.float32(0.0)

    operands = (p, carry, dyn, cache)
    if len(types) == 1:
        c2, c, st, al = branch(types[0])(operands)
        active = (tag != BLOCK_PAD)
        c2 = jax.tree.map(lambda new, old: jnp.where(active, new, old),
                          c2, carry)
        c = jax.tree.map(lambda new, old: jnp.where(active, new, old),
                         c, cache) if cache is not None else c
        st = jax.tree.map(lambda a: jnp.where(active, a, jnp.zeros_like(a)),
                          st)
        return c2, c, st, jnp.where(active, al, 0.0)

    branches = [pad_fn] + [branch(t) for t in types]
    idx_map = [0] * (max(types) + 1)
    for i, t in enumerate(types):
        idx_map[t] = i + 1
    idx = jnp.asarray(idx_map, jnp.int32)[jnp.clip(tag, 0, max(types))]
    return jax.lax.switch(idx, branches, operands)


# ---------------------------------------------------------------------------
# Freezable wrapper (runtime backward skip — layer-freezing dynamism)
# ---------------------------------------------------------------------------
def freezable(fn):
    """Wrap out = fn(p, operand) so that when frozen, the backward pass skips
    dW entirely at runtime (lax.cond in the VJP) — true compute saving,
    matching the paper's layer-freezing case.

    ``operand`` must be a pytree of float arrays only (ints encoded as floats
    by the caller) so both cond branches produce identical cotangent types.
    fn must not close over tracers — pass everything via p/operand."""
    @jax.custom_vjp
    def wrapped(frozen, p, operand):
        return fn(p, operand)

    def fwd(frozen, p, operand):
        return fn(p, operand), (frozen, p, operand)

    def bwd(res, g):
        frozen, p, operand = res

        def full(_):
            _, vjp = jax.vjp(fn, p, operand)
            return vjp(g)

        def skip(_):
            _, vjp = jax.vjp(
                lambda o: fn(jax.lax.stop_gradient(p), o), operand)
            (do,) = vjp(g)
            return jax.tree.map(jnp.zeros_like, p), do

        dp, do = jax.lax.cond(frozen > 0, skip, full, None)
        return None, dp, do

    wrapped.defvjp(fwd, bwd)
    return wrapped
