"""Primitive layers shared by all architectures.

Everything is a pure function of (params, inputs).  Attention defaults to a
scan-based online-softmax implementation ("xla flash") so 32k+ contexts never
materialise the full score matrix — this is also the pure-jnp oracle that the
Pallas kernels are validated against.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30
KERNEL_IMPLS = ("reference", "scan", "pallas")


def expand_ff_mask(ff_mask: jax.Array, dim: int) -> jax.Array:
    """Block-level [n_blocks] -> feature-level [dim] pruning mask (no-op if
    already expanded).  Single home for the expansion rule — swiglu,
    gelu_mlp and blocks.py all share it."""
    if ff_mask.shape[0] != dim:
        ff_mask = jnp.repeat(ff_mask, dim // ff_mask.shape[0])
    return ff_mask


def pin_batch(x: jax.Array) -> jax.Array:
    """Constrain dim 0 (batch) to shard over the DP mesh axes.

    XLA's auto propagation inside the pipeline's remat+scan bodies sometimes
    replicates large activations (its involuntary-full-rematerialization
    fallback); pinning the batch dim of block-internal tensors keeps the
    per-tick working set 1/dp-sized.  No-op outside a mesh context or when
    the batch dim is not divisible."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:   # noqa: BLE001
        return x
    if am is None or not getattr(am, "axis_names", None):
        return x
    daxes = tuple(a for a in am.axis_names
                  if a != "model" and am.shape[a] > 1)
    if not daxes:
        return x
    dp = 1
    for a in daxes:
        dp *= am.shape[a]
    if x.ndim < 1 or x.shape[0] % dp or x.shape[0] < dp:
        return x
    spec = jax.sharding.PartitionSpec(
        daxes if len(daxes) > 1 else daxes[0], *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(am, spec))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    angles = angles[..., None, :]                              # broadcast heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array,
           ff_mask: Optional[jax.Array] = None, *, impl: str = "scan",
           interpret: Optional[bool] = None) -> jax.Array:
    """SwiGLU MLP.  ``ff_mask`` zeroes pruned feature blocks (block-
    structured pruning) — either block-level [n_blocks] or expanded [d_ff].

    ``impl="pallas"`` routes through the fused block-pruned Pallas SwiGLU
    (kernels.pruned_matmul): pruned blocks skip MXU tiles in forward AND
    backward.  The pallas path needs the block-level mask (granularity =
    d_ff // n_blocks); the dense paths accept either and expand.  Single-
    token calls (decode) stay dense — padding 1 row to a 128-tile wastes
    the MXU, mirroring the decode_attention special case."""
    assert impl in KERNEL_IMPLS, impl
    d_ff = wi.shape[1]
    if impl == "pallas" and x.shape[-2] > 1:
        from repro.kernels.pruned_matmul import pruned_swiglu
        if ff_mask is None:
            bmask, bf = jnp.ones((1,), jnp.float32), d_ff
        else:
            nb = ff_mask.shape[0]
            # an expanded [d_ff] mask would pass divisibility with bf=1 —
            # width-1 "blocks" defeat the MXU tiling; demand block-level
            assert nb < d_ff and d_ff % nb == 0, (
                "pallas swiglu needs a block-level ff_mask",
                ff_mask.shape, d_ff)
            bmask, bf = ff_mask, d_ff // nb
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return pin_batch(pruned_swiglu(x, wi, wg, wo, bmask, bf=bf,
                                       interpret=interpret))
    h = pin_batch(jax.nn.silu(x @ wg) * (x @ wi))
    if ff_mask is not None:
        h = h * expand_ff_mask(ff_mask, d_ff).astype(h.dtype)
    return pin_batch(h @ wo)


def gelu_mlp(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
             b2: jax.Array, ff_mask: Optional[jax.Array] = None, *,
             impl: str = "scan",
             interpret: Optional[bool] = None) -> jax.Array:
    """Biased GELU MLP (whisper enc/dec FFN) with block-structured pruning.

    Same dispatch contract as ``swiglu``: the dense impls accept a
    block-level or expanded ``ff_mask``; ``impl="pallas"`` needs the
    block-level mask and runs both matmuls through the pruned Pallas kernel
    (mask over "n" for the up-projection, over "k" for the down-projection).
    The bias lands after the pruned up-projection and pruned columns are
    re-zeroed before GELU's output enters the down-projection, so kept
    columns match the dense path exactly."""
    assert impl in KERNEL_IMPLS, impl
    d_ff = w1.shape[1]
    if impl == "pallas" and x.shape[-2] > 1:
        from repro.kernels.pruned_matmul import pruned_matmul
        bmask = (jnp.ones((1,), jnp.float32) if ff_mask is None
                 else ff_mask)
        nb = bmask.shape[0]
        assert nb < d_ff and d_ff % nb == 0, (
            "pallas gelu_mlp needs a block-level ff_mask", bmask.shape,
            d_ff)
        bf = d_ff // nb
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        h = pruned_matmul(x, w1, bmask, mask_axis="n", bn=bf,
                          interpret=interpret) + b1
        h = jax.nn.gelu(h) * jnp.repeat(bmask, bf).astype(x.dtype)
        return pruned_matmul(h, w2, bmask, mask_axis="k", bk=bf,
                             interpret=interpret) + b2
    h = jax.nn.gelu(x @ w1 + b1)
    if ff_mask is not None:
        h = h * expand_ff_mask(ff_mask, d_ff).astype(x.dtype)
    return h @ w2 + b2


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def _repeat_kv(k: jax.Array, num_q_heads: int) -> jax.Array:
    """[b, s, kv, d] -> [b, s, q, d] by repeating groups."""
    b, s, kv, d = k.shape
    rep = num_q_heads // kv
    return jnp.repeat(k, rep, axis=2)


def attention_reference(q, k, v, *, causal: bool, sliding_window: int = 0,
                        q_offset: int = 0,
                        block_mask: Optional[jax.Array] = None,
                        positions_q: Optional[jax.Array] = None,
                        positions_kv: Optional[jax.Array] = None,
                        block_size: int = 128) -> jax.Array:
    """Naive O(s^2) attention; oracle for tests.  q:[b,sq,h,d] k,v:[b,sk,kv,d].
    ``block_mask`` [h, sq//bs, sk//bs] enables hash-based block sparsity."""
    b, sq, h, d = q.shape
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    pq = (jnp.arange(sq) + q_offset if positions_q is None
          else positions_q)
    pk = jnp.arange(k.shape[1]) if positions_kv is None else positions_kv
    if causal:
        scores = jnp.where(pq[:, None] >= pk[None, :], scores, NEG_INF)
    if sliding_window:
        scores = jnp.where(pq[:, None] - pk[None, :] < sliding_window,
                           scores, NEG_INF)
    if block_mask is not None:
        bs = block_size
        bm = block_mask if block_mask.ndim == 4 else block_mask[None]
        m = jnp.repeat(jnp.repeat(bm, bs, axis=-2), bs, axis=-1)
        sk = k.shape[1]
        if m.shape[-2] < sq or m.shape[-1] < sk:
            # trailing partial blocks reuse the last mask row/col (matches
            # the flash paths' clipped block-id gather)
            m = jnp.pad(m, ((0, 0), (0, 0),
                            (0, max(0, sq - m.shape[-2])),
                            (0, max(0, sk - m.shape[-1]))), mode="edge")
        scores = jnp.where(m[..., :sq, :sk] > 0, scores, NEG_INF)
    # guard fully-masked rows
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.max(scores, -1, keepdims=True) <= NEG_INF / 2,
                      0.0, probs)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def flash_attention(q, k, v, *, causal: bool, sliding_window: int = 0,
                    q_offset: int = 0,
                    block_mask: Optional[jax.Array] = None,
                    kv_block: int = 512, impl: str = "scan",
                    interpret: Optional[bool] = None) -> jax.Array:
    """Flash attention with a FLASH BACKWARD (custom VJP): the backward
    recomputes scores block-by-block from (q, k, v, out, lse) instead of
    storing per-block probability matrices — without this, differentiating
    the forward scan materialises the full O(sq·sk) score tensor per layer
    per slot (measured: the dominant memory term of every attention cell).

    ``impl`` selects the inner implementation (DistConfig.kernel_impl):
      * "reference" — the O(s^2) dense oracle;
      * "scan"      — the pure-JAX online-softmax scan (this module);
      * "pallas"    — the block-skipping Pallas kernels with the Pallas
        flash backward (kernels.block_sparse_attention); masked tiles do
        no MXU work in forward or backward.  Sliding-window / offset
        queries aren't expressible as block masks — those fall back to
        the scan (see DESIGN.md).
    """
    assert impl in KERNEL_IMPLS, impl
    if impl == "pallas" and sliding_window == 0 and q_offset == 0:
        return _pallas_attention(q, k, v, block_mask, causal, kv_block,
                                 interpret)
    if impl == "reference":
        return attention_reference(
            q, k, v, causal=causal, sliding_window=sliding_window,
            q_offset=q_offset, block_mask=block_mask, block_size=kv_block)
    out, _ = _flash_vjp(q, k, v, block_mask, causal, sliding_window,
                        q_offset, kv_block)
    return out


def _pallas_attention(q, k, v, block_mask, causal, kv_block,
                      interpret=None):
    """Route through the Pallas block-sparse kernel (dense = all-ones mask).

    Accepts the model's mask layouts ([h, nqb, nkb] or [b, h|1, nqb, nkb])
    and broadcasts/edge-extends them to the kernel's [b, hq, nqb, nkb]."""
    from repro.kernels.block_sparse_attention import block_sparse_attention
    b, sq, hq, _ = q.shape
    sk = k.shape[1]
    block = kv_block if block_mask is not None else min(kv_block, 128)
    nqb = -(-sq // block)
    nkb = -(-sk // block)
    if block_mask is None:
        bm = jnp.ones((b, hq, nqb, nkb), jnp.float32)
    else:
        bm = block_mask if block_mask.ndim == 4 else block_mask[None]
        # trailing partial blocks reuse the last mask row/col (the scan
        # path's qb_ids gather clips the same way)
        qb = jnp.clip(jnp.arange(nqb), 0, bm.shape[2] - 1)
        kb = jnp.clip(jnp.arange(nkb), 0, bm.shape[3] - 1)
        bm = bm[:, :, qb][:, :, :, kb]
        bm = jnp.broadcast_to(bm, (b, hq, nqb, nkb)).astype(jnp.float32)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return block_sparse_attention(q, k, v, bm, causal=causal, block_q=block,
                                  block_k=block, interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash_vjp(q, k, v, block_mask, causal, sliding_window, q_offset,
               kv_block):
    return _flash_fwd_impl(q, k, v, block_mask, causal, sliding_window,
                           q_offset, kv_block)


def _flash_vjp_fwd(q, k, v, block_mask, causal, sliding_window, q_offset,
                   kv_block):
    out, lse = _flash_fwd_impl(q, k, v, block_mask, causal, sliding_window,
                               q_offset, kv_block)
    return (out, lse), (q, k, v, block_mask, out, lse)


def _flash_vjp_bwd(causal, sliding_window, q_offset, kv_block, res, cts):
    q, k, v, block_mask, out, lse = res
    dout = cts[0]
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kv_heads = k.shape[2]
    rep = h // kv_heads
    pad = (-sk) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkb = k.shape[1] // kv_block
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    pq = jnp.arange(sq) + q_offset
    D = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                axis=-1).transpose(0, 2, 1)                     # [b,h,sq]
    qf = q.astype(jnp.float32)
    doutf = dout.astype(jnp.float32).transpose(0, 2, 1, 3)      # [b,h,sq,d]
    qh = qf.transpose(0, 2, 1, 3)                               # [b,h,sq,d]
    kb = k.reshape(b, nkb, kv_block, kv_heads, d)
    vb = v.reshape(b, nkb, kv_block, kv_heads, d)

    def body(dq, inp):
        kblk, vblk, jb = inp
        krep = jnp.repeat(kblk.astype(jnp.float32), rep, axis=2)
        # [b,h,sq,kv_block]
        s = jnp.einsum("bhqd,bkhd->bhqk", qh, krep) * scale
        pk = jb * kv_block + jnp.arange(kv_block)
        mask = pk[None, :] <= jnp.full((sq, 1), sk - 1)
        if causal:
            mask &= pq[:, None] >= pk[None, :]
        if sliding_window:
            mask &= pq[:, None] - pk[None, :] < sliding_window
        if block_mask is not None:
            qb_ids = jnp.arange(sq) // kv_block
            if block_mask.ndim == 3:
                bm = block_mask[:, qb_ids, jb]
                s = jnp.where(bm[None, :, :, None] > 0, s, NEG_INF)
            else:
                bm = block_mask[:, :, qb_ids, jb]
                s = jnp.where(bm[..., None] > 0, s, NEG_INF)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                 # [b,h,sq,kb]
        # masked entries: s=NEG_INF ⇒ p→0; fully-masked rows have
        # lse≈NEG_INF which would make p spuriously 1 — zero them
        p = jnp.where((s <= NEG_INF / 2)
                      | (lse[..., None] <= NEG_INF / 4), 0.0, p)
        vrep = jnp.repeat(vblk.astype(jnp.float32), rep, axis=2)
        dp = jnp.einsum("bhqd,bkhd->bhqk", doutf, vrep)
        ds = p * (dp - D[..., None]) * scale
        dq = dq + pin_batch(jnp.einsum("bhqk,bkhd->bhqd", ds, krep))
        dk_blk = jnp.einsum("bhqk,bhqd->bkhd", ds, qh)
        dv_blk = jnp.einsum("bhqk,bhqd->bkhd", p, doutf)
        # fold grouped heads back to kv heads
        dk_blk = dk_blk.reshape(b, kv_block, kv_heads, rep, d).sum(3)
        dv_blk = dv_blk.reshape(b, kv_block, kv_heads, rep, d).sum(3)
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, h, sq, d), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(
        body, dq0, (kb.transpose(1, 0, 2, 3, 4),
                    vb.transpose(1, 0, 2, 3, 4), jnp.arange(nkb)))
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(b, nkb * kv_block, kv_heads,
                                               d)[:, :sk]
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(b, nkb * kv_block, kv_heads,
                                               d)[:, :sk]
    dq = dq.transpose(0, 2, 1, 3)
    dbm = None if block_mask is None else jnp.zeros_like(block_mask)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dbm)


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _flash_fwd_impl(q, k, v, block_mask, causal, sliding_window, q_offset,
                    kv_block):
    """Forward online-softmax scan; returns (out, lse [b,h,sq])."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kv_heads = k.shape[2]
    if sk % kv_block:
        pad = kv_block - sk % kv_block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkb = k.shape[1] // kv_block
    rep = h // kv_heads
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    pq = jnp.arange(sq) + q_offset

    kb = k.reshape(b, nkb, kv_block, kv_heads, d)
    vb = v.reshape(b, nkb, kv_block, kv_heads, d)

    def body(carry, inp):
        acc, m_prev, l_prev = carry
        kblk, vblk, jb = inp                       # [b, kv_block, kv, d]
        kblk = jnp.repeat(kblk, rep, axis=2)
        vblk = jnp.repeat(vblk, rep, axis=2)
        pk = jb * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kblk).astype(jnp.float32) * scale
        mask = pk[None, :] <= jnp.full((sq, 1), sk - 1)
        if causal:
            mask &= pq[:, None] >= pk[None, :]
        if sliding_window:
            mask &= pq[:, None] - pk[None, :] < sliding_window
        if block_mask is not None:
            # block_mask: [h, nqb, nkb] or [b, h, nqb, nkb], square blocks
            # of size kv_block
            qb_ids = jnp.arange(sq) // kv_block
            if block_mask.ndim == 3:
                bm = block_mask[:, qb_ids, jb]     # [h, sq]
                s = jnp.where(bm[None, :, :, None] > 0, s, NEG_INF)
            else:
                bm = block_mask[:, :, qb_ids, jb]  # [b, h, sq]
                s = jnp.where(bm[..., None] > 0, s, NEG_INF)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = pin_batch(
            acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vblk.dtype),
                vblk).astype(jnp.float32))
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         jnp.arange(nkb)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.where(m[..., None] <= NEG_INF / 2, 0.0, out)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))          # [b,h,sq]
    return out.transpose(0, 2, 1, 3).astype(q.dtype), lse


def decode_attention(q, k_cache, v_cache, cache_len, *, sliding_window: int = 0,
                     window_offset: int = 0) -> jax.Array:
    """Single-token decode attention over a (possibly ring-buffer) cache.

    q: [b, 1, h, d]; k_cache/v_cache: [b, S, kv, d]; cache_len: count of
    valid entries — a scalar (all lanes at the same position) or a [b]
    vector (continuous batching: each request at its own position).  For
    sliding-window archs the cache IS the ring buffer (S == window) and
    window_offset gives the rotation; masking handles both.
    """
    b, s, kv, d = k_cache.shape
    h = q.shape[2]
    k = _repeat_kv(k_cache, h)
    v = _repeat_kv(v_cache, h)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    idx = jnp.arange(s)
    cl = jnp.asarray(cache_len)
    if cl.ndim == 0:
        valid = idx < cl                                   # [s]
        if sliding_window:
            # non-ring cache with windowed attention: last `window` live
            valid &= idx >= cl - sliding_window
        vmask = valid[None, None, None, :]
    else:
        valid = idx[None, :] < cl[:, None]                 # [b, s]
        if sliding_window:
            valid &= idx[None, :] >= cl[:, None] - sliding_window
        vmask = valid[:, None, None, :]
    scores = jnp.where(vmask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def gqa_project(x, wq, wk, wv, num_heads, num_kv_heads, head_dim):
    b, s, _ = x.shape
    q = (x @ wq).reshape(b, s, num_heads, head_dim)
    k = (x @ wk).reshape(b, s, num_kv_heads, head_dim)
    v = (x @ wv).reshape(b, s, num_kv_heads, head_dim)
    return q, k, v


def cross_entropy_with_head(h, head_w, labels, *, label_mask=None,
                            vocab_shard_size: Optional[int] = None,
                            vocab_offset: int = 0,
                            axis_name: Optional[str] = None):
    """Cross-entropy over (possibly vocab-sharded) head.  h: [..., d],
    head_w: [d, V_local], labels int32 [...].  When ``axis_name`` is given the
    head is vocab-sharded over that mesh axis (Megatron-style vocab-parallel
    loss): per-shard max/sumexp/label-logit are combined with collectives."""
    logits = (h @ head_w).astype(jnp.float32)              # [..., V_local]
    if axis_name is None:
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    else:
        local_max = jnp.max(logits, axis=-1)
        gmax = jax.lax.pmax(local_max, axis_name)
        sumexp = jnp.sum(jnp.exp(logits - gmax[..., None]), axis=-1)
        sumexp = jax.lax.psum(sumexp, axis_name)
        lse = gmax + jnp.log(sumexp)
        local_labels = labels - vocab_offset
        in_shard = (local_labels >= 0) & (local_labels < logits.shape[-1])
        safe = jnp.clip(local_labels, 0, logits.shape[-1] - 1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        ll = jax.lax.psum(jnp.where(in_shard, ll, 0.0), axis_name)
    nll = lse - ll
    if label_mask is not None:
        nll = nll * label_mask
        denom = jnp.maximum(jnp.sum(label_mask), 1.0)
    else:
        denom = float(nll.size)
    return jnp.sum(nll) / denom
