"""Mamba2 (SSD) block internals — chunked parallel form for train/prefill,
O(1) recurrent form for decode.  Single group (G=1), expand factor 2.

Parallel form follows the minimal-SSD decomposition: intra-chunk quadratic
attention-like term + inter-chunk state recurrence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., L] -> [..., L, L] lower-tri cumulative sums: out[i,j] =
    sum_{k=j+1..i} x[k] for i>=j, -inf above diagonal."""
    L = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    out = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A_log, B, C, D, *, chunk: int = 128,
                init_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: [b, s, nh, dh]; dt: [b, s, nh] (softplus-ed); A_log: [nh];
    B, C: [b, s, state]; D: [nh].  Returns (y [b,s,nh,dh],
    final_state [b, nh, dh, state]).
    """
    b, s, nh, dh = x.shape
    st = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))                    # [nh] < 0

    xc = x.reshape(b, nc, chunk, nh, dh)
    dtc = dt.reshape(b, nc, chunk, nh).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, st).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, st).astype(jnp.float32)
    dA = dtc * A                                               # [b,nc,cl,nh]
    dA_t = dA.transpose(0, 1, 3, 2)                            # [b,nc,nh,cl]

    # intra-chunk (diagonal blocks): attention-like with decay mask
    Lmat = jnp.exp(_segsum(dA_t))                              # [b,nc,nh,cl,cl]
    scores = jnp.einsum("bcls,bcms->bclm", Cc, Bc)             # [b,nc,cl,cl]
    gated = scores[:, :, None] * Lmat.transpose(0, 1, 2, 3, 4)  # [b,nc,nh,cl,cl]
    xdt = xc.astype(jnp.float32) * dtc[..., None]              # [b,nc,cl,nh,dh]
    y_diag = jnp.einsum("bchlm,bcmhd->bclhd",
                        gated.transpose(0, 1, 2, 3, 4),
                        xdt.transpose(0, 1, 2, 3, 4))

    # chunk-final states: S_c = sum_t exp(sum_{t..end} dA) dt_t x_t B_t^T
    decay_to_end = jnp.exp(jnp.cumsum(dA_t[..., ::-1], axis=-1)[..., ::-1]
                           - dA_t)                             # [b,nc,nh,cl]
    S_chunk = jnp.einsum("bchl,bclhd,bcls->bchds",
                         decay_to_end, xdt, Bc)                # [b,nc,nh,dh,st]
    chunk_decay = jnp.exp(jnp.sum(dA_t, axis=-1))              # [b,nc,nh]

    # inter-chunk recurrence over nc
    def scan_fn(S, inp):
        Sc, dec = inp                                          # [b,nh,dh,st],[b,nh]
        S_out = S                                              # state entering chunk
        S = S * dec[..., None, None] + Sc
        return S, S_out

    S0 = (jnp.zeros((b, nh, dh, st), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    S_final, S_in = jax.lax.scan(
        scan_fn, S0,
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_in = S_in.transpose(1, 0, 2, 3, 4)                       # [b,nc,nh,dh,st]

    # contribution of the incoming state to each position
    decay_from_start = jnp.exp(jnp.cumsum(dA_t, axis=-1))      # [b,nc,nh,cl]
    y_off = jnp.einsum("bcls,bchds,bchl->bclhd", Cc, S_in, decay_from_start)

    y = y_diag + y_off + xc.astype(jnp.float32) * D[None, None, None, :, None]
    y = y.reshape(b, nc * chunk, nh, dh)[:, :s]
    return y.astype(x.dtype), S_final


def ssd_decode_step(x, dt, A_log, B, C, D, state):
    """One-token recurrent update.  x: [b, nh, dh]; dt: [b, nh];
    B, C: [b, state]; state: [b, nh, dh, st]."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32) * A)                   # [b, nh]
    xdt = x.astype(jnp.float32) * dt[..., None]
    state = (state * dA[..., None, None]
             + jnp.einsum("bhd,bs->bhds", xdt, B.astype(jnp.float32)))
    y = jnp.einsum("bs,bhds->bhd", C.astype(jnp.float32), state)
    y = y + x.astype(jnp.float32) * D[None, :, None]
    return y.astype(x.dtype), state


def causal_conv(x, w, b, *, state=None):
    """Depthwise causal conv1d.  x: [b, s, c]; w: [k, c]; b: [c].
    With ``state`` [b, k-1, c] performs streaming update (decode)."""
    k = w.shape[0]
    if state is not None:
        xin = jnp.concatenate([state, x], axis=1)              # [b, k-1+s, c]
        new_state = xin[:, -(k - 1):]
    else:
        xin = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = xin[:, -(k - 1):]
    out = sum(xin[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out + b[None, None]), new_state
