from repro.models import blocks, layers, mamba, model, xlstm

__all__ = ["blocks", "layers", "mamba", "model", "xlstm"]
