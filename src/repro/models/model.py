"""Whole-model assembly on top of the slot-block layer.

Parameters
  params = {
    "embed":  [V, d],
    "head":   [d, V]            (absent when tied),
    "final_norm": [d],
    "stages": {field: [S, L_max, ...]},     # stacked slot params
    "shared": {...},                        # zamba2 shared attn, whisper pos
  }

Assignment (runtime input — rebalancing never recompiles)
  assignment = {
    "tags":       int32 [S, L_max]   BLOCK_* per slot (BLOCK_PAD = empty),
    "num_active": int32 [S],
  }

Dynamism state (runtime input)
  dyn = {"ff_mask": f32 [S, L_max, npb], "frozen": f32 [S, L_max],
         "mod_router": f32 [S, L_max, d]}          (router only when MoD)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    BLOCK_PAD, DistConfig, ModelConfig,
)
from repro.dynamics.config import DynamicsConfig
from repro.models import blocks as B
from repro.models.layers import cross_entropy_with_head, rms_norm


# ---------------------------------------------------------------------------
# Assignment
# ---------------------------------------------------------------------------
def uniform_boundaries(num_layers: int, num_stages: int) -> List[int]:
    """Megatron-style uniform contiguous split: layers per stage."""
    base = num_layers // num_stages
    rem = num_layers % num_stages
    return [base + (1 if s < rem else 0) for s in range(num_stages)]


def make_assignment(cfg: ModelConfig, dcfg: DistConfig,
                    layers_per_stage: Optional[Sequence[int]] = None
                    ) -> Dict[str, jax.Array]:
    """Build assignment arrays from a contiguous layers-per-stage split."""
    pattern = cfg.block_pattern()
    S, L_max = dcfg.num_stages, dcfg.slots_for(cfg)
    if layers_per_stage is None:
        layers_per_stage = uniform_boundaries(len(pattern), S)
    assert sum(layers_per_stage) == len(pattern), (
        f"{sum(layers_per_stage)} != {len(pattern)}")
    assert max(layers_per_stage) <= L_max, (
        f"stage over capacity: {max(layers_per_stage)} > {L_max}")
    tags = [[BLOCK_PAD] * L_max for _ in range(S)]
    i = 0
    for s, n in enumerate(layers_per_stage):
        for l in range(n):
            tags[s][l] = pattern[i]
            i += 1
    import numpy as np
    lps = np.array(layers_per_stage)
    depth_base = np.concatenate([[0], np.cumsum(lps)[:-1]])
    return {
        "tags": jnp.asarray(np.array(tags), jnp.int32),
        "num_active": jnp.asarray(lps, jnp.int32),
        "depth_base": jnp.asarray(depth_base, jnp.int32),
    }


def assignment_to_boundaries(assignment) -> List[int]:
    import numpy as np
    return list(np.asarray(assignment["num_active"]))


# ---------------------------------------------------------------------------
# Params / dyn-state / cache construction
# ---------------------------------------------------------------------------
def _dtype_of(dcfg: DistConfig):
    return jnp.bfloat16 if dcfg.param_dtype == "bfloat16" else jnp.float32


# NOTE (dtype rule, see DESIGN.md §3 / pipeline.py): params that are
# replicated over the manual `model` axis (embed, head, final_norm, shared)
# are stored in float32 — their gradient psum crosses the shard_map boundary
# and XLA-CPU's bf16 all-reduce promotion pass crashes.  Stage params (sharded
# over `model`, no boundary psum) stay in the configured dtype (bf16).
def param_spec(cfg: ModelConfig, dcfg: DistConfig) -> Dict[str, Any]:
    dt = _dtype_of(dcfg)
    S, L_max = dcfg.num_stages, dcfg.slots_for(cfg)
    slot = B.slot_param_spec(cfg, dt)
    stages = {k: jax.ShapeDtypeStruct((S, L_max) + v.shape, v.dtype)
              for k, v in slot.items()}
    spec = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab_size, cfg.d_model),
                                      jnp.float32),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
        "stages": stages,
        "shared": B.shared_param_spec(cfg, jnp.float32),
    }
    if not cfg.tie_embeddings:
        spec["head"] = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab_size),
                                            jnp.float32)
    return spec


def init_params(rng: jax.Array, cfg: ModelConfig,
                dcfg: DistConfig) -> Dict[str, Any]:
    dt = _dtype_of(dcfg)
    S, L_max = dcfg.num_stages, dcfg.slots_for(cfg)
    k_emb, k_head, k_slots, k_shared = jax.random.split(rng, 4)
    slot_keys = jax.random.split(k_slots, S * L_max).reshape(S, L_max, 2)
    stages = jax.vmap(jax.vmap(lambda k: B.init_slot(k, cfg, dt)))(slot_keys)
    params = {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "stages": stages,
        "shared": B.init_shared(k_shared, cfg, jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size), jnp.float32) \
            * cfg.d_model ** -0.5
    return params


def init_dyn(cfg: ModelConfig, dcfg: DistConfig,
             dyncfg: DynamicsConfig) -> Dict[str, jax.Array]:
    S, L_max = dcfg.num_stages, dcfg.slots_for(cfg)
    npb = B.n_prune_blocks(cfg)
    dyn = {
        "ff_mask": jnp.ones((S, L_max, npb), jnp.float32),
        "frozen": jnp.zeros((S, L_max), jnp.float32),
    }
    if dyncfg.uses_mod:
        dyn["mod_router"] = jnp.zeros((S, L_max, cfg.d_model), jnp.float32)
        # enable MoD on every k-th slot is decided by the controller via
        # mod_on (tied to global layer index, migrates with the slot)
        dyn["mod_on"] = jnp.zeros((S, L_max), jnp.float32)
    if dyncfg.expert_relayout and cfg.num_experts:
        # logical expert -> physical kernel group, per slot (identity at
        # init).  Stored float32 so the leaf rides `freezable`'s float-only
        # operand rule; its [S, L_max] leading dims migrate/resize with
        # every other dyn leaf.  Only the pallas grouped path reads it —
        # and per-token math is placement-invariant, so a re-layout never
        # changes the model function (bit-identity tested).
        dyn["expert_map"] = jnp.tile(
            jnp.arange(cfg.num_experts, dtype=jnp.float32),
            (S, L_max, 1))
    return dyn


def dyn_spec(cfg: ModelConfig, dcfg: DistConfig,
             dyncfg: DynamicsConfig) -> Dict[str, Any]:
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        init_dyn(cfg, dcfg, dyncfg))


def cache_spec(cfg: ModelConfig, dcfg: DistConfig, num_micro: int, mb: int,
               cache_len: int) -> Dict[str, Any]:
    """Stacked decode cache: [S, L_max, num_micro, ...per-slot...]."""
    S, L_max = dcfg.num_stages, dcfg.slots_for(cfg)
    slot = B.slot_cache_spec(cfg, mb, cache_len)
    return {k: jax.ShapeDtypeStruct((S, L_max, num_micro) + v.shape, v.dtype)
            for k, v in slot.items()}


def init_cache(cfg: ModelConfig, dcfg: DistConfig, num_micro: int, mb: int,
               cache_len: int) -> Dict[str, jax.Array]:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, dcfg, num_micro, mb, cache_len))


def paged_cache_spec(cfg: ModelConfig, dcfg: DistConfig, pool_pages: int,
                     page_size: int) -> Dict[str, Any]:
    """Stacked block-paged decode cache: [S, L_max, pool+1, page, kv, hd].

    Unlike the dense cache there is NO per-microbatch axis — all m*B lanes
    of a stage-slot share one physical pool, indexed through page tables
    that live host-side and ride into decode as an input.  Leading
    [S, L_max] means the pool re-splits across elastic resizes through the
    same stage-tree machinery as the dense cache.
    """
    S, L_max = dcfg.num_stages, dcfg.slots_for(cfg)
    slot = B.paged_slot_cache_spec(cfg, pool_pages, page_size)
    return {k: jax.ShapeDtypeStruct((S, L_max) + v.shape, v.dtype)
            for k, v in slot.items()}


def init_paged_cache(cfg: ModelConfig, dcfg: DistConfig, pool_pages: int,
                     page_size: int) -> Dict[str, jax.Array]:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        paged_cache_spec(cfg, dcfg, pool_pages, page_size))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embed(params, cfg: ModelConfig, tokens, *, prefix_emb=None,
          pos_offset=0):
    """tokens: [b, s] int32 -> carry dict.

    ``prefix_emb``: [b, p, d] precomputed modality embeddings (VLM patches /
    audio frames) prepended to the token stream (VLM) or used as the encoder
    stream (whisper)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.is_encdec:
        # decoder learned positions; encoder stream = frame stub + sinusoid
        s = tokens.shape[1]
        pos = params["shared"]["dec_pos"][pos_offset:pos_offset + s] \
            if isinstance(pos_offset, int) else jax.lax.dynamic_slice_in_dim(
                params["shared"]["dec_pos"], pos_offset, 1, 0)
        x = x + pos[None].astype(x.dtype)
        carry = {"x": x}
        if prefix_emb is not None:
            enc = prefix_emb + _sinusoidal(prefix_emb.shape[1],
                                           cfg.d_model).astype(x.dtype)[None]
            carry["enc"] = enc
        return carry
    if cfg.family == "vlm" and prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    return {"x": x}


def _sinusoidal(length: int, channels: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(channels // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / (channels // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def lm_loss(params, cfg: ModelConfig, h, labels, label_mask=None,
            vocab_axis=None, vocab_offset=0):
    """h: [b, s, d] final hidden -> mean xent.  When ``vocab_axis`` is set the
    head is vocab-sharded over that mesh axis (vocab-parallel loss)."""
    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    return cross_entropy_with_head(
        hn, head, labels, label_mask=label_mask, axis_name=vocab_axis,
        vocab_offset=vocab_offset)


def lm_logits(params, cfg: ModelConfig, h):
    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    return (hn @ head).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Single-device sequential reference (oracle for pipeline equivalence tests)
# ---------------------------------------------------------------------------
def reference_loss(cfg: ModelConfig, dcfg: DistConfig,
                   dyncfg: DynamicsConfig, params, assignment, dyn, tokens,
                   labels, label_mask=None, prefix_emb=None):
    """Apply all blocks in global order on one device; same math as the
    pipelined loss (excluding MoE aux loss weighting, added identically)."""
    import numpy as np
    from repro.pipeline.pipeline import AUX_LOSS_COEF
    tags_np = np.asarray(assignment["tags"])
    carry = embed(params, cfg, tokens, prefix_emb=prefix_emb)
    dt = _dtype_of(dcfg)
    carry["x"] = carry["x"].astype(dt)
    if "enc" in carry:
        carry["enc"] = carry["enc"].astype(dt)
    if dyncfg.uses_early_exit:
        carry["exited"] = jnp.zeros(carry["x"].shape[:2], jnp.float32)
    pos = jnp.arange(carry["x"].shape[1])
    aux_total = jnp.float32(0.0)
    depth = 0
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    for s in range(tags_np.shape[0]):
        for l in range(tags_np.shape[1]):
            if tags_np[s, l] == BLOCK_PAD:
                continue
            p = jax.tree.map(lambda a: a[s, l], params["stages"])
            dyn_slot = jax.tree.map(lambda a: a[s, l], dyn)
            carry_in = carry
            carry, _, stats, aux = B.apply_block(
                cfg, dyncfg, "train", p, params["shared"], carry,
                jnp.int32(tags_np[s, l]), dyn_slot, None, pos,
                kernel_impl=dcfg.kernel_impl)
            if dyncfg.uses_mod:
                from repro.models.model import _mod_wrap
                carry, _ = _mod_wrap(cfg, dyncfg, dyn_slot, carry_in, carry)
            if dyncfg.uses_early_exit:
                carry, _ = _ee_update(cfg, dyncfg, carry_in, carry,
                                      jnp.float32(depth)
                                      / max(1, cfg.total_blocks()))
            aux_total = aux_total + aux
            depth += 1
    h = carry["x"][:, prefix:]
    if label_mask is None:
        label_mask = jnp.ones(labels.shape, jnp.float32)
    hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = hn.astype(jnp.float32) @ head.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    loss = jnp.sum((lse - ll) * label_mask) / jnp.maximum(
        jnp.sum(label_mask), 1.0)
    aux = aux_total / max(1, cfg.total_blocks())
    return loss + AUX_LOSS_COEF * aux


# ---------------------------------------------------------------------------
# Stage executor
# ---------------------------------------------------------------------------
def _mod_wrap(cfg, dyncfg, dyn_slot, carry_in, carry_out):
    """Mixture-of-Depths: route only top-capacity tokens through the block.

    Applied as an output mix: tokens not selected keep their input
    activation (residual bypass).  Selection comes from the slot's router.
    The *compute* saving is modelled at cost level (capacity fraction);
    the Pallas/serving path can gather-compact instead."""
    x_in, x_out = carry_in["x"], carry_out["x"]
    b, s, d = x_in.shape
    k = max(1, int(dyncfg.mod_capacity * s))
    scores = jnp.einsum("bsd,d->bs", x_in.astype(jnp.float32),
                        dyn_slot["mod_router"])
    thresh = jax.lax.top_k(scores, k)[0][:, -1:]
    sel = (scores >= thresh).astype(x_in.dtype)[..., None]
    on = dyn_slot["mod_on"] > 0
    mix = jnp.where(sel > 0, x_out, x_in)
    new_x = jnp.where(on, mix, x_out)
    frac = jnp.where(on, jnp.float32(k / s), 1.0)
    return {**carry_out, "x": new_x}, frac


def _ee_update(cfg, dyncfg, carry_in, carry_out, depth_frac):
    """Early exit: tokens whose hidden state has saturated stop updating.

    carry holds "exited" [b, s]; exited tokens keep their activation frozen
    (the cost model/simulator accounts the skipped compute)."""
    x_in, x_out = carry_in["x"], carry_out["x"]
    exited = carry_in.get("exited")
    if exited is None:
        return carry_out, jnp.float32(1.0)
    xi = x_in.astype(jnp.float32)
    xo = x_out.astype(jnp.float32)
    cos = jnp.sum(xi * xo, -1) / jnp.maximum(
        jnp.linalg.norm(xi, axis=-1) * jnp.linalg.norm(xo, axis=-1), 1e-6)
    can_exit = depth_frac >= dyncfg.ee_min_layer_frac
    newly = (cos > dyncfg.ee_threshold) & can_exit
    exited_new = jnp.maximum(exited, newly.astype(exited.dtype))
    x_keep = jnp.where(exited[..., None] > 0, x_in, x_out)
    active_frac = 1.0 - jnp.mean(exited)
    return {**carry_out, "x": x_keep, "exited": exited_new}, active_frac


def stage_forward(cfg: ModelConfig, dcfg: DistConfig, dyncfg: DynamicsConfig,
                  mode: str, stage_params, shared, tags, dyn_stage, carry,
                  cache_stage, pos, stage_depth_base):
    """Run one stage's L_max slots over the carry.

    stage_params: {field: [L_max, ...]}; tags: [L_max]; cache_stage: stacked
    per-slot cache or None.  Returns (carry, cache, stats [L_max, ...],
    aux_loss)."""
    L_max = tags.shape[0]
    total = cfg.total_blocks()

    def slot_fn(l, carry, cache_slot):
        p = jax.tree.map(lambda a: a[l], stage_params)
        dyn_slot = jax.tree.map(lambda a: a[l], dyn_stage)
        tag = tags[l]

        active = tag != BLOCK_PAD

        def run(carry):
            out_carry, out_cache, stats, aux = B.apply_block(
                cfg, dyncfg, mode, p, shared, carry, tag, dyn_slot,
                cache_slot, pos, kernel_impl=dcfg.kernel_impl)
            extra = jnp.float32(1.0)
            # EE/MoD wrappers only act on real (non-pad) slots
            if dyncfg.uses_mod and mode == "train":
                wrapped, extra = _mod_wrap(cfg, dyncfg, dyn_slot, carry,
                                           out_carry)
                out_carry = jax.tree.map(
                    lambda a, b: jnp.where(active, a, b), wrapped, out_carry)
            if dyncfg.uses_early_exit:
                depth_frac = (stage_depth_base + l).astype(jnp.float32) \
                    / max(1, total)
                wrapped, extra = _ee_update(cfg, dyncfg, carry, out_carry,
                                            depth_frac)
                out_carry = jax.tree.map(
                    lambda a, b: jnp.where(active, a, b), wrapped, out_carry)
            return out_carry, out_cache, stats, aux, extra

        if dyncfg.uses_freezing and mode == "train":
            # operand carries every traced input as floats (freezable's VJP
            # requires float-only cotangent trees and no tracer closures)
            operand = (carry, shared, dyn_slot, tag.astype(jnp.float32),
                       pos.astype(jnp.float32))

            def frz_fn(p_, op):
                carry_, shared_, dyn_slot_, tag_f, pos_f = op
                out_carry, _, stats, aux = B.apply_block(
                    cfg, dyncfg, mode, p_, shared_, carry_,
                    tag_f.astype(jnp.int32), dyn_slot_, None, pos_f,
                    kernel_impl=dcfg.kernel_impl)
                return out_carry, stats, aux

            out_carry, stats, aux = B.freezable(frz_fn)(
                dyn_slot["frozen"], p, operand)
            return out_carry, cache_slot, stats, aux, jnp.float32(1.0)
        return run(carry)

    if dcfg.slot_exec == "bounded_loop" and not dcfg.unroll_slots:
        # data-dependent trip count: a lightly-loaded stage does less work
        stats0 = jax.tree.map(
            lambda s: jnp.zeros((L_max,) + s.shape, s.dtype),
            B.stats_spec(cfg))
        num_active = jnp.sum((tags != BLOCK_PAD).astype(jnp.int32))

        def body(l, state):
            carry, cache, stats_acc, aux_acc = state
            cache_slot = (None if cache is None else
                          jax.tree.map(lambda a: a[l], cache))
            carry, new_cache, stats, aux, extra = slot_fn(l, carry,
                                                          cache_slot)
            if cache is not None:
                cache = jax.tree.map(
                    lambda full, ns: jax.lax.dynamic_update_index_in_dim(
                        full, ns, l, 0), cache, new_cache)
            stats_acc = jax.tree.map(
                lambda acc, s: jax.lax.dynamic_update_index_in_dim(
                    acc, s, l, 0), stats_acc, stats)
            return carry, cache, stats_acc, aux_acc + aux

        carry, cache_stage, stats, aux = jax.lax.fori_loop(
            0, num_active, body, (carry, cache_stage, stats0,
                                  jnp.float32(0.0)))
        return carry, cache_stage, stats, aux

    # masked scan (default) or full unroll
    def scan_body(state, inp):
        carry, aux_acc = state
        l, cache_slot = inp
        cache_slot = None if cache_stage is None else cache_slot
        carry, new_cache, stats, aux, extra = slot_fn(l, carry, cache_slot)
        return (carry, aux_acc + aux), (new_cache, stats)

    ls = jnp.arange(L_max)
    if dcfg.unroll_slots:
        outs = []
        state = (carry, jnp.float32(0.0))
        for l in range(L_max):
            cache_slot = (None if cache_stage is None else
                          jax.tree.map(lambda a: a[l], cache_stage))
            state, out = scan_body(state, (ls[l], cache_slot))
            outs.append(out)
        (carry, aux) = state
        new_caches = (None if cache_stage is None else jax.tree.map(
            lambda *xs: jnp.stack(xs), *[o[0] for o in outs]))
        stats = jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[o[1] for o in outs])
    else:
        cache_xs = cache_stage
        if cache_stage is None:
            (carry, aux), (new_caches, stats) = jax.lax.scan(
                lambda st, l: scan_body(st, (l, None)),
                (carry, jnp.float32(0.0)), ls)
            new_caches = None
        else:
            (carry, aux), (new_caches, stats) = jax.lax.scan(
                scan_body, (carry, jnp.float32(0.0)), (ls, cache_xs))
    return carry, new_caches, stats, aux
