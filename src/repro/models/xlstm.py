"""xLSTM block internals — mLSTM (parallel, attention-like with exponential
gating) and sLSTM (recurrent scan with stabilized exponential gates).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def mlstm_parallel(q, k, v, ig, fg):
    """Stabilized parallel mLSTM.

    q,k,v: [b, s, nh, dh]; ig,fg: [b, s, nh] pre-activation gates.
    Returns h: [b, s, nh, dh].
    """
    b, s, nh, dh = q.shape
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))          # [b,s,nh]
    logf_cum = jnp.cumsum(logf, axis=1)
    # D[t, s'] = logf_cum[t] - logf_cum[s'] + ig[s']   for s' <= t
    D = (logf_cum[:, :, None, :] - logf_cum[:, None, :, :]
         + ig.astype(jnp.float32)[:, None, :, :])              # [b,t,s',nh]
    mask = jnp.tril(jnp.ones((s, s), bool))
    D = jnp.where(mask[None, :, :, None], D, -jnp.inf)
    m = jnp.max(D, axis=2, keepdims=True)                      # [b,t,1,nh]
    Dp = jnp.exp(D - m)
    scores = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(dh)
    w = scores * Dp
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-m[:, :, 0]))
    h = jnp.einsum("btsh,bshd->bthd", w, v.astype(jnp.float32))
    h = h / (norm[..., None] + 1e-6)
    return h.astype(q.dtype)


def mlstm_chunked(q, k, v, ig, fg, *, chunk: int = 256):
    """Memory-sane mLSTM: process queries in chunks with running state.
    Exact same math as mlstm_parallel (used for long sequences)."""
    b, s, nh, dh = q.shape
    pad = (-s) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v, ig, fg = map(zf, (q, k, v, ig, fg))
    nc = q.shape[1] // chunk

    def one_chunk(carry, inp):
        C, n, m_run, f_run = carry
        qc, kc, vc, igc, fgc = inp
        h, C, n, m_run, f_run = _mlstm_chunk_step(
            qc, kc, vc, igc, fgc, C, n, m_run, f_run)
        return (C, n, m_run, f_run), h

    C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    m0 = jnp.full((b, nh), -jnp.inf, jnp.float32)
    f0 = jnp.zeros((b, nh), jnp.float32)
    r = lambda a: a.reshape(b, nc, chunk, *a.shape[2:]).transpose(
        1, 0, *range(2, a.ndim + 1))
    _, hs = jax.lax.scan(one_chunk, (C0, n0, m0, f0),
                         (r(q), r(k), r(v), r(ig), r(fg)))
    h = hs.transpose(1, 0, *range(2, hs.ndim)).reshape(b, nc * chunk, nh, dh)
    return h[:, :s].astype(q.dtype)


def _mlstm_chunk_step(q, k, v, ig, fg, C, n, m_run, f_run):
    """One chunk with incoming state (C, n) at stabilizer m_run; f_run is the
    cumulative log-forget up to the chunk start."""
    b, L, nh, dh = q.shape
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    lc = jnp.cumsum(logf, axis=1)                              # [b,L,nh]
    igf = ig.astype(jnp.float32)
    # intra-chunk decay matrix
    D = lc[:, :, None, :] - lc[:, None, :, :] + igf[:, None, :, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(mask[None, :, :, None], D, -jnp.inf)
    # inter contribution decay for each position t: lc[t] (+ state stabilizer)
    m_intra = jnp.max(D, axis=2)                               # [b,L,nh]
    m_inter = lc + m_run[:, None, :]                           # [b,L,nh]
    m_new = jnp.maximum(m_intra, m_inter)
    Dp = jnp.exp(D - m_new[:, :, None, :])
    scores = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(dh)
    w = scores * Dp
    h_intra = jnp.einsum("btsh,bshd->bthd", w, v.astype(jnp.float32))
    denom_intra = jnp.sum(w, axis=2)                           # [b,t,nh]
    inter_scale = jnp.exp(m_inter - m_new)                     # [b,t,nh]
    qf = q.astype(jnp.float32) / jnp.sqrt(dh)
    h_inter = jnp.einsum("bthd,bhde->bthe", qf, C) * inter_scale[..., None]
    denom_inter = jnp.einsum("bthd,bhd->bth", qf, n) * inter_scale
    norm = jnp.maximum(jnp.abs(denom_intra + denom_inter), jnp.exp(-m_new))
    h = (h_intra + h_inter) / (norm[..., None] + 1e-6)
    # update running state to end of chunk
    lc_end = lc[:, -1]                                         # [b,nh]
    m_state_new = jnp.maximum(m_run + lc_end,
                              jnp.max(igf + lc_end[:, None] - lc, axis=1))
    decay_state = jnp.exp(m_run + lc_end - m_state_new)
    kv_decay = jnp.exp(igf + lc_end[:, None] - lc - m_state_new[:, None])
    C = (C * decay_state[..., None, None]
         + jnp.einsum("bsh,bshd,bshe->bhde", kv_decay, k.astype(jnp.float32),
                      v.astype(jnp.float32)))
    n = (n * decay_state[..., None]
         + jnp.einsum("bsh,bshd->bhd", kv_decay, k.astype(jnp.float32)))
    return h.astype(q.dtype), C, n, m_state_new, f_run + lc_end


def mlstm_decode_step(q, k, v, ig, fg, C, n, m):
    """One-token recurrent mLSTM.  q,k,v: [b, nh, dh]; ig,fg: [b, nh];
    state C: [b, nh, dh, dh], n: [b, nh, dh], m: [b, nh]."""
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, ig.astype(jnp.float32))
    C = (C * jnp.exp(logf + m - m_new)[..., None, None]
         + jnp.exp(ig.astype(jnp.float32) - m_new)[..., None, None]
         * jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32),
                      v.astype(jnp.float32)))
    n = (n * jnp.exp(logf + m - m_new)[..., None]
         + jnp.exp(ig.astype(jnp.float32) - m_new)[..., None]
         * k.astype(jnp.float32))
    qf = q.astype(jnp.float32) / jnp.sqrt(q.shape[-1])
    h = jnp.einsum("bhd,bhde->bhe", qf, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                        jnp.exp(-m_new))
    return (h / (denom[..., None] + 1e-6)).astype(q.dtype), C, n, m_new


def slstm_scan(x_gates, r, *, init=None):
    """Sequential sLSTM over time with diagonal recurrence.

    x_gates: [b, s, 4, d] input pre-activations (i, f, z, o); r: [4, d]
    per-channel recurrent weights (g_t = x_proj_t + r * h_{t-1}).
    Returns h: [b, s, d] and final state (c, n, m, h)."""
    b, s, _, d = x_gates.shape

    def step(carry, g):
        c, n, m, h_prev = carry
        g = g + r[None] * h_prev[:, None, :].astype(g.dtype)
        gi, gf, gz, go = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        logf = jax.nn.log_sigmoid(gf.astype(jnp.float32))
        m_new = jnp.maximum(logf + m, gi.astype(jnp.float32))
        i = jnp.exp(gi.astype(jnp.float32) - m_new)
        f = jnp.exp(logf + m - m_new)
        c = f * c + i * jnp.tanh(gz.astype(jnp.float32))
        n = f * n + i
        h = jax.nn.sigmoid(go.astype(jnp.float32)) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    if init is None:
        z = jnp.zeros((b, d), jnp.float32)
        init = (z, z, jnp.full((b, d), -jnp.inf, jnp.float32), z)
    carry, hs = jax.lax.scan(step, init, x_gates.transpose(1, 0, 2, 3))
    return hs.transpose(1, 0, 2).astype(x_gates.dtype), carry
