from repro.kernels.block_sparse_attention.ops import (attention_tile_work,
                                                      block_sparse_attention)
from repro.kernels.block_sparse_attention.ref import (
    block_sparse_attention_ref)

__all__ = ["attention_tile_work", "block_sparse_attention",
           "block_sparse_attention_ref"]
