"""Pure-jnp oracle for the block-sparse flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def block_sparse_attention_ref(q, k, v, block_mask, *, causal: bool = True,
                               block_q: int = 128, block_k: int = 128,
                               sm_scale=None):
    """q: [BH, sq, d]; k, v: [BH, sk, d]; block_mask: [BH, nqb, nkb].

    Exact dense computation of the kernel's semantics: scores masked at
    block granularity (+ token-level causal), softmax with fully-masked-row
    guard."""
    BH, sq, d = q.shape
    sk = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    mask = jnp.repeat(jnp.repeat(block_mask, block_q, axis=1), block_k,
                      axis=2)[:, :sq, :sk] > 0
    if causal:
        mask &= (jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :])[None]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-30)
    out = jnp.where(l > 0, out, 0.0)
    return out.astype(q.dtype)
