"""Block-sparse FlashAttention backward — Pallas TPU kernels.

Recompute-from-lse flash backward (no stored probability matrices): each
tile rebuilds p = exp(q·kᵀ·scale − lse) from the forward's log-sum-exp and
applies the standard dq/dk/dv recurrences.  Both kernels reuse the forward's
block mask, so dead (q-block × kv-block) tiles skip the MXU work in the
backward exactly as in the forward — per-layer backward compute shrinks
proportionally with mask density (paper §2.2 / §4.2.4).

Two sweeps:
  * dq kernel:  grid (BH, q_blocks, kv_blocks), kv innermost — dq[qi] sums
    over the active kv blocks of row qi;
  * dk/dv kernel: grid (BH, kv_blocks, q_blocks), q innermost — dk/dv[ki]
    sum over the active q blocks of column ki.

``delta`` = rowsum(dout ⊙ out) is a cheap elementwise reduction computed in
plain jnp by the vjp wrapper (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.block_sparse_attention.block_sparse_attention import (
    NEG_INF, tile_active, tile_scores)


def _tile_p_ds(q, k, v, do, lse, delta, *, qi, ki, sm_scale, causal,
               block_q, block_k, kv_len, sk_pad):
    """Shared per-tile recompute: returns (p, ds) [bq, bk] in float32."""
    s = tile_scores(q, k, qi, ki, sm_scale=sm_scale, causal=causal,
                    block_q=block_q, block_k=block_k, kv_len=kv_len,
                    sk_pad=sk_pad)                          # [bq, bk]
    p = jnp.exp(s - lse[:, None])
    # masked entries: s=NEG_INF ⇒ p→0 when lse is finite; fully-masked rows
    # have lse≈NEG_INF (sentinel) which would make p spuriously 1 — zero them
    p = jnp.where(lse[:, None] <= NEG_INF / 4, 0.0, p)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                 # [bq, bk]
    ds = p * (dp - delta[:, None]) * sm_scale
    return p, ds


def _dq_kernel(q_ref, k_ref, v_ref, mask_ref, do_ref, lse_ref, delta_ref,
               dq_ref, acc_ref, *, nkb: int, sm_scale: float, causal: bool,
               block_q: int, block_k: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    sk_pad = nkb * block_k

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    active = tile_active(mask_ref[0, 0, 0], qi, ki, causal=causal,
                         block_q=block_q, block_k=block_k, kv_len=kv_len,
                         sk_pad=sk_pad)

    @pl.when(active)
    def _compute():
        k = k_ref[0].astype(jnp.float32)
        _, ds = _tile_p_ds(
            q_ref[0].astype(jnp.float32), k, v_ref[0].astype(jnp.float32),
            do_ref[0].astype(jnp.float32), lse_ref[0], delta_ref[0],
            qi=qi, ki=ki, sm_scale=sm_scale, causal=causal,
            block_q=block_q, block_k=block_k, kv_len=kv_len, sk_pad=sk_pad)
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nkb - 1)
    def _finish():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, mask_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, nqb: int, nkb: int,
                sm_scale: float, causal: bool, block_q: int, block_k: int,
                kv_len: int):
    ki = pl.program_id(1)
    qi = pl.program_id(2)
    sk_pad = nkb * block_k

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    active = tile_active(mask_ref[0, 0, 0], qi, ki, causal=causal,
                         block_q=block_q, block_k=block_k, kv_len=kv_len,
                         sk_pad=sk_pad)

    @pl.when(active)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        p, ds = _tile_p_ds(
            q, k_ref[0].astype(jnp.float32), v_ref[0].astype(jnp.float32),
            do, lse_ref[0], delta_ref[0],
            qi=qi, ki=ki, sm_scale=sm_scale, causal=causal,
            block_q=block_q, block_k=block_k, kv_len=kv_len, sk_pad=sk_pad)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # [bk, d]
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # [bk, d]

    @pl.when(qi == nqb - 1)
    def _finish():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def block_sparse_attention_bwd_p(q, k, v, block_mask, dout, lse, delta, *,
                                 causal: bool = True, block_q: int = 128,
                                 block_k: int = 128,
                                 sm_scale: float | None = None,
                                 kv_len: int | None = None,
                                 interpret: bool = False):
    """Flash backward over pre-padded flat inputs.

    q, dout: [BH, sq, d]; k, v: [BH, sk, d]; block_mask: [BH, nqb, nkb];
    lse, delta: [BH, sq] float32.  Returns (dq, dk, dv) in the input dtypes.
    """
    BH, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    nqb, nkb = sq // block_q, sk // block_k
    assert block_mask.shape == (BH, nqb, nkb), block_mask.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    if kv_len is None:
        kv_len = sk

    q_spec_q = pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0))
    k_spec_q = pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0))
    row_spec_q = pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi))
    dq = pl.pallas_call(
        functools.partial(
            _dq_kernel, nkb=nkb, sm_scale=sm_scale, causal=causal,
            block_q=block_q, block_k=block_k, kv_len=kv_len),
        grid=(BH, nqb, nkb),
        in_specs=[
            q_spec_q, k_spec_q, k_spec_q,
            pl.BlockSpec((1, 1, 1), lambda b, qi, ki: (b, qi, ki)),
            q_spec_q, row_spec_q, row_spec_q,
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, block_mask, dout, lse, delta)

    # kv sweep: grid order (BH, kv_blocks, q_blocks), q innermost
    q_spec_k = pl.BlockSpec((1, block_q, d), lambda b, ki, qi: (b, qi, 0))
    k_spec_k = pl.BlockSpec((1, block_k, d), lambda b, ki, qi: (b, ki, 0))
    row_spec_k = pl.BlockSpec((1, block_q), lambda b, ki, qi: (b, qi))
    dk, dv = pl.pallas_call(
        functools.partial(
            _dkv_kernel, nqb=nqb, nkb=nkb, sm_scale=sm_scale, causal=causal,
            block_q=block_q, block_k=block_k, kv_len=kv_len),
        grid=(BH, nkb, nqb),
        in_specs=[
            q_spec_k, k_spec_k, k_spec_k,
            pl.BlockSpec((1, 1, 1), lambda b, ki, qi: (b, qi, ki)),
            q_spec_k, row_spec_k, row_spec_k,
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda b, ki, qi: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki, qi: (b, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, sk, d), k.dtype),
            jax.ShapeDtypeStruct((BH, sk, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, block_mask, dout, lse, delta)
    return dq, dk, dv
