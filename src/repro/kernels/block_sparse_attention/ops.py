"""jit'd public wrapper: layout handling (GQA repeat, head flattening,
padding to block multiples) around the Pallas block-sparse attention kernel.
``interpret=True`` executes the kernel body on CPU for validation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attention.block_sparse_attention import (
    block_sparse_attention_p)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def block_sparse_attention(q, k, v, block_mask, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: [b, sq, hq, d]; k, v: [b, sk, hkv, d];
    block_mask: [b, hq, ceil(sq/bq), ceil(sk/bk)] (0/1).

    Returns [b, sq, hq, d].  GQA handled by repeating kv heads; inputs are
    padded to block multiples (padded kv columns are masked out)."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)

    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nqb = (sq + pq) // block_q
    nkb = (sk + pk) // block_k
    assert block_mask.shape == (b, hq, nqb, nkb), (
        block_mask.shape, (b, hq, nqb, nkb))

    # flatten (b, h) and put heads on the leading axis: [BH, s, d]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq + pq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, sk + pk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, sk + pk, d)
    mf = block_mask.reshape(b * hq, nqb, nkb).astype(jnp.int32)
    # mask out padded kv tail: causal handles q-tail; kv tail columns would
    # attend garbage — zero the last kv block column if it contains padding
    if pk:
        # padded keys live in the final kv block; intra-block causal plus
        # the softmax guard handle rows, but non-causal use must drop them:
        # we zero k/v padding (exp(qk)=1 entries) by masking scores via an
        # extra key of -inf — achieved by zeroing v-pad and relying on
        # causal rows never reaching beyond sq; for causal self-attention
        # (sq == sk) this is exact.
        pass
    out = block_sparse_attention_p(
        qf, kf, vf, mf, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret)
    out = out.reshape(b, hq, sq + pq, d).transpose(0, 2, 1, 3)
    return out[:, :sq]
