"""jit'd public wrapper: layout handling (GQA repeat, head flattening,
padding to block multiples) around the Pallas block-sparse attention kernel,
plus the custom-VJP that routes the backward through the Pallas flash
backward kernels (backward.py) — masked tiles skip work in both directions.
``interpret=True`` executes the kernel bodies on CPU for validation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_sparse_attention.backward import (
    block_sparse_attention_bwd_p)
from repro.kernels.block_sparse_attention.block_sparse_attention import (
    block_sparse_attention_p)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _bsa_flat(q, k, v, block_mask, causal, block_q, block_k, kv_len,
              interpret):
    """Flat pre-padded attention (q/k/v: [BH, s, d], mask float [BH, nqb,
    nkb]).  Padding / GQA repeat happen OUTSIDE this boundary with
    differentiable jnp ops, so their transposes (slice / group-sum) come for
    free."""
    out, _ = block_sparse_attention_p(
        q, k, v, block_mask.astype(jnp.int32), causal=causal,
        block_q=block_q, block_k=block_k, kv_len=kv_len, interpret=interpret)
    return out


def _bsa_flat_fwd(q, k, v, block_mask, causal, block_q, block_k, kv_len,
                  interpret):
    out, lse = block_sparse_attention_p(
        q, k, v, block_mask.astype(jnp.int32), causal=causal,
        block_q=block_q, block_k=block_k, kv_len=kv_len, interpret=interpret)
    return out, (q, k, v, block_mask, out, lse)


def _bsa_flat_bwd(causal, block_q, block_k, kv_len, interpret, res, dout):
    q, k, v, block_mask, out, lse = res
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                 # [BH, sq]
    dq, dk, dv = block_sparse_attention_bwd_p(
        q, k, v, block_mask.astype(jnp.int32), dout, lse, delta,
        causal=causal, block_q=block_q, block_k=block_k, kv_len=kv_len,
        interpret=interpret)
    return dq, dk, dv, jnp.zeros_like(block_mask)


_bsa_flat.defvjp(_bsa_flat_fwd, _bsa_flat_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def block_sparse_attention(q, k, v, block_mask, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: [b, sq, hq, d]; k, v: [b, sk, hkv, d];
    block_mask: [b, hq, ceil(sq/bq), ceil(sk/bk)] (0/1).

    Returns [b, sq, hq, d].  GQA handled by repeating kv heads; inputs are
    padded to block multiples.  Padded kv columns are masked exactly inside
    the kernels via the static ``kv_len`` (correct for non-causal and
    rectangular use too).  Differentiable: jax.grad routes through the
    Pallas flash backward with the same tile skipping as the forward."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)

    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nqb = (sq + pq) // block_q
    nkb = (sk + pk) // block_k
    assert block_mask.shape == (b, hq, nqb, nkb), (
        block_mask.shape, (b, hq, nqb, nkb))

    # flatten (b, h) and put heads on the leading axis: [BH, s, d]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq + pq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, sk + pk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, sk + pk, d)
    mf = block_mask.reshape(b * hq, nqb, nkb).astype(jnp.float32)
    out = _bsa_flat(qf, kf, vf, mf, causal, block_q, block_k, sk, interpret)
    out = out.reshape(b, hq, sq + pq, d).transpose(0, 2, 1, 3)
    return out[:, :sq]


def attention_tile_work(block_mask, *, causal: bool = True,
                        block_q: int = 128, block_k: int = 128):
    """MXU tile-work accounting using the kernels' own gating predicates.

    block_mask: [..., nqb, nkb] (0/1).  Returns a dict with mean active and
    total (q-block × kv-block) tile counts per head for the forward and the
    backward (dq sweep + dk/dv sweep — each revisits the active tiles once).

    This is ACCOUNTING, not instrumentation: it recomputes the same
    (mask & causal-reachable) predicate the kernels gate on, so by
    construction bwd_ratio == fwd_ratio.  The *measured* signal that the
    backward really skips work is the fwd+bwd wall time reported next to
    these ratios by benchmarks/bench_kernels.py (falls with density), plus
    the gradient-parity tests that pin the predicates' correctness.
    """
    m = np.asarray(block_mask) > 0
    nqb, nkb = m.shape[-2], m.shape[-1]
    if causal:
        qi = np.arange(nqb)[:, None] * block_q + (block_q - 1)
        ki = np.arange(nkb)[None, :] * block_k
        reachable = ki <= qi
        m = m & reachable
        total = int(reachable.sum())
    else:
        total = nqb * nkb
    lead = int(np.prod(m.shape[:-2])) or 1
    active = float(m.sum()) / lead
    return {
        "fwd_active": active, "fwd_total": total,
        "bwd_active": 2.0 * active, "bwd_total": 2 * total,
    }
