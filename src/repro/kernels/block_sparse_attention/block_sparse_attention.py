"""Hash-based block-sparse FlashAttention — Pallas TPU kernel (forward).

TPU adaptation of the paper's dynamic sparse flash attention (§4.2.4): the
hash-derived block mask gates whole (q-block × kv-block) tiles; masked tiles
skip the MXU work via pl.when (the grid slot still iterates, but no DMA
compute is issued — on TPU the saved time is the tile's matmul+softmax).

Tiling: grid = (batch·heads, q_blocks, kv_blocks), kv innermost so the
online-softmax accumulator lives in VMEM scratch across the kv sweep.
Block shapes default to (128, 128) — MXU-aligned.

The forward emits the per-row log-sum-exp alongside the output so the
backward kernels (backward.py) can recompute probabilities tile-by-tile
from (q, k, lse) instead of storing them — the standard flash backward.
``kv_len`` (static) masks key columns beyond the unpadded sequence length,
so ops.py can zero-pad kv to a block multiple without attending garbage in
the non-causal / non-square case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def tile_active(mask_val, qi, ki, *, causal: bool, block_q: int,
                block_k: int, kv_len: int, sk_pad: int):
    """The pl.when tile-gating predicate SHARED by the forward and both
    backward sweeps (backward.py) — these must stay in lockstep, or a tile
    skipped in one direction gets computed in the other and gradients
    silently diverge."""
    active = mask_val > 0
    if causal:
        # whole block above the diagonal band is dead regardless of the mask
        active = jnp.logical_and(
            active, ki * block_k <= qi * block_q + (block_q - 1))
    if kv_len < sk_pad:
        # kv padding exists: blocks fully beyond kv_len are dead
        active = jnp.logical_and(active, ki * block_k < kv_len)
    return active


def tile_scores(q, k, qi, ki, *, sm_scale: float, causal: bool,
                block_q: int, block_k: int, kv_len: int, sk_pad: int):
    """Masked score tile [bq, bk] in fp32 — shared by forward and backward
    (token-level causal + exact padded-kv column masking)."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale
    cols = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        s = jnp.where(rows >= cols, s, NEG_INF)
    if kv_len < sk_pad:
        # padded kv tail: mask token columns exactly (only the last block
        # has cols >= kv_len; elementwise where is cheap)
        s = jnp.where(cols < kv_len, s, NEG_INF)
    return s


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, lse_ref, acc_ref, m_ref,
            l_ref, *, nkb: int, sm_scale: float, causal: bool, block_q: int,
            block_k: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    sk_pad = nkb * block_k

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    active = tile_active(mask_ref[0, 0, 0], qi, ki, causal=causal,
                         block_q=block_q, block_k=block_k, kv_len=kv_len,
                         sk_pad=sk_pad)

    @pl.when(active)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # [bq, d]
        k = k_ref[0].astype(jnp.float32)           # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = tile_scores(q, k, qi, ki, sm_scale=sm_scale, causal=causal,
                        block_q=block_q, block_k=block_k, kv_len=kv_len,
                        sk_pad=sk_pad)             # [bq, bk]
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        # a row with NO live entry so far has m_new == NEG_INF, making
        # p = exp(0) = 1 for its all-masked columns (e.g. block_q > block_k
        # tiles entirely above the diagonal band) — zero it so l stays 0
        p = jnp.where(m_new[:, None] <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nkb - 1)
    def _finish():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        # fully-masked rows (l == 0) emit zeros
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)
        # lse of fully-masked rows stays ~NEG_INF: the backward zeroes their
        # probabilities off that sentinel (zero, not NaN, gradients)
        lse_ref[0] = m_ref[...] + jnp.log(jnp.maximum(l, 1e-30))


def block_sparse_attention_p(q, k, v, block_mask, *, causal: bool = True,
                             block_q: int = 128, block_k: int = 128,
                             sm_scale: float | None = None,
                             kv_len: int | None = None,
                             interpret: bool = False):
    """q: [BH, sq, d]; k, v: [BH, sk, d]; block_mask: [BH, nqb, nkb] int32.

    Shapes must be pre-padded to block multiples (ops.py handles that);
    ``kv_len`` is the unpadded key length (defaults to sk = no padding).
    Returns (out [BH, sq, d], lse [BH, sq] float32)."""
    BH, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    nqb, nkb = sq // block_q, sk // block_k
    assert block_mask.shape == (BH, nqb, nkb), block_mask.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    if kv_len is None:
        kv_len = sk

    kernel = functools.partial(
        _kernel, nkb=nkb, sm_scale=sm_scale, causal=causal,
        block_q=block_q, block_k=block_k, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=(BH, nqb, nkb),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, qi, ki: (b, qi, ki)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, qi, ki: (b, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, sq, d), q.dtype),
            jax.ShapeDtypeStruct((BH, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, block_mask)
