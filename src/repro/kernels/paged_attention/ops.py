"""Public entry points for paged decode attention.

``paged_attention`` keeps the ``decode_attention`` calling convention
(``q [b, 1, h, d]`` in, ``[b, 1, h, d]`` out) so `blocks._attn_fwd` can
swap it in behind ``DistConfig.kernel_impl``; ``paged_tile_work`` is the
host-side accounting of kernel tiles the count-gating actually runs.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.paged_attention import paged_attention_fwd


def paged_attention(q, kp, vp, page_table, cache_len, *,
                    interpret: bool = False):
    """Pallas paged decode attention with the dense-oracle contract.

    q: ``[b, 1, h, d]``; kp/vp: ``[pool+1, page, n_kv, d]``; page_table:
    ``[b, J]`` (-1 unmapped); cache_len: scalar or ``[b]``.
    """
    b = q.shape[0]
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    out = paged_attention_fwd(q[:, 0], kp, vp, page_table, cl,
                              interpret=interpret)
    return out[:, None]


def paged_tile_work(page_table, cache_len, page_size: int):
    """(live, total) kernel tiles for one decode call: a tile is live iff
    its page starts before the lane's ``cache_len`` AND is mapped."""
    pt = np.asarray(page_table)
    jtot = pt.shape[-1]
    pt2 = pt.reshape(-1, jtot)
    cl = np.broadcast_to(np.asarray(cache_len).reshape(-1),
                         (pt2.shape[0],))[:, None]
    j = np.arange(jtot)[None, :]
    live = (j * page_size < cl) & (pt2 >= 0)
    return int(live.sum()), int(pt2.shape[0] * jtot)
