"""Reference paged decode attention: gather K/V blocks through the page
table, then run the unmodified dense ``decode_attention`` oracle.

Because the gathered row holds exactly the bytes a contiguous cache would
hold at every position ``< cache_len`` (unmapped pages resolve to the trash
block, which only ever backs positions ``>= cache_len``), this path is
bit-identical to the dense cache — it IS the token-parity oracle for the
paged subsystem, and the scan-free default (`kernel_impl != "pallas"`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_pages(kp: jax.Array, vp: jax.Array,
                 page_table: jax.Array) -> tuple:
    """Materialise contiguous per-lane K/V rows from the block pool.

    kp/vp: ``[pool+1, page, n_kv, head_dim]`` (last block is trash);
    page_table: ``[b, J]`` int32, ``-1`` = unmapped (resolved to trash).
    Returns two ``[b, J*page, n_kv, head_dim]`` arrays.
    """
    trash = kp.shape[0] - 1
    blk = jnp.where(page_table >= 0, page_table, trash)
    k = kp[blk]                                   # [b, J, page, kv, hd]
    v = vp[blk]
    b, j, page, kv, hd = k.shape
    return (k.reshape(b, j * page, kv, hd), v.reshape(b, j * page, kv, hd))


def paged_attention_ref(q: jax.Array, kp: jax.Array, vp: jax.Array,
                        page_table: jax.Array,
                        cache_len: jax.Array) -> jax.Array:
    """q: ``[b, 1, h, d]``; returns ``[b, 1, h, d]`` — same contract as
    ``decode_attention(q, k_cache, v_cache, cache_len)``.

    Every page covering a position ``< cache_len`` must be mapped; unmapped
    pages may only back positions at or past ``cache_len`` (they gather the
    trash block, which the length mask then excludes).
    """
    from repro.models.layers import decode_attention  # lazy: no import cycle
    k, v = gather_pages(kp, vp, page_table)
    return decode_attention(q, k, v, cache_len)
