"""Paged decode attention: single-token attention over a block-paged KV
pool, gathering K/V through a per-lane page table.

``paged_attention`` (kernel_impl="pallas") is the count-gated Pallas kernel;
``paged_attention_ref`` gathers pages and defers to the dense
``decode_attention`` oracle — bit-identical to a contiguous cache by
construction. ``paged_tile_work`` accounts kernel tiles actually computed.
"""
from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_tile_work)
from repro.kernels.paged_attention.ref import (gather_pages,
                                               paged_attention_ref)

__all__ = ["paged_attention", "paged_attention_ref", "gather_pages",
           "paged_tile_work"]
