"""Pallas paged decode attention forward kernel.

Grid ``(b, J)``: one program per (lane, logical page).  The page table and
per-lane lengths ride as scalar prefetch so the K/V BlockSpec index maps can
steer each program's DMA at the physical block the table names — unmapped
pages are redirected to the trash block and, like pages wholly past
``cache_len``, are count-gated with ``pl.when`` so they cost no MXU work
(mirroring the grouped/pruned kernels' dead-tile gating).

Softmax is accumulated online (flash-style running max / normaliser in VMEM
scratch), finalised on the last page program of each lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref, cl_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, page: int, n_q: int, n_kv: int,
            head_dim: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    clen = cl_ref[i]
    live = (j * page < clen) & (pt_ref[i, j] >= 0)

    @pl.when(live)
    def _page():
        q = q_ref[0].astype(jnp.float32)            # [n_q, hd]
        k = k_ref[0].astype(jnp.float32)            # [page, n_kv, hd]
        v = v_ref[0].astype(jnp.float32)
        gsz = n_q // n_kv
        # grouped q·kᵀ with the kv head as the batch dim (GQA without
        # materialising repeated K)
        q3 = q.reshape(n_kv, gsz, head_dim)
        k3 = jnp.transpose(k, (1, 2, 0))            # [n_kv, hd, page]
        s = jax.lax.dot_general(
            q3, k3, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).reshape(n_q, page)
        s = s / jnp.sqrt(jnp.float32(head_dim))
        tpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (n_q, page), 1)
        s = jnp.where(tpos < clen, s, NEG_INF)      # tail-page mask
        m_prev = m_ref[...]                         # [n_q, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                      # [n_q, page]
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        p3 = p.reshape(n_kv, gsz, page)
        v3 = jnp.transpose(v, (1, 0, 2))            # [n_kv, page, hd]
        pv = jax.lax.dot_general(
            p3, v3, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).reshape(n_q, head_dim)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...]
                    / jnp.where(l > 0.0, l, 1.0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_fwd(q: jax.Array, kp: jax.Array, vp: jax.Array,
                        page_table: jax.Array, cache_len: jax.Array, *,
                        interpret: bool = False) -> jax.Array:
    """q: [b, n_q, hd]; kp/vp: [pool+1, page, n_kv, hd] (last block trash);
    page_table: [b, J] int32 (-1 unmapped); cache_len: [b] int32."""
    b, n_q, head_dim = q.shape
    _, page, n_kv, _ = kp.shape
    jtot = page_table.shape[1]
    trash = kp.shape[0] - 1
    if n_q % n_kv:
        raise ValueError(f"n_q={n_q} not a multiple of n_kv={n_kv}")

    def kv_map(i, j, pt_ref, cl_ref):
        blk = pt_ref[i, j]
        return (jnp.where(blk >= 0, blk, trash), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, jtot),
        in_specs=[
            pl.BlockSpec((1, n_q, head_dim), lambda i, j, *_: (i, 0, 0)),
            pl.BlockSpec((1, page, n_kv, head_dim), kv_map),
            pl.BlockSpec((1, page, n_kv, head_dim), kv_map),
        ],
        out_specs=pl.BlockSpec((1, n_q, head_dim), lambda i, j, *_: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_q, head_dim), jnp.float32),
            pltpu.VMEM((n_q, 1), jnp.float32),
            pltpu.VMEM((n_q, 1), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, page=page, n_q=n_q, n_kv=n_kv,
                             head_dim=head_dim)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n_q, head_dim), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), cache_len.astype(jnp.int32), q, kp, vp)
