"""Backward pass for the grouped expert matmul + tile-work accounting.

dx is the SAME forward kernel with per-expert transposed weights
(dx_g = g_g @ w[e]^T, still row-ragged so the same count-gated tiles skip),
dw runs the dedicated transposed-grid kernel (grouped_matmul_dw_p) that
accumulates x^T @ g over each expert's batch groups with identical
count gating — empty experts cost zero tile work in fwd AND bwd.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

import jax.numpy as jnp

from repro.kernels.grouped_matmul.grouped_matmul import (grouped_matmul_dw_p,
                                                         grouped_matmul_p)


def grouped_matmul_bwd_p(x, w, counts, g, *, gpb: int, bm: int, bn: int,
                         bk: int, interpret: bool = False):
    """x: [G*cap, K], w: [E, K, N], counts: [G], g: [G*cap, N] upstream
    cotangent.  Returns (dx [G*cap, K] in x.dtype, dw [E, K, N] f32).

    Dead rows (>= count) of the cotangent are zeroed first: the forward
    emits zeros there, so they carry no gradient — and the dw kernel's
    partially-live row tiles must not accumulate their garbage."""
    M = x.shape[0]
    cap = gpb * bm
    live = (jnp.arange(M) % cap) < jnp.repeat(counts, cap)
    g = g * live[:, None].astype(g.dtype)
    dx = grouped_matmul_p(g, w.transpose(0, 2, 1), counts, gpb=gpb,
                          bm=bm, bn=bk, bk=bn, interpret=interpret)
    dw = grouped_matmul_dw_p(x, g, counts, num_experts=w.shape[0], gpb=gpb,
                             bm=bm, bn=bn, bk=bk, interpret=interpret)
    return dx.astype(x.dtype), dw


def grouped_tile_work(counts, cap: int, *, bm: int = 8
                      ) -> Dict[str, float]:
    """MXU row-tile accounting at measured routed load: active vs total
    (group, row-tile) cells for the forward and the dx+dw backward.  The
    fwd/bwd ratios are what BENCH_moe reports — on CPU interpret mode wall
    time is not TPU time, but the skipped-tile fraction is exact."""
    counts = np.asarray(counts)
    gpb = max(1, -(-cap // bm))
    active = int(np.sum(np.minimum(-(-counts // bm), gpb)))
    total = int(counts.size * gpb)
    return {
        "fwd_active": active, "fwd_total": total,
        "bwd_active": 2 * active, "bwd_total": 2 * total,
    }
