from repro.kernels.grouped_matmul.backward import (grouped_matmul_bwd_p,
                                                   grouped_tile_work)
from repro.kernels.grouped_matmul.grouped_matmul import (grouped_matmul_dw_p,
                                                         grouped_matmul_p)
from repro.kernels.grouped_matmul.ops import grouped_matmul
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref

__all__ = [
    "grouped_matmul", "grouped_matmul_ref", "grouped_matmul_p",
    "grouped_matmul_dw_p", "grouped_matmul_bwd_p", "grouped_tile_work",
]
