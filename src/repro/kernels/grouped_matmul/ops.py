"""jit'd wrapper + custom-VJP for the grouped expert matmul.

Shape/padding policy (all differentiable jnp ops OUTSIDE the vjp boundary,
same layout as pruned_matmul.ops):
  * per-group rows pad cap -> cap_g (next multiple of bm), K/N pad to
    bk/bn multiples;
  * dead rows (>= count) of x are zeroed before the kernel, so the public
    semantics are "rows past the count are dead" no matter what the caller
    left in the padding — the reference oracle masks identically;
  * counts cross the custom_vjp as float32 (int leaves would need float0
    cotangents); the kernels compare them against int row indices directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.grouped_matmul.backward import grouped_matmul_bwd_p
from repro.kernels.grouped_matmul.grouped_matmul import grouped_matmul_p


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _gm_flat(x, w, counts_f, gpb, bm, bn, bk, interpret):
    """Flat pre-padded grouped matmul (x: [G*cap_g, K], w: [E, K, N],
    counts_f: [G] float32)."""
    return grouped_matmul_p(x, w, counts_f, gpb=gpb, bm=bm, bn=bn, bk=bk,
                            interpret=interpret)


def _gm_flat_fwd(x, w, counts_f, gpb, bm, bn, bk, interpret):
    out = _gm_flat(x, w, counts_f, gpb, bm, bn, bk, interpret)
    return out, (x, w, counts_f)


def _gm_flat_bwd(gpb, bm, bn, bk, interpret, res, g):
    x, w, counts_f = res
    dx, dw = grouped_matmul_bwd_p(x, w, counts_f, g, gpb=gpb, bm=bm, bn=bn,
                                  bk=bk, interpret=interpret)
    return dx, dw.astype(w.dtype), jnp.zeros_like(counts_f)


_gm_flat.defvjp(_gm_flat_fwd, _gm_flat_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_matmul(x, w, counts, *, bm: int = 8, bn: int = 128,
                   bk: int = 128, interpret: bool = False):
    """Ragged grouped matmul: x [G, cap, K] (G groups of up to ``counts[g]``
    live rows each), w [E, K, N] with G % E == 0 (group g uses w[g % E]),
    counts [G] int.  Returns [G, cap, N]; rows past each group's count are
    zero.  Differentiable in x and w; empty groups skip all tile work in
    forward and backward."""
    G, cap, K = x.shape
    E, _, N = w.shape
    assert G % E == 0, (G, E)
    cap_g = cap + (-cap) % bm
    gpb = cap_g // bm
    live = jnp.arange(cap)[None, :] < counts[:, None]
    x = x * live[..., None].astype(x.dtype)
    pk = (-K) % bk
    pn = (-N) % bn
    x = jnp.pad(x, ((0, 0), (0, cap_g - cap), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, 0), (0, pk), (0, pn)))
    out = _gm_flat(x.reshape(G * cap_g, K + pk), w,
                   counts.astype(jnp.float32), gpb, bm, bn, bk, interpret)
    return out.reshape(G, cap_g, N + pn)[:, :cap, :N]
