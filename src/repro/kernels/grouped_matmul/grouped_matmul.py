"""Pallas grouped/ragged expert matmul (MoE sort -> matmul -> unsort path).

Tokens are pre-sorted by (batch row, physical expert group) into a
``[G, cap, K]`` buffer (G = b * E groups, each zero-padded to ``cap`` rows);
``counts[g]`` is the number of live rows in group g.  The grid tiles
(group, row-tile, n-tile, k-tile) and a row tile whose first row is past the
group's count is **skipped entirely** (``pl.when`` on the count scalar —
data-dependent, no recompile when routing changes), so an empty expert costs
zero MXU tile work and a cold expert costs work proportional to its load,
not to the capacity bound — unlike the dense GShard capacity einsum which
pays full ``cap`` rows per expert unconditionally.

Group g uses weight ``w[g % E]``: groups are batch-major (g = bi * E + e)
so every batch row's expert-e tokens hit the same expert weights.

The counts ride in as a 1-D array with a ``(1,)`` BlockSpec (same idiom as
pruned_matmul's block mask) — proven on both interpret and compiled paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scratch(shape):
    return pltpu.VMEM(shape, jnp.float32)


def _gm_kernel(x_ref, w_ref, c_ref, o_ref, acc_ref, *, nkb, bm):
    """One (group, row-tile, n-tile, k-tile) cell; k innermost accumulates."""
    i = pl.program_id(1)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # live-row tile test: rows are packed front-of-group, so a tile whose
    # first row index reaches the count holds no live rows at all
    @pl.when(i * bm < c_ref[0])
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nkb - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul_p(x, w, counts, *, gpb: int, bm: int, bn: int, bk: int,
                     interpret: bool = False):
    """x: [G*cap, K] row-sorted groups (cap = gpb*bm rows each, dead rows
    zero), w: [E, K, N] with G % E == 0, counts: [G].  Returns [G*cap, N].
    K/N must be block multiples (pad outside)."""
    M, K = x.shape
    E, _, N = w.shape
    G = M // (gpb * bm)
    assert M == G * gpb * bm and G % E == 0, (M, G, gpb, bm, E)
    assert K % bk == 0 and N % bn == 0, (K, bk, N, bn)
    nkb = K // bk
    grid = (G, gpb, N // bn, nkb)
    return pl.pallas_call(
        functools.partial(_gm_kernel, nkb=nkb, bm=bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda g, i, j, k: (g * gpb + i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g % E, k, j)),
            pl.BlockSpec((1,), lambda g, i, j, k: (g,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda g, i, j, k: (g * gpb + i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[_scratch((bm, bn))],
        interpret=interpret,
    )(x, w, counts)


def _gm_dw_kernel(x_ref, g_ref, c_ref, o_ref, acc_ref, *, nrb, bm, gpb):
    """dw[e] = sum over batch groups of x_{b,e}^T @ g_{b,e}; the row-chunk
    axis r (innermost) walks every (batch, row-tile) pair of expert e."""
    r = pl.program_id(3)

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((r % gpb) * bm < c_ref[0])
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(r == nrb - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul_dw_p(x, g, counts, *, num_experts: int, gpb: int,
                        bm: int, bn: int, bk: int, interpret: bool = False):
    """x: [G*cap, K], g: [G*cap, N] (dead rows zero in both), counts: [G].
    Returns dw [E, K, N] summing each expert's groups across batch rows —
    the same ragged tile skipping as the forward, transposed."""
    M, K = x.shape
    _, N = g.shape
    E = num_experts
    G = M // (gpb * bm)
    assert G % E == 0, (G, E)
    nrb = (G // E) * gpb
    row = lambda e, r: ((r // gpb) * E + e) * gpb + (r % gpb)
    grid = (E, K // bk, N // bn, nrb)
    return pl.pallas_call(
        functools.partial(_gm_dw_kernel, nrb=nrb, bm=bm, gpb=gpb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda e, kk, j, r: (row(e, r), kk)),
            pl.BlockSpec((bm, bn), lambda e, kk, j, r: (row(e, r), j)),
            pl.BlockSpec((1,), lambda e, kk, j, r: ((r // gpb) * E + e,)),
        ],
        out_specs=pl.BlockSpec((1, bk, bn), lambda e, kk, j, r: (e, kk, j)),
        out_shape=jax.ShapeDtypeStruct((E, K, N), jnp.float32),
        scratch_shapes=[_scratch((bk, bn))],
        interpret=interpret,
    )(x, g, counts)
