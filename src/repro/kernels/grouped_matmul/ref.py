"""fp32 reference oracle for the grouped expert matmul.

Same semantics as the kernel: rows at or past a group's count are dead
(treated as zero regardless of their contents), group g uses expert weight
``w[g % E]``, accumulation in float32.  The MoE capacity-einsum path in
``models.blocks.moe_ffn`` composes this per-projection contract; tests pin
the kernel against it."""
from __future__ import annotations

import jax.numpy as jnp


def grouped_matmul_ref(x, w, counts):
    """x: [G, cap, K], w: [E, K, N] (G % E == 0), counts: [G] ->
    [G, cap, N]."""
    G, cap, _ = x.shape
    E = w.shape[0]
    live = jnp.arange(cap)[None, :] < counts[:, None]
    xm = x * live[..., None].astype(x.dtype)
    wg = w[jnp.arange(G) % E]
    out = jnp.einsum("gck,gkn->gcn", xm.astype(jnp.float32),
                     wg.astype(jnp.float32))
    return out.astype(x.dtype)
