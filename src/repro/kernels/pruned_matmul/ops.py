"""jit'd wrappers: padding + reshaping around the pruned matmul kernel, and
the fused block-pruned SwiGLU built from the two mask positions."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pruned_matmul.pruned_matmul import pruned_matmul_p


@functools.partial(jax.jit, static_argnames=("mask_axis", "bm", "bn", "bk",
                                             "interpret"))
def pruned_matmul(x, w, block_mask, *, mask_axis: str = "n", bm: int = 128,
                  bn: int = 128, bk: int = 128, interpret: bool = False):
    """x: [..., K] @ w: [K, N] with block mask; pads M/K/N to block
    multiples.  block_mask granularity must match (N//bn or K//bk of the
    *unpadded* shapes, which must already be block-multiples for the masked
    axis)."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    pm = (-M) % bm
    if pm:
        x2 = jnp.pad(x2, ((0, pm), (0, 0)))
    # the MASKED dim must be an exact multiple of its block (the mask
    # defines the granularity); the other dims are zero-padded freely
    if mask_axis == "n":
        assert N % bn == 0, ("masked dim must be a block multiple", N, bn)
        pk = (-K) % bk
        if pk:
            x2 = jnp.pad(x2, ((0, 0), (0, pk)))
            w = jnp.pad(w, ((0, pk), (0, 0)))
        out = pruned_matmul_p(x2, w, block_mask, mask_axis="n", bm=bm,
                              bn=bn, bk=bk, interpret=interpret)
    else:
        assert K % bk == 0, ("masked dim must be a block multiple", K, bk)
        pn = (-N) % bn
        if pn:
            w = jnp.pad(w, ((0, 0), (0, pn)))
        out = pruned_matmul_p(x2, w, block_mask, mask_axis="k", bm=bm,
                              bn=bn, bk=bk, interpret=interpret)
        out = out[:, :N]
    return out[:M, :N].reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def pruned_swiglu(x, wi, wg, wo, block_mask, *, bf: int = 128,
                  interpret: bool = False):
    """Block-pruned SwiGLU MLP: up-projections mask output blocks ('n'),
    the down-projection skips the same blocks as reduction blocks ('k') —
    both matmuls genuinely skip the pruned tiles."""
    a = pruned_matmul(x, wg, block_mask, mask_axis="n", bn=bf,
                      interpret=interpret)
    b = pruned_matmul(x, wi, block_mask, mask_axis="n", bn=bf,
                      interpret=interpret)
    h = jax.nn.silu(a.astype(jnp.float32)) * b.astype(jnp.float32)
    return pruned_matmul(h.astype(x.dtype), wo, block_mask, mask_axis="k",
                         bk=bf, interpret=interpret)
