"""jit'd wrappers: padding + reshaping around the pruned matmul kernel, the
fused block-pruned SwiGLU built from the two mask positions, and the
custom-VJP that routes dx/dw through the same Pallas kernel with the mask
transposed between the "n" and "k" slots (backward.py) — pruned blocks skip
tile work in the backward too."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pruned_matmul.backward import pruned_matmul_bwd_p
from repro.kernels.pruned_matmul.pruned_matmul import pruned_matmul_p


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _pm_flat(x, w, block_mask, mask_axis, bm, bn, bk, interpret):
    """Flat pre-padded pruned matmul (x: [M, K], w: [K, N], mask float).
    Padding happens OUTSIDE this boundary with differentiable jnp ops."""
    return pruned_matmul_p(x, w, block_mask.astype(jnp.int32),
                           mask_axis=mask_axis, bm=bm, bn=bn, bk=bk,
                           interpret=interpret)


def _pm_flat_fwd(x, w, block_mask, mask_axis, bm, bn, bk, interpret):
    out = _pm_flat(x, w, block_mask, mask_axis, bm, bn, bk, interpret)
    return out, (x, w, block_mask)


def _pm_flat_bwd(mask_axis, bm, bn, bk, interpret, res, g):
    x, w, block_mask = res
    dx, dw = pruned_matmul_bwd_p(
        x, w, block_mask.astype(jnp.int32), g.astype(jnp.float32),
        mask_axis=mask_axis, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return dx, dw, jnp.zeros_like(block_mask)


_pm_flat.defvjp(_pm_flat_fwd, _pm_flat_bwd)


@functools.partial(jax.jit, static_argnames=("mask_axis", "bm", "bn", "bk",
                                             "interpret"))
def pruned_matmul(x, w, block_mask, *, mask_axis: str = "n", bm: int = 128,
                  bn: int = 128, bk: int = 128, interpret: bool = False):
    """x: [..., K] @ w: [K, N] with block mask; pads M/K/N to block
    multiples.  block_mask granularity must match (N//bn or K//bk of the
    *unpadded* shapes, which must already be block-multiples for the masked
    axis).  Differentiable: dx/dw run through the Pallas kernel with the
    mask in the transposed slot (same tile skipping as the forward)."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    # the MASKED dim must be an exact multiple of its block (the mask
    # defines the granularity); the other dims are zero-padded freely
    if mask_axis == "n":
        assert N % bn == 0, ("masked dim must be a block multiple", N, bn)
    else:
        assert K % bk == 0, ("masked dim must be a block multiple", K, bk)
    pm = (-M) % bm
    pk = (-K) % bk
    pn = (-N) % bn
    if pm or pk:
        x2 = jnp.pad(x2, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    out = _pm_flat(x2, w, block_mask.astype(jnp.float32), mask_axis,
                   bm, bn, bk, interpret)
    return out[:M, :N].reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def pruned_swiglu(x, wi, wg, wo, block_mask, *, bf: int = 128,
                  interpret: bool = False):
    """Block-pruned SwiGLU MLP: up-projections mask output blocks ('n'),
    the down-projection skips the same blocks as reduction blocks ('k') —
    both matmuls genuinely skip the pruned tiles, forward and backward."""
    a = pruned_matmul(x, wg, block_mask, mask_axis="n", bn=bf,
                      interpret=interpret)
    b = pruned_matmul(x, wi, block_mask, mask_axis="n", bn=bf,
                      interpret=interpret)
    h = jax.nn.silu(a.astype(jnp.float32)) * b.astype(jnp.float32)
    return pruned_matmul(h.astype(x.dtype), wo, block_mask, mask_axis="k",
                         bk=bf, interpret=interpret)
