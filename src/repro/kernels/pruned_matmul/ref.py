"""Pure-jnp oracles for the pruned matmul kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pruned_matmul_ref(x, w, block_mask, *, mask_axis: str = "n",
                      bn: int = 128, bk: int = 128):
    """Exact dense semantics of the kernel (fp32 accumulation)."""
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if mask_axis == "n":
        m = jnp.repeat(block_mask.astype(jnp.float32), bn)
        out = (xf @ wf) * m[None, :]
    else:
        m = jnp.repeat(block_mask.astype(jnp.float32), bk)
        out = (xf * m[None, :]) @ wf
    return out.astype(x.dtype)


def pruned_swiglu_ref(x, wi, wg, wo, block_mask, *, bf: int = 128):
    """Block-pruned SwiGLU: mask over d_ff blocks."""
    m = jnp.repeat(block_mask.astype(jnp.float32), bf)
    h = jax.nn.silu(x.astype(jnp.float32) @ wg.astype(jnp.float32))
    h = h * (x.astype(jnp.float32) @ wi.astype(jnp.float32))
    h = h * m[None, :]
    return (h @ wo.astype(jnp.float32)).astype(x.dtype)
