"""Block-pruned matmul backward — built from the same Pallas kernel.

The backward of a block-pruned matmul is itself a block-pruned matmul with
the mask moved between the "n" (output-column) and "k" (reduction) slots:

  mask over N:  out = (x @ w) ⊙ m_N
      dx = (g ⊙ m_N) @ wᵀ   — m in the REDUCTION slot of a [M,N]@[N,K] GEMM
      dw = xᵀ @ (g ⊙ m_N)   — m stays in the output-column slot
  mask over K:  out = (x ⊙ m_K) @ w
      dx = (g @ wᵀ) ⊙ m_K   — m moves to the output-column slot
      dw = m_K ⊙ (xᵀ @ g)   — row mask ⇒ computed transposed, m in the
                               output-column slot of gᵀ @ x, then .T

All four products run through ``pruned_matmul_p`` — pruned blocks skip the
MXU tiles in the backward exactly as in the forward, which is where the
paper's per-layer backward compute reduction (§2.2/§4.2.2) comes from.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.pruned_matmul.pruned_matmul import pruned_matmul_p


def pruned_matmul_bwd_p(x, w, block_mask, g, *, mask_axis: str = "n",
                        bm: int = 128, bn: int = 128, bk: int = 128,
                        interpret: bool = False):
    """dx, dw for out = pruned_matmul_p(x, w, mask).  x: [M, K]; w: [K, N];
    g: [M, N]; all dims pre-padded to block multiples (ops.py)."""
    if mask_axis == "n":
        dx = pruned_matmul_p(g, w.T, block_mask, mask_axis="k",
                             bm=bm, bn=bk, bk=bn, interpret=interpret)
        dw = pruned_matmul_p(x.T, g, block_mask, mask_axis="n",
                             bm=bk, bn=bn, bk=bm, interpret=interpret)
    else:
        dx = pruned_matmul_p(g, w.T, block_mask, mask_axis="n",
                             bm=bm, bn=bk, bk=bn, interpret=interpret)
        dw = pruned_matmul_p(g.T, x, block_mask, mask_axis="n",
                             bm=bn, bn=bk, bk=bm, interpret=interpret).T
    return dx.astype(x.dtype), dw.astype(w.dtype)


def matmul_tile_work(M: int, K: int, N: int, block_mask, *,
                     mask_axis: str = "n", bm: int = 128, bn: int = 128,
                     bk: int = 128):
    """MXU tile-work accounting mirroring the kernels' pl.when gating.

    Forward grid is (M/bm, N/bn, K/bk); a pruned block kills the whole
    row/column of tiles it gates.  Backward = dx product + dw product, each
    gated by the same mask (see pruned_matmul_bwd_p)."""
    keep = float((np.asarray(block_mask) > 0).mean())
    nmb = -(-M // bm)
    nnb = -(-N // bn)
    nkb = -(-K // bk)
    fwd_total = nmb * nnb * nkb
    # both mask positions gate the same fraction of the K-sweep tiles
    fwd_active = fwd_total * keep
    # dx: [M,N]x[N,K] grid nmb*nkb*nnb; dw: [K,M]x[M,N] grid nkb*nnb*nmb
    bwd_total = 2 * fwd_total
    bwd_active = bwd_total * keep
    return {
        "fwd_active": fwd_active, "fwd_total": fwd_total,
        "bwd_active": bwd_active, "bwd_total": bwd_total,
    }
