from repro.kernels.pruned_matmul.ops import (pruned_matmul,
                                             pruned_swiglu)
from repro.kernels.pruned_matmul.ref import (pruned_matmul_ref,
                                             pruned_swiglu_ref)

__all__ = ["pruned_matmul", "pruned_swiglu", "pruned_matmul_ref",
           "pruned_swiglu_ref"]
