from repro.kernels.pruned_matmul.backward import matmul_tile_work
from repro.kernels.pruned_matmul.ops import (pruned_matmul,
                                             pruned_swiglu)
from repro.kernels.pruned_matmul.ref import (pruned_matmul_ref,
                                             pruned_swiglu_ref)

__all__ = ["matmul_tile_work", "pruned_matmul", "pruned_swiglu",
           "pruned_matmul_ref", "pruned_swiglu_ref"]
