"""Block-structured pruned matmul — Pallas TPU kernel.

TPU adaptation of Sputnik-style sparse matmul (paper §4.2.2): unstructured
CSR cannot accelerate the MXU's dense 128×128 tiles, so pruning removes
feature *blocks* (width = MXU tile) and the kernel skips dead blocks with
pl.when — zero DMA, zero MXU work for pruned tiles, which is where the
paper's per-layer compute reduction (p_i^(k)·c_i, §2.2) physically comes
from on TPU.

Two mask positions:
  * mask over N (output-feature blocks): pruned output columns are zeros —
    used for the FFN up-projection x@W1;
  * mask over K (reduction blocks): pruned rows skip accumulation — used for
    the down-projection h@W2 (h's pruned columns are dead anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_mask_n(x_ref, w_ref, mask_ref, o_ref, acc_ref, *, nkb: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[0] > 0)
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nkb - 1)
    def _finish():
        o_ref[...] = jnp.where(mask_ref[0] > 0,
                               acc_ref[...], 0.0).astype(o_ref.dtype)


def _kernel_mask_k(x_ref, w_ref, mask_ref, o_ref, acc_ref, *, nkb: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[0] > 0)
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nkb - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pruned_matmul_p(x, w, block_mask, *, mask_axis: str = "n",
                    bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool = False):
    """x: [M, K] @ w: [K, N] with a 0/1 block mask.

    mask_axis='n': block_mask [N // bn]; pruned output-column blocks skipped.
    mask_axis='k': block_mask [K // bk]; pruned reduction blocks skipped.
    Shapes must be multiples of the block sizes (ops.py pads)."""
    M, K = x.shape
    _, N = w.shape
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N)
    nkb = K // bk
    if mask_axis == "n":
        assert block_mask.shape == (N // bn,), block_mask.shape
        kernel = functools.partial(_kernel_mask_n, nkb=nkb)
        mask_spec = pl.BlockSpec((1,), lambda i, j, k_: (j,))
    else:
        assert block_mask.shape == (nkb,), block_mask.shape
        kernel = functools.partial(_kernel_mask_k, nkb=nkb)
        mask_spec = pl.BlockSpec((1,), lambda i, j, k_: (k_,))
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nkb),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k_: (i, k_)),
            pl.BlockSpec((bk, bn), lambda i, j, k_: (k_, j)),
            mask_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k_: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, block_mask.astype(jnp.int32))
