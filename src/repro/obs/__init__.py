"""Unified observability layer (DESIGN.md §15).

Three pillars, one package:

  * ``obs.timing``  — in-step stage timing: host-callback timestamps at
    stage boundaries *inside* the pipelined jitted step, so the
    controller's cost vector reflects the step it just ran (ROADMAP open
    item 5).  Imported lazily by the pipeline/engine (it needs jax).
  * ``obs.trace``   — span-based structured tracing (trace_id / span_id /
    parent, wall + logical-clock stamps) exported as Chrome trace-event
    JSON, loadable in Perfetto.  Stdlib-only.
  * ``obs.metrics`` — a counters/gauges/histograms registry with
    Prometheus text exposition and a JSON snapshot for CI.  Stdlib-only.
  * ``obs.events``  — the unified event-record schema shared by the
    session telemetry stream, the fault-event log, and the cluster
    scheduler's grant timeline.

``obs.timing`` is deliberately NOT imported here: the cluster manager
processes import ``obs.trace``/``obs.metrics`` and must not pull in jax.
"""
from repro.obs.events import EVENT_SCHEMA, stamp_record
from repro.obs.metrics import MetricsRegistry, scheduler_to_prometheus
from repro.obs.trace import Tracer, current_tracer, set_current_tracer

__all__ = [
    "EVENT_SCHEMA", "stamp_record", "MetricsRegistry",
    "scheduler_to_prometheus", "Tracer", "current_tracer",
    "set_current_tracer",
]
