"""The unified event-record schema (DESIGN.md §15).

Before this layer the repo had grown three uncorrelated event streams:

  * session telemetry (``SessionEvent`` → ``--events-out``)
  * the fault-event log (``faults.injector.FaultRecord``)
  * the cluster scheduler's grant timeline (``ClusterScheduler.events``)

All three now share one record shape — their legacy field names are kept
as-is (aliases, one release), and each record *additionally* carries:

  ``schema``     "obs.event/1"
  ``source``     "session" | "fault" | "scheduler"
  ``kind``       the event kind (scheduler records alias their legacy
                 ``ev`` field here)
  ``wall``       unix wall stamp (absent on replayed/journaled records)
  ``trace_id`` / ``span_id`` / ``parent_id`` / ``lc``
                 tracing identity, when a tracer (local or propagated
                 over RPC) is in scope; ``lc`` is the source's logical
                 clock — comparable within a source, not across them.

``stamp_record`` is the single mutator every producer calls.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

from repro.obs import trace as _trace

EVENT_SCHEMA = "obs.event/1"


def stamp_record(rec: Dict[str, Any], *, source: str,
                 kind: Optional[str] = None,
                 tracer: Optional["_trace.Tracer"] = None,
                 ctx: Optional[Dict[str, Any]] = None,
                 wall: bool = True) -> Dict[str, Any]:
    """Attach the unified-schema fields to ``rec`` in place.

    ``ctx`` is a foreign span context (e.g. carried over RPC): its
    trace_id/span_id become this record's trace identity/parent.  A local
    ``tracer`` (defaults to the process-current one) mints fresh ids.
    """
    rec.setdefault("schema", EVENT_SCHEMA)
    rec.setdefault("source", source)
    if kind is not None:
        rec.setdefault("kind", kind)
    if wall and "wall" not in rec:
        rec["wall"] = time.time()
    tr = tracer if tracer is not None else _trace.current_tracer()
    if tr is not None:
        rec.update(tr.event_context())
    elif ctx:
        rec.setdefault("trace_id", ctx.get("trace_id"))
        rec.setdefault("parent_id", ctx.get("span_id"))
    elif ctx is not None:
        pass
    if ctx and tr is not None:
        # a local tracer AND a foreign cause: keep local identity, parent
        # onto the foreign span so cross-process chains correlate
        rec["parent_id"] = ctx.get("span_id") or rec.get("parent_id")
        rec.setdefault("cause_trace_id", ctx.get("trace_id"))
    return rec
