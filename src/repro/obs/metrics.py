"""Metrics registry (DESIGN.md §15): counters, gauges, histograms behind
one API, with Prometheus text exposition (``GET /metrics``) and a JSON
snapshot for CI artifacts.

Stdlib-only — the cluster manager process serves ``/metrics`` from the
same registry code without importing jax.  Metric identity is
``(name, sorted(labels))``; helps are attached on first touch.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple

SNAPSHOT_SCHEMA = "obs.metrics/1"

# latency-ish default buckets, seconds (also fine for fractions/counts)
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)


def _key(name: str, labels: Dict[str, Any]) -> Tuple[str, tuple]:
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_labels(label_items: tuple) -> str:
    if not label_items:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in label_items)
    return "{%s}" % inner


class MetricsRegistry:
    """One process-local registry; thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, tuple], float] = {}
        self._gauges: Dict[Tuple[str, tuple], float] = {}
        self._hists: Dict[Tuple[str, tuple], Dict[str, Any]] = {}
        self._help: Dict[str, str] = {}
        self._buckets: Dict[str, tuple] = {}

    # -- write API ----------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, help: str = "",
            **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0.0) + value
            if help:
                self._help.setdefault(name, help)

    def set(self, name: str, value: float, help: str = "",
            **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            self._gauges[k] = float(value)
            if help:
                self._help.setdefault(name, help)

    def observe(self, name: str, value: float, help: str = "",
                buckets: Optional[tuple] = None, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            bks = self._buckets.setdefault(name, buckets or DEFAULT_BUCKETS)
            h = self._hists.get(k)
            if h is None:
                h = self._hists[k] = {"counts": [0] * (len(bks) + 1),
                                      "sum": 0.0, "count": 0}
            for i, b in enumerate(bks):
                if value <= b:
                    h["counts"][i] += 1
                    break
            else:
                h["counts"][-1] += 1
            h["sum"] += float(value)
            h["count"] += 1
            if help:
                self._help.setdefault(name, help)

    # -- read API -----------------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        with self._lock:
            lines = []
            seen_type: Dict[str, str] = {}

            def head(name, mtype):
                if seen_type.get(name) != mtype:
                    seen_type[name] = mtype
                    if name in self._help:
                        lines.append(f"# HELP {name} {self._help[name]}")
                    lines.append(f"# TYPE {name} {mtype}")

            for (name, li), v in sorted(self._counters.items()):
                head(name, "counter")
                lines.append(f"{name}{_fmt_labels(li)} {_num(v)}")
            for (name, li), v in sorted(self._gauges.items()):
                head(name, "gauge")
                lines.append(f"{name}{_fmt_labels(li)} {_num(v)}")
            for (name, li), h in sorted(self._hists.items()):
                head(name, "histogram")
                bks = self._buckets[name]
                cum = 0
                for i, b in enumerate(bks):
                    cum += h["counts"][i]
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(li + (('le', _num(b)),))} {cum}")
                cum += h["counts"][-1]
                lines.append(
                    f"{name}_bucket{_fmt_labels(li + (('le', '+Inf'),))} "
                    f"{cum}")
                lines.append(f"{name}_sum{_fmt_labels(li)} {_num(h['sum'])}")
                lines.append(f"{name}_count{_fmt_labels(li)} {h['count']}")
            return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able state dump (the CI artifact format, golden-pinned)."""
        with self._lock:
            def unkey(d):
                return [{"name": name, "labels": dict(li), "value": v}
                        for (name, li), v in sorted(d.items())]
            hists = []
            for (name, li), h in sorted(self._hists.items()):
                hists.append({"name": name, "labels": dict(li),
                              "buckets": list(self._buckets[name]),
                              "counts": list(h["counts"]),
                              "sum": h["sum"], "count": h["count"]})
            return {"schema": SNAPSHOT_SCHEMA,
                    "counters": unkey(self._counters),
                    "gauges": unkey(self._gauges),
                    "histograms": hists}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
        return path


def _num(v) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


# ---------------------------------------------------------------------------
# scheduler -> Prometheus (the manager's GET /metrics)
# ---------------------------------------------------------------------------
def scheduler_to_prometheus(sched) -> str:
    """Render a ``ClusterScheduler``'s grant timeline + tenant state as
    Prometheus text.  Event counters are derived from the same ``events``
    list the ``metrics`` RPC verb returns, so scraped counters and the
    events stream can never disagree (asserted by cluster_smoke)."""
    reg = MetricsRegistry()
    for ev in sched.events:
        reg.inc("dynmo_scheduler_events_total",
                help="scheduler grant-timeline events by tenant and kind",
                tenant=ev["tenant"], event=ev["ev"])
    for t in sched.tenants.values():
        reg.set("dynmo_workers_granted", len(t.granted),
                help="workers currently granted to the tenant",
                tenant=t.tenant_id)
        reg.set("dynmo_tenant_priority", t.priority,
                help="tenant priority (higher steals first)",
                tenant=t.tenant_id)
        reg.set("dynmo_preempt_due", t.preempt_due,
                help="workers the tenant still owes to preemption",
                tenant=t.tenant_id)
    reg.set("dynmo_pool_active", sched.pool.total + sched.pool.spares,
            help="total workers in the shared pool (incl. spares)")
    return reg.to_prometheus()


# ---------------------------------------------------------------------------
# optional in-process /metrics endpoint (obs.metrics_port)
# ---------------------------------------------------------------------------
class _MetricsHandler(BaseHTTPRequestHandler):
    def do_GET(self):          # noqa: N802 (stdlib API)
        if self.path not in ("/metrics", "/"):
            self.send_response(404)
            self.end_headers()
            return
        body = self.server.registry.to_prometheus().encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *a):  # quiet
        pass


def serve_metrics(registry: MetricsRegistry, port: int,
                  host: str = "127.0.0.1") -> ThreadingHTTPServer:
    """Expose ``registry`` at ``http://host:port/metrics`` on a daemon
    thread; caller shuts down with ``server.shutdown()``."""
    srv = ThreadingHTTPServer((host, port), _MetricsHandler)
    srv.registry = registry
    threading.Thread(target=srv.serve_forever, daemon=True,
                     name="obs-metrics").start()
    return srv
