"""In-step stage timing (DESIGN.md §15; ROADMAP open item 5).

The old stage profiler (``ElasticEngine.measure_stage_times``) runs a
*separate* bounded-loop execution per stage — an isolated probe that costs
a full extra forward and measures something other than the live step.
This module instead stamps host timestamps at the stage boundaries of the
real pipelined jitted step:

  * ``make_stamp(timer)`` returns a jax-traceable ``stamp(tok, stage,
    phase)`` that issues a ``jax.pure_callback`` into the host-side
    ``StageTimer``.  The callback's operands/result are threaded through
    the tick's activation carry, so XLA cannot reorder it across the
    stage compute (phase 0 consumes the carry *before* ``stage_forward``,
    phase 1 consumes its output), and a ``custom_vjp`` makes it transparent
    to ``jax.grad`` (identity forward, identity cotangent).
  * ``StageTimer`` pairs the per-shard (stage, phase) stamps into busy
    seconds per stage.  Every stage stamps once per tick — exactly the
    cadence of the ``[S, L_max]`` stats fold — so per-step stage seconds
    are ``mean_busy_per_tick * T`` with ``T = num_micro + S - 1``.

Ordered io_callback is NOT used: on the experimental shard_map fallback
(jax without ``jax.shard_map``) its effect tokens break partial-eval under
``jax.grad``.  The pure_callback + data-dependency construction composes
with jit + grad + scan + shard_map on every jax the repo supports
(validated by the parity test against the probe oracle).
"""
from __future__ import annotations

import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class StageTimer:
    """Host-side collector for in-step stage-boundary stamps.

    Thread-safe: XLA-CPU runs each pipeline shard on its own thread and
    the callbacks arrive concurrently; stamps are keyed by stage index so
    shards never pair against each other."""

    def __init__(self, num_stages: int):
        self.num_stages = int(num_stages)
        self._lock = threading.Lock()
        self._open = {}
        self._acc = np.zeros(self.num_stages, np.float64)
        self._n = np.zeros(self.num_stages, np.int64)

    def stamp(self, stage: int, phase: int) -> None:
        t = time.perf_counter()
        s = int(stage)
        if not (0 <= s < self.num_stages):
            return
        with self._lock:
            if int(phase) == 0:
                self._open[s] = t
            else:
                t0 = self._open.pop(s, None)
                if t0 is not None:
                    self._acc[s] += t - t0
                    self._n[s] += 1

    def snapshot(self, ticks_per_step: Optional[int] = None,
                 reset: bool = True) -> Optional[np.ndarray]:
        """Per-stage busy seconds: mean-per-tick (scaled to per-step when
        ``ticks_per_step`` is given).  None until every stage has stamped
        at least once since the last snapshot."""
        with self._lock:
            acc, n = self._acc.copy(), self._n.copy()
            if reset:
                self._acc[:] = 0.0
                self._n[:] = 0
                self._open.clear()
        if not n.all():
            return None
        per_tick = acc / n
        return per_tick * ticks_per_step if ticks_per_step else per_tick

    @property
    def samples(self) -> np.ndarray:
        with self._lock:
            return self._n.copy()


def make_stamp(timer: StageTimer):
    """Build the jax-traceable stage-boundary stamp for one ``timer``.

    ``stamp(tok, stage, phase)`` returns ``tok`` unchanged (plus a
    callback-produced zero, which is what pins the execution order); it is
    safe under ``jax.grad`` — the backward pass re-runs no callbacks and
    passes the cotangent straight through."""

    def _host(stage, phase, _tok):
        timer.stamp(int(stage), int(phase))
        return np.zeros((), np.float32)

    @jax.custom_vjp
    def stamp(tok, stage, phase):
        del stage, phase
        return tok

    def _fwd(tok, stage, phase):
        z = jax.pure_callback(
            _host, jax.ShapeDtypeStruct((), jnp.float32),
            stage, phase, tok.ravel()[0].astype(jnp.float32))
        return tok + z.astype(tok.dtype), None

    def _bwd(_res, g):
        return (g, None, None)

    stamp.defvjp(_fwd, _bwd)
    return stamp
