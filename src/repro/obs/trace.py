"""Span-based structured tracing (DESIGN.md §15).

A ``Tracer`` records spans (trace_id / span_id / parent_id) with both
wall-clock stamps (microseconds, for Perfetto) and a logical clock (a
per-tracer monotonic counter, for determinism tests and cross-event
ordering that survives wall-clock noise).  Export is Chrome trace-event
JSON: ``{"traceEvents": [...]}`` — drag the file into
https://ui.perfetto.dev and every span shows its ids under ``args``.

Cross-process correlation: RPC transports call :meth:`Tracer.rpc_ctx` to
mint a child span context ``{"trace_id", "span_id"}`` and ship it inside
the request payload; the receiving process records the context on its own
events (``parent_id`` pointing at the sender's span), so a serve-tenant
steal, the scheduler's preemption directive, and the trainer's safe-point
shrink chain up across three processes.

The module-level *current tracer* is how deep layers (RPC clients, the
control plane, the fault injector) find the session's tracer without
threading it through every constructor.  It is process-global on
purpose — the async controller thread and HTTP client calls must see it.
Stdlib-only: safe to import in manager processes that never load jax.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

_lock = threading.Lock()
_current: Optional["Tracer"] = None


def set_current_tracer(tracer: Optional["Tracer"]) -> None:
    global _current
    with _lock:
        _current = tracer


def current_tracer() -> Optional["Tracer"]:
    return _current


class Span:
    """One open span; use as a context manager or call ``end()``."""

    __slots__ = ("tracer", "name", "cat", "span_id", "parent_id",
                 "args", "_t0", "_lc0", "_done")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 span_id: str, parent_id: Optional[str],
                 args: Dict[str, Any], t0: float, lc0: int):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.span_id = span_id
        self.parent_id = parent_id
        self.args = args
        self._t0 = t0
        self._lc0 = lc0
        self._done = False

    def ctx(self) -> Dict[str, str]:
        """The wire context other processes parent their events on."""
        return {"trace_id": self.tracer.trace_id, "span_id": self.span_id}

    def end(self, **extra_args) -> None:
        if self._done:
            return
        self._done = True
        self.tracer._end_span(self, extra_args)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()


class Tracer:
    """Collects spans/instants; exports Chrome trace-event JSON.

    ``trace_id`` should be derived from stable run identity (seed +
    tenant), NOT from pids or clocks — the logical event sequence of a
    fixed-seed run must be reproducible (tested).  ``clock``/``pid`` are
    injectable for golden fixtures.
    """

    def __init__(self, trace_id: str, *, clock=time.perf_counter,
                 pid: Optional[int] = None,
                 meta: Optional[Dict[str, Any]] = None):
        self.trace_id = trace_id
        self._clock = clock
        self._pid = os.getpid() if pid is None else pid
        self._lock = threading.RLock()
        self._events: List[Dict[str, Any]] = []
        self._lc = 0
        self._span_seq = 0
        self._t0 = clock()
        self._wall0 = time.time()
        self._stack = threading.local()   # open-span stack, per thread
        self.meta = dict(meta or {})

    # -- clocks and ids -----------------------------------------------------
    def next_lc(self) -> int:
        with self._lock:
            self._lc += 1
            return self._lc

    def _new_span_id(self) -> str:
        with self._lock:
            self._span_seq += 1
            return f"{self.trace_id}.s{self._span_seq}"

    def _us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _tid(self) -> int:
        return threading.get_ident() % 100000

    def _parent(self) -> Optional[str]:
        stack = getattr(self._stack, "spans", None)
        return stack[-1].span_id if stack else None

    # -- recording ----------------------------------------------------------
    def span(self, name: str, cat: str = "session",
             parent_id: Optional[str] = None, **args) -> Span:
        """Open a span; parent defaults to this thread's enclosing span.
        Pass ``parent_id`` explicitly to chain onto a foreign (cross-
        process) span context."""
        sp = Span(self, name, cat, self._new_span_id(),
                  parent_id if parent_id is not None else self._parent(),
                  dict(args), self._us(), self.next_lc())
        stack = getattr(self._stack, "spans", None)
        if stack is None:
            stack = self._stack.spans = []
        stack.append(sp)
        return sp

    def _end_span(self, sp: Span, extra_args: Dict[str, Any]) -> None:
        stack = getattr(self._stack, "spans", None)
        if stack and sp in stack:
            stack.remove(sp)
        t1 = self._us()
        args = {"trace_id": self.trace_id, "span_id": sp.span_id,
                "parent_id": sp.parent_id, "lc": sp._lc0,
                "lc_end": self.next_lc(), **sp.args, **extra_args}
        with self._lock:
            self._events.append(
                {"name": sp.name, "cat": sp.cat, "ph": "X",
                 "ts": sp._t0, "dur": max(0.0, t1 - sp._t0),
                 "pid": self._pid, "tid": self._tid(), "args": args})

    def instant(self, name: str, cat: str = "session",
                parent_id: Optional[str] = None, **args) -> Dict[str, str]:
        """Record a zero-duration event; returns its wire context."""
        span_id = self._new_span_id()
        rec_args = {"trace_id": self.trace_id, "span_id": span_id,
                    "parent_id": (parent_id if parent_id is not None
                                  else self._parent()),
                    "lc": self.next_lc(), **args}
        with self._lock:
            self._events.append(
                {"name": name, "cat": cat, "ph": "i", "s": "p",
                 "ts": self._us(), "pid": self._pid, "tid": self._tid(),
                 "args": rec_args})
        return {"trace_id": self.trace_id, "span_id": span_id}

    def rpc_ctx(self, op: str, **args) -> Dict[str, str]:
        """Mint the child context an RPC request carries on the wire."""
        return self.instant(f"rpc.{op}", cat="rpc", **args)

    def event_context(self) -> Dict[str, Any]:
        """ids + logical stamp for a unified event record (obs.events)."""
        span_id = self._new_span_id()
        return {"trace_id": self.trace_id, "span_id": span_id,
                "parent_id": self._parent(), "lc": self.next_lc()}

    # -- export -------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        with self._lock:
            events = list(self._events)
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"trace_id": self.trace_id,
                              "wall0": self._wall0, **self.meta}}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
        return path

    def event_sequence(self) -> List[tuple]:
        """The wall-free view a determinism test compares: (name, ph, lc,
        span_id, parent_id) in logical-clock order."""
        with self._lock:
            evs = [(e["name"], e["ph"], e["args"].get("lc"),
                    e["args"].get("span_id"), e["args"].get("parent_id"))
                   for e in self._events]
        return sorted(evs, key=lambda t: (t[2] is None, t[2]))

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)
