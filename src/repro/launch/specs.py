"""input_specs(): ShapeDtypeStruct stand-ins (with shardings) for every input
of the train / prefill / decode step of every (arch × shape × mesh) cell —
weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (SHAPES, DistConfig, ModelConfig, get_config)
from repro.dynamics.config import DynamicsConfig
from repro.launch import sharding as SH
from repro.launch.mesh import dp_degree
from repro.models import model as M
from repro.optim.optimizers import OptConfig, make_optimizer
from repro.pipeline.pipeline import PipelineShapes, plan_shapes


def arch_dist_config(arch: str, shape_name: str, *,
                     unroll_ticks: bool = False, unroll_slots: bool = False,
                     num_micro_override: Optional[int] = None,
                     remat: str = "full", slot_exec: str = "masked_scan",
                     slot_slack: int = 1) -> DistConfig:
    """Per-arch distribution defaults for the production mesh.

    * llama3-405b uses adafactor: AdamW's f32 moments alone are 12.7 GB/chip
      at 256 chips — over the v5e 16 GB budget (napkin math in DESIGN.md).
    * FSDP only for archs > 8B params: below that, stage-replicated weights
      (+ moments) fit comfortably (e.g. xlstm 3.6B → 2.3 GB/chip) and
      dropping FSDP removes the per-tick weight all-gather/reduce-scatter
      traffic — the dominant collective term for small archs."""
    optimizer = "adafactor" if arch == "llama3-405b" else "adamw"
    fsdp = get_config(arch).param_count() > 8e9
    return DistConfig(
        num_stages=16, slot_slack=slot_slack, remat=remat,
        slot_exec=slot_exec, unroll_ticks=unroll_ticks,
        unroll_slots=unroll_slots, optimizer=optimizer, fsdp=fsdp,
        param_dtype="bfloat16")


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape_name: str
    kind: str                        # train | prefill | decode
    cfg: ModelConfig
    dcfg: DistConfig
    dyncfg: DynamicsConfig
    shapes: PipelineShapes
    args: Tuple[Any, ...]            # ShapeDtypeStructs with shardings
    skip_reason: Optional[str] = None


def cell_skip_reason(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return ("long_500k needs sub-quadratic attention; "
                f"{cfg.name} is full-attention (DESIGN.md §7)")
    if shape_name == "long_500k" and cfg.is_encdec:
        return "whisper decoder context << 500k (enc-dec); skipped"
    return None


def input_specs(arch: str, shape_name: str, mesh,
                dcfg: Optional[DistConfig] = None,
                dyncfg: Optional[DynamicsConfig] = None,
                num_micro_override: Optional[int] = None) -> CellSpec:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    dcfg = dcfg or arch_dist_config(arch, shape_name)
    dyncfg = dyncfg or DynamicsConfig()
    skip = cell_skip_reason(cfg, shape_name)
    dp = dp_degree(mesh)
    shapes = plan_shapes(cfg, dcfg, shape.kind, shape.seq_len,
                         shape.global_batch, dp)
    if num_micro_override:
        shapes = dataclasses.replace(shapes, num_micro=num_micro_override)
    if skip:
        return CellSpec(arch, shape_name, shape.kind, cfg, dcfg, dyncfg,
                        shapes, (), skip)

    # --- params / opt / assignment / dyn specs with shardings
    pspec = M.param_spec(cfg, dcfg)
    pshard = SH.param_shardings(cfg, dcfg, mesh, pspec)
    params_sds = SH.attach(pspec, pshard)
    aspec = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        M.make_assignment(cfg, dcfg))
    assignment_sds = SH.attach(aspec, SH.stage_tree_shardings(aspec, mesh))
    dspec = M.dyn_spec(cfg, dcfg, dyncfg)
    dyn_sds = SH.attach(dspec, SH.stage_tree_shardings(dspec, mesh))

    m, B, s = shapes.num_micro, shapes.mb_global, shapes.seq
    if shape.kind == "train":
        opt_cfg = OptConfig(name=dcfg.optimizer)
        init_fn, _ = make_optimizer(opt_cfg)
        opt_template = jax.eval_shape(init_fn, pspec)
        opt_sds = SH.attach(opt_template,
                            SH.opt_shardings(opt_template, pshard, mesh))
        batch_spec = {
            "tokens": jax.ShapeDtypeStruct((m, B, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((m, B, s), jnp.int32),
            "label_mask": jax.ShapeDtypeStruct((m, B, s), jnp.float32),
        }
        if cfg.family == "vlm":
            batch_spec["prefix_emb"] = jax.ShapeDtypeStruct(
                (m, B, cfg.num_patches, cfg.d_model), jnp.float32)
        if cfg.is_encdec:
            batch_spec["frames"] = jax.ShapeDtypeStruct(
                (m, B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        batch_sds = SH.attach(batch_spec,
                              SH.batch_shardings(batch_spec, mesh))
        lr_sds = jax.ShapeDtypeStruct((), jnp.float32)
        args = (params_sds, opt_sds, assignment_sds, dyn_sds, batch_sds,
                lr_sds)
    elif shape.kind == "prefill":
        cspec = M.cache_spec(cfg, dcfg, m, B, shapes.seq)
        cache_sds = SH.attach(cspec, SH.cache_shardings(cspec, mesh))
        batch_spec = {
            "tokens": jax.ShapeDtypeStruct((m, B, s), jnp.int32),
        }
        if cfg.family == "vlm":
            batch_spec["prefix_emb"] = jax.ShapeDtypeStruct(
                (m, B, cfg.num_patches, cfg.d_model), jnp.float32)
        if cfg.is_encdec:
            batch_spec["frames"] = jax.ShapeDtypeStruct(
                (m, B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        batch_sds = SH.attach(batch_spec,
                              SH.batch_shardings(batch_spec, mesh))
        args = (params_sds, assignment_sds, dyn_sds, cache_sds, batch_sds)
    else:  # decode
        cspec = M.cache_spec(cfg, dcfg, m, B, shapes.seq)
        cache_sds = SH.attach(cspec, SH.cache_shardings(cspec, mesh))
        tok_spec = {"tokens": jax.ShapeDtypeStruct((m, B), jnp.int32)}
        tok_sds = SH.attach(
            tok_spec, SH.batch_shardings(tok_spec, mesh))["tokens"]
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        args = (params_sds, assignment_sds, dyn_sds, cache_sds, tok_sds,
                pos_sds)
    return CellSpec(arch, shape_name, shape.kind, cfg, dcfg, dyncfg, shapes,
                    args, None)
