import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (architecture × input shape ×
mesh) cell on placeholder devices and extract memory / cost / roofline terms.

The two lines above MUST precede any other import (jax locks the device count
on first init).  Do not import this module from processes that need 1 device.

Per cell:
  1. full-scale scan-based compile    → memory_analysis (fits?), raw
     cost_analysis, HLO collective census;
  2. (single-pod, --probes) two unrolled probe compiles (num_micro = 1, 2)
     → exact per-tick FLOPs / bytes / collective bytes, extrapolated to the
     real schedule length (XLA counts loop bodies once — DESIGN.md §5);
  3. roofline terms + MODEL_FLOPS ratio → JSON under results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --probes
  python -m repro.launch.dryrun --all [--multi-pod] [--probes]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, list_configs
from repro.launch import roofline as RL
from repro.launch.mesh import dp_degree, make_production_mesh
from repro.launch.specs import arch_dist_config, cell_skip_reason, input_specs
from repro.launch.train import make_train_step
from repro.optim.optimizers import OptConfig
from repro.pipeline.pipeline import (build_decode_fn, build_loss_fn,
                                     build_prefill_fn)

ARCHS = [
    "mixtral-8x7b", "mixtral-8x22b", "llama3-405b", "command-r-plus-104b",
    "smollm-360m", "deepseek-coder-33b", "internvl2-26b", "zamba2-1.2b",
    "xlstm-1.3b", "whisper-large-v3",
]
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _build_step(cell, mesh):
    cfg, dcfg, dyncfg, shapes = cell.cfg, cell.dcfg, cell.dyncfg, cell.shapes
    if cell.kind == "train":
        _, step = make_train_step(cfg, dcfg, dyncfg, mesh, shapes,
                                  OptConfig(name=dcfg.optimizer))
        return jax.jit(step, donate_argnums=(0, 1))
    if cell.kind == "prefill":
        fn = build_prefill_fn(cfg, dcfg, dyncfg, mesh, shapes)
        return jax.jit(fn, donate_argnums=(3,))
    fn = build_decode_fn(cfg, dcfg, dyncfg, mesh, shapes)
    return jax.jit(fn, donate_argnums=(3,))


def _compile(cell, mesh):
    step = _build_step(cell, mesh)
    t0 = time.time()
    lowered = step.lower(*cell.args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, t1 - t0, t2 - t1


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             probes: bool = False, verbose: bool = True,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    skip = cell_skip_reason(
        __import__("repro.configs", fromlist=["get_config"]
                   ).get_config(arch), shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    out: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
    }
    if skip:
        out["skipped"] = skip
        return out

    dcfg = arch_dist_config(arch, shape_name)
    if overrides:
        dcfg = dataclasses.replace(dcfg, **overrides)
        out["overrides"] = dict(overrides)
    cell = input_specs(arch, shape_name, mesh, dcfg=dcfg)
    shapes = cell.shapes
    S = cell.dcfg.num_stages
    T_real = shapes.num_micro + S - 1
    out.update(num_micro=shapes.num_micro, mb_global=shapes.mb_global,
               seq=shapes.seq, kind=cell.kind,
               L_max=cell.dcfg.slots_for(cell.cfg))

    compiled, t_lower, t_compile = _compile(cell, mesh)
    ma = compiled.memory_analysis()
    out["memory"] = {
        "argument_bytes_per_chip": ma.argument_size_in_bytes,
        "output_bytes_per_chip": ma.output_size_in_bytes,
        "temp_bytes_per_chip": ma.temp_size_in_bytes,
        "alias_bytes_per_chip": ma.alias_size_in_bytes,
        "peak_bytes_per_chip": (ma.argument_size_in_bytes
                                + ma.output_size_in_bytes
                                + ma.temp_size_in_bytes
                                - ma.alias_size_in_bytes),
        "fits_16GB": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                      + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        < 16 * 1024 ** 3,
    }
    raw = RL.cost_dict(compiled)
    out["raw_cost"] = {"flops": raw.get("flops", 0.0),
                       "bytes_accessed": raw.get("bytes accessed", 0.0)}
    out["collectives_census"] = RL.collective_bytes(compiled.as_text())
    out["lower_s"] = round(t_lower, 2)
    out["compile_s"] = round(t_compile, 2)
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] compiled "
              f"({t_compile:.1f}s); peak/chip = "
              f"{out['memory']['peak_bytes_per_chip'] / 2**30:.2f} GiB "
              f"fits={out['memory']['fits_16GB']}")

    if not probes and not multi_pod:
        out["roofline"] = _analytic_roofline(cell, chips, T_real)
        if verbose:
            d = out["roofline"]
            print(f"  roofline (analytic): compute {d['t_compute_s']:.4f}s "
                  f"memory {d['t_memory_analytic_s']:.4f}s collective "
                  f"{d['t_collective_s']:.4f}s → {d['bottleneck']}-bound")
    if probes and not multi_pod:
        # probes at m=2,3: m=1 lets XLA constant-fold the microbatch index
        # (clip(t-idx,0,0)=0), structurally changing the program and breaking
        # the affine-in-ticks extrapolation
        M1, M2 = 2, 3
        probe_cost = {}
        probe_coll = {}
        for m_probe in (M1, M2):
            pc = dataclasses.replace(
                dcfg, unroll_ticks=True, unroll_slots=True)
            pcell = input_specs(arch, shape_name, mesh, dcfg=pc,
                                num_micro_override=m_probe)
            comp, _, tc = _compile(pcell, mesh)
            probe_cost[m_probe] = RL.cost_dict(comp)
            probe_coll[m_probe] = RL.collective_bytes(comp.as_text())
            if verbose:
                print(f"  probe m={m_probe}: compile {tc:.1f}s flops="
                      f"{probe_cost[m_probe].get('flops', 0):.3e}")
        out["probes"] = {
            str(m): {"flops": probe_cost[m].get("flops", 0.0),
                     "bytes": probe_cost[m].get("bytes accessed", 0.0),
                     "coll": probe_coll[m]["total"]}
            for m in (M1, M2)}
        T1, T2 = M1 + S - 1, M2 + S - 1
        adj = RL.extrapolate(
            {"flops": probe_cost[M1].get("flops", 0.0),
             "bytes": probe_cost[M1].get("bytes accessed", 0.0),
             "coll": probe_coll[M1]["total"]},
            {"flops": probe_cost[M2].get("flops", 0.0),
             "bytes": probe_cost[M2].get("bytes accessed", 0.0),
             "coll": probe_coll[M2]["total"]},
            T1, T2, T_real)
        tokens = shapes.num_micro * shapes.mb_global * max(1, shapes.seq
                                                           if cell.kind !=
                                                           "decode" else 1)
        mf = __import__("repro.core.cost_model",
                        fromlist=["model_flops"]).model_flops(
            cell.cfg, tokens, train=(cell.kind == "train"))
        # analytic HBM traffic (hottest chip): XLA-CPU "bytes accessed"
        # counts every unfused intermediate, so it overestimates TPU HBM
        # traffic; the analytic model (weights ×3 for fwd/bwd/remat +
        # activation/KV streams) is the TPU-realistic lower envelope.
        out["analytic_hbm_bytes_per_chip"] = _analytic_hbm(cell, chips,
                                                           T_real)
        terms = RL.RooflineTerms(
            flops=adj["flops"], hbm_bytes=adj["bytes"],
            coll_bytes=adj["coll"], chips=chips, model_flops=mf)
        out["roofline"] = terms.as_dict()
        out["roofline"]["t_memory_analytic_s"] = (
            out["analytic_hbm_bytes_per_chip"] / RL.HBM_BW)
        out["adjusted"] = adj
        out["T_real"] = T_real
        if verbose:
            d = terms.as_dict()
            print(f"  roofline: compute {d['t_compute_s']:.4f}s  memory "
                  f"{d['t_memory_s']:.4f}s  collective "
                  f"{d['t_collective_s']:.4f}s  → {d['bottleneck']}-bound; "
                  f"useful-flops {d['useful_flops_ratio']:.2f} "
                  f"mfu≤{d['mfu_bound']:.2f}")
    return out


def _analytic_roofline(cell, chips: int, T_real: int) -> Dict[str, Any]:
    """Cost-model-based roofline terms for cells without probe compiles
    (flagged "analytic": the hottest-stage FLOPs, analytic HBM bytes, and a
    structural collective estimate: ppermute carries + DP grad all-reduce +
    FSDP weight AG/RS when enabled)."""
    from repro.core import cost_model as CM
    from repro.launch import roofline as RL
    cfg, shapes, dcfg = cell.cfg, cell.shapes, cell.dcfg
    S = dcfg.num_stages
    dp = chips // S
    pattern = cfg.block_pattern()
    per_stage = (len(pattern) + S - 1) // S
    L_max = dcfg.slots_for(cfg)
    stage_pattern = pattern[-per_stage:]
    train = cell.kind == "train"
    if cell.kind == "decode":
        tokens_tick = max(1, shapes.mb_global // dp)
        seq = shapes.seq
    else:
        tokens_tick = max(1, shapes.mb_global // dp) * shapes.seq_total
        seq = shapes.seq_total
    slot_mult = L_max / max(1, per_stage)      # masked_scan pad overhead
    fwd = sum(CM.layer_flops(cfg, bt, tokens_tick, seq)
              for bt in stage_pattern) * slot_mult
    per_tick = fwd * (4.0 if train else 1.0)   # fwd + bwd(2) + remat(1)
    flops = T_real * per_tick
    if train:                                  # vocab head on last stage
        flops += (shapes.num_micro * 2 * tokens_tick * cfg.d_model
                  * cfg.vocab_size * 3)
    hbm = _analytic_hbm(cell, chips, T_real)
    # collectives per chip: ppermute carry each tick + grad psum + FSDP
    carry = tokens_tick * cfg.d_model * 2
    if cfg.is_encdec:
        carry += max(1, shapes.mb_global // dp) * cfg.encoder_seq \
            * cfg.d_model * 2
    coll = T_real * carry
    stage_params = sum(cfg.params_per_block(bt) for bt in stage_pattern) \
        * slot_mult
    if train:
        coll += 2 * stage_params * 4 * (dp - 1) / dp       # DP grad reduce
        if dcfg.fsdp:
            coll += T_real * 3 * stage_params * 2 / dp     # AG fwd/bwd/remat
        emb_head = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings
                                                   else 2)
        coll += 2 * emb_head * 4 / chips                   # psum over model
    mf = CM.model_flops(
        cfg, shapes.num_micro * shapes.mb_global
        * (1 if cell.kind == "decode" else shapes.seq), train=train)
    terms = RL.RooflineTerms(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                             chips=chips, model_flops=mf)
    d = terms.as_dict()
    d["analytic"] = True
    d["t_memory_analytic_s"] = hbm / RL.HBM_BW
    return d


def _analytic_hbm(cell, chips: int, T_real: int) -> float:
    """Analytic per-chip HBM bytes for one step (hottest stage)."""
    from repro.core import cost_model as CM
    cfg, shapes = cell.cfg, cell.shapes
    S = cell.dcfg.num_stages
    dp = chips // S
    pattern = cfg.block_pattern()
    per_stage = (len(pattern) + S - 1) // S
    stage_pattern = pattern[-per_stage:]          # last stage (has the head)
    if cell.kind == "decode":
        tokens_tick = max(1, shapes.mb_global // dp)
        seq = shapes.seq
    else:
        tokens_tick = max(1, shapes.mb_global // dp) * shapes.seq_total
        seq = shapes.seq_total
    per_tick = sum(CM.layer_bytes(cfg, bt, tokens_tick, seq)
                   for bt in stage_pattern)
    mult = 3.0 if cell.kind == "train" else 1.0   # fwd + bwd + remat
    total = T_real * per_tick * mult
    # head + embed traffic (last stage / stage 0)
    head_bytes = cfg.d_model * cfg.vocab_size * 4 / max(1, dp)
    if cell.kind == "train":
        tok_total = shapes.num_micro * max(1, shapes.mb_global // dp) \
            * shapes.seq
        total += shapes.num_micro * head_bytes * 3
        total += tok_total * cfg.vocab_size * 4 / 32   # logit stream, fused
        # optimizer: read+write params + 2 moments on this stage's shard
        stage_params = sum(cfg.params_per_block(bt) for bt in stage_pattern)
        total += stage_params / max(1, dp) * (2 + 4 + 4) * 2
    else:
        total += head_bytes * shapes.num_micro
    return float(total)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--probes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true")
    # hillclimb overrides (DistConfig fields)
    ap.add_argument("--slot-slack", type=int, default=None)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--tag", default="",
                    help="suffix for the result file (perf iterations)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    overrides = {}
    if args.slot_slack is not None:
        overrides["slot_slack"] = args.slot_slack
    if args.no_fsdp:
        overrides["fsdp"] = False
    if args.remat:
        overrides["remat"] = args.remat
    if args.optimizer:
        overrides["optimizer"] = args.optimizer

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for a, s in cells:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        suffix = f"__{args.tag}" if args.tag else ""
        path = os.path.join(args.out, f"{a}__{s}__{mesh_name}{suffix}.json")
        if os.path.exists(path) and not args.force:
            print(f"skip (cached): {path}")
            continue
        try:
            res = run_cell(a, s, multi_pod=args.multi_pod,
                           probes=args.probes, overrides=overrides or None)
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            res = {"arch": a, "shape": s, "mesh": mesh_name,
                   "error": f"{type(e).__name__}: {e}"}
            failures.append((a, s))
        with open(path, "w") as fh:
            json.dump(res, fh, indent=2, default=str)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
