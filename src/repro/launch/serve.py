"""Serving CLI — a thin adapter over ``repro.api`` plus the legacy oracle:

  * ``run_elastic_serving`` / ``--elastic`` — the ``repro.serve`` subsystem
    (continuous batching on ``ElasticEngine`` worlds with load-driven
    autoscaling).  The lifecycle lives in ``Session.serve``; the kwarg
    entry point is a deprecation shim that builds the equivalent
    ``RunSpec`` (``serve_spec``), so flag path, config path, and Python
    API produce identical runs.
  * ``run_serving`` — the legacy one-shot generator (one fixed batch,
    prefill + gen decode rounds, optional DynMo rebalance between rounds);
    kept as the parity oracle for the continuous scheduler.

CPU-scale usage:
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \
      --arch smollm-360m --layers 8 --stages 4 --gen 16 --dynamism early_exit
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \
      --elastic --autoscale --requests 24 --burst-period 16 --burst-len 4
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \
      --elastic --config my_serve.json --set serve.queue_high=4
"""
from __future__ import annotations

import os
if os.environ.get("REPRO_TRAIN_DEVICES"):       # must precede jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_TRAIN_DEVICES"])

import argparse
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.api.cli import (SERVE_ALIASES, SERVE_CLI_DEFAULTS,
                           add_alias_flags, add_config_args, add_spec_flags,
                           build_spec, maybe_dump)
from repro.api.session import Session
from repro.api.specs import (ClusterSpec, ControllerSpec, DynamicsSpec,
                             ModelSpec, ParallelSpec, RunSpec, ServeSpec)


def run_serving(arch: str, *, stages: int = 4, micro: int = 2,
                mb_global: int = 4, prompt_len: int = 32, gen: int = 8,
                layers: Optional[int] = 8, d_model: int = 128,
                dynamism: str = "none", rebalance_every: int = 0,
                seed: int = 0, mesh=None):
    import jax
    import jax.numpy as jnp
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.core.controller import ControllerConfig, DynMoController
    from repro.core.cost_model import LayerDynState, cost_vector
    from repro.core.profiler import LayerProfile
    from repro.dynamics.config import DynamicsConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.pipeline.pipeline import (PipelineShapes, build_decode_fn,
                                         build_prefill_fn)

    cfg = get_config(arch)
    if layers is not None:
        cfg = reduced_config(cfg, num_layers=layers, d_model=d_model,
                             num_heads=4, num_kv_heads=2, d_ff=2 * d_model,
                             vocab_size=512)
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig(kind=dynamism)
    mesh = mesh or make_host_mesh(data=1, model=stages)
    cache_len = prompt_len + gen
    shapes = PipelineShapes(micro, mb_global, prompt_len,
                            cache_len=cache_len)

    params = M.init_params(jax.random.PRNGKey(seed), cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    cache = M.init_cache(cfg, dcfg, micro, mb_global, cache_len)
    prefill = jax.jit(build_prefill_fn(cfg, dcfg, dyncfg, mesh, shapes))
    decode = jax.jit(build_decode_fn(cfg, dcfg, dyncfg, mesh, shapes),
                     donate_argnums=(3,))
    ctrl = DynMoController(
        cfg, dcfg, dyncfg,
        ControllerConfig(method="partition", cost_by="time",
                         rebalance_every=max(1, rebalance_every)))

    rng = np.random.RandomState(seed)
    tokens = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (micro, mb_global, prompt_len)),
        jnp.int32)
    outs = []
    t0 = time.perf_counter()
    with mesh:
        ids, cache, _ = prefill(params, assignment, dyn, cache,
                                {"tokens": tokens})
        outs.append(np.asarray(ids))
        for g in range(1, gen):
            ids, lp, cache, _ = decode(params, assignment, dyn, cache, ids,
                                       jnp.int32(prompt_len + g - 1))
            outs.append(np.asarray(ids))
            if rebalance_every and g % rebalance_every == 0:
                # serving-time profile: survival-curve cost vector
                L = cfg.total_blocks()
                states = [LayerDynState() for _ in range(L)]
                t = cost_vector(cfg, mb_global, prompt_len + g, states,
                                by="time")
                prof = LayerProfile(
                    t, cost_vector(cfg, mb_global, prompt_len + g, states,
                                   by="param") * dcfg.bytes_per_param,
                    np.zeros(stages), states)
                new_lps, ev = ctrl.decide(prof, g)
                if new_lps is not None:
                    params, _, dyn, assignment, cache = ctrl.apply(
                        new_lps, params, None, dyn, cache)
    wall = time.perf_counter() - t0
    gen_tokens = np.stack(outs, axis=-1)
    tps = micro * mb_global * gen / wall
    return {"tokens": gen_tokens, "wall_s": wall, "tokens_per_s": tps,
            "final_lps": ctrl.lps}


def serve_spec(arch: str, *, stages: int = 4, micro: int = 2,
               mb_global: int = 4, prompt_len: int = 32,
               gen: int = 8, layers: Optional[int] = 8,
               d_model: int = 128, dynamism: str = "none",
               requests: int = 16, min_prompt: Optional[int] = None,
               burst_period: int = 0, burst_len: int = 0,
               burst_rate: int = 4, lull_rate: int = 1,
               early_exit_frac: float = 0.0, seed: int = 0,
               autoscale: bool = False, min_stages: int = 1,
               queue_high: int = 8, occupancy_low: float = 0.35,
               patience: int = 2, cooldown: int = 4,
               defrag_every: int = 0, job_manager: str = "inproc",
               job_manager_dir: Optional[str] = None,
               tenant_id: Optional[str] = None, priority: int = 0,
               manager_url: Optional[str] = None,
               latency_slo_s: float = 0.0,
               kernel_impl: str = "scan",
               measure_stage_times: bool = False,
               max_ticks: int = 100000,
               kv_page_size: int = 0, kv_pool_pages: int = 0,
               prefix_cache: bool = False,
               temperature: float = 0.0) -> RunSpec:
    """The ``RunSpec`` equivalent of the legacy ``run_elastic_serving``
    kwargs — the single place the old vocabulary maps onto the schema."""
    return RunSpec(
        model=ModelSpec(arch=arch, layers=layers, d_model=d_model),
        parallel=ParallelSpec(stages=stages, num_micro=micro,
                              mb_global=mb_global,
                              kernel_impl=kernel_impl),
        dynamics=DynamicsSpec(kind=dynamism),
        controller=ControllerSpec(measure_stage_times=measure_stage_times),
        cluster=ClusterSpec(job_manager=job_manager,
                            job_manager_dir=job_manager_dir,
                            autoscale=autoscale, tenant_id=tenant_id,
                            priority=priority, manager_url=manager_url),
        serve=ServeSpec(requests=requests, prompt_len=prompt_len, gen=gen,
                        min_prompt=min_prompt, burst_period=burst_period,
                        burst_len=burst_len, burst_rate=burst_rate,
                        lull_rate=lull_rate,
                        early_exit_frac=early_exit_frac,
                        defrag_every=defrag_every,
                        min_stages=max(1, min_stages),
                        queue_high=queue_high,
                        occupancy_low=occupancy_low, patience=patience,
                        cooldown=cooldown, latency_slo_s=latency_slo_s,
                        max_ticks=max_ticks, kv_page_size=kv_page_size,
                        kv_pool_pages=kv_pool_pages,
                        prefix_cache=prefix_cache, temperature=temperature),
        seed=seed)


def run_elastic_serving(arch: str, *, resize_at=None,
                        **kwargs) -> Dict[str, Any]:
    """Legacy kwarg entry point (deprecation shim).

    Builds the equivalent ``RunSpec`` and serves it through a ``Session``
    — new code should do that directly:

        with Session(serve_spec(arch, ...)) as s:
            report = s.serve()
    """
    spec = serve_spec(arch, **kwargs)
    with Session(spec) as s:
        return s.serve(resize_at=resize_at)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="DynMo serving (config-first: --config RUN.JSON; "
                    "flags below override spec fields)")
    ap.add_argument("--elastic", action="store_true",
                    help="serve a request trace through the continuous-"
                         "batching scheduler on elastic engine worlds")
    ap.add_argument("--rebalance-every", type=int, default=0,
                    help="legacy one-shot path only: DynMo rebalance "
                         "between decode rounds")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the session's structured telemetry stream "
                         "(one JSON record per resize / autoscale / "
                         "tenant_register / steal / yield event) to this "
                         "file")
    add_config_args(ap)
    add_alias_flags(ap, SERVE_ALIASES)
    add_spec_flags(ap)
    args = ap.parse_args(argv)
    spec = build_spec(args, SERVE_ALIASES, cli_defaults=SERVE_CLI_DEFAULTS)
    if maybe_dump(args, spec):
        return
    if args.elastic or args.config:
        with Session(spec) as s:
            rep = s.serve()
        if args.events_out:
            import dataclasses
            import json
            with open(args.events_out, "w") as f:
                json.dump([dataclasses.asdict(ev) for ev in s.events], f,
                          indent=1)
            print(f"wrote {len(s.events)} events to {args.events_out}")
        kinds = [r["kind"] for r in rep["resizes"]]
        print(f"served {len(rep['completions'])} requests / "
              f"{rep['total_tokens']} tokens in {rep['wall_s']:.1f}s "
              f"({rep['tokens_per_s']:.1f} tok/s); "
              f"p50/p95 token latency "
              f"{rep['latency_p50_s'] * 1e3:.0f}/"
              f"{rep['latency_p95_s'] * 1e3:.0f}ms; "
              f"resizes={kinds}; "
              f"stages {rep['stages_history'][0]}->"
              f"{rep['stages_history'][-1]}")
        if rep.get("measured_stage_times") is not None:
            print(f"  measured stage times "
                  f"{[f'{t*1e3:.1f}ms' for t in rep['measured_stage_times']]}")
        for d in rep["autoscale_decisions"]:
            print(f"  autoscale @tick {d['step']}: {d['action']} "
                  f"({d['reason']})")
        return
    out = run_serving(
        spec.model.arch, stages=spec.parallel.stages,
        micro=spec.parallel.num_micro, mb_global=spec.parallel.mb_global,
        prompt_len=spec.serve.prompt_len, gen=spec.serve.gen,
        layers=spec.model.layers, d_model=spec.model.d_model,
        dynamism=spec.dynamics.kind, rebalance_every=args.rebalance_every,
        seed=spec.seed)
    print(f"generated {out['tokens'].shape} in {out['wall_s']:.1f}s "
          f"({out['tokens_per_s']:.1f} tok/s); final lps={out['final_lps']}")


if __name__ == "__main__":
    main()
