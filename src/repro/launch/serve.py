"""Serving CLI — a thin front-end over two paths:

  * ``run_serving`` — the legacy one-shot generator (one fixed batch,
    prefill + gen decode rounds, optional DynMo rebalance between rounds);
    kept as the parity oracle for the continuous scheduler;
  * ``run_elastic_serving`` (``--elastic``) — the ``repro.serve``
    subsystem: a bursty request trace through the continuous-batching
    scheduler on ``ElasticEngine`` worlds, with the autoscaler shrinking /
    growing the pipeline on queue-depth/occupancy watermarks and workers
    released/re-granted through the job-manager client.

CPU-scale usage:
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \
      --arch smollm-360m --layers 8 --stages 4 --gen 16 --dynamism early_exit
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \
      --elastic --autoscale --requests 24 --burst-period 16 --burst-len 4
"""
from __future__ import annotations

import os
if os.environ.get("REPRO_TRAIN_DEVICES"):       # must precede jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_TRAIN_DEVICES"])

import argparse
import time
from typing import Optional

import numpy as np


def run_serving(arch: str, *, stages: int = 4, micro: int = 2,
                mb_global: int = 4, prompt_len: int = 32, gen: int = 8,
                layers: Optional[int] = 8, d_model: int = 128,
                dynamism: str = "none", rebalance_every: int = 0,
                seed: int = 0, mesh=None):
    import jax
    import jax.numpy as jnp
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.core.controller import ControllerConfig, DynMoController
    from repro.core.cost_model import LayerDynState, cost_vector
    from repro.core.profiler import LayerProfile
    from repro.dynamics.config import DynamicsConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.pipeline.pipeline import (PipelineShapes, build_decode_fn,
                                         build_prefill_fn)

    cfg = get_config(arch)
    if layers is not None:
        cfg = reduced_config(cfg, num_layers=layers, d_model=d_model,
                             num_heads=4, num_kv_heads=2, d_ff=2 * d_model,
                             vocab_size=512)
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig(kind=dynamism)
    mesh = mesh or make_host_mesh(data=1, model=stages)
    cache_len = prompt_len + gen
    shapes = PipelineShapes(micro, mb_global, prompt_len,
                            cache_len=cache_len)

    params = M.init_params(jax.random.PRNGKey(seed), cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    cache = M.init_cache(cfg, dcfg, micro, mb_global, cache_len)
    prefill = jax.jit(build_prefill_fn(cfg, dcfg, dyncfg, mesh, shapes))
    decode = jax.jit(build_decode_fn(cfg, dcfg, dyncfg, mesh, shapes),
                     donate_argnums=(3,))
    ctrl = DynMoController(
        cfg, dcfg, dyncfg,
        ControllerConfig(method="partition", cost_by="time",
                         rebalance_every=max(1, rebalance_every)))

    rng = np.random.RandomState(seed)
    tokens = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (micro, mb_global, prompt_len)),
        jnp.int32)
    outs = []
    t0 = time.perf_counter()
    with mesh:
        ids, cache = prefill(params, assignment, dyn, cache,
                             {"tokens": tokens})
        outs.append(np.asarray(ids))
        for g in range(1, gen):
            ids, lp, cache = decode(params, assignment, dyn, cache, ids,
                                    jnp.int32(prompt_len + g - 1))
            outs.append(np.asarray(ids))
            if rebalance_every and g % rebalance_every == 0:
                # serving-time profile: survival-curve cost vector
                L = cfg.total_blocks()
                states = [LayerDynState() for _ in range(L)]
                t = cost_vector(cfg, mb_global, prompt_len + g, states,
                                by="time")
                prof = LayerProfile(
                    t, cost_vector(cfg, mb_global, prompt_len + g, states,
                                   by="param") * dcfg.bytes_per_param,
                    np.zeros(stages), states)
                new_lps, ev = ctrl.decide(prof, g)
                if new_lps is not None:
                    params, _, dyn, assignment, cache = ctrl.apply(
                        new_lps, params, None, dyn, cache)
    wall = time.perf_counter() - t0
    gen_tokens = np.stack(outs, axis=-1)
    tps = micro * mb_global * gen / wall
    return {"tokens": gen_tokens, "wall_s": wall, "tokens_per_s": tps,
            "final_lps": ctrl.lps}


def run_elastic_serving(arch: str, *, stages: int = 4, micro: int = 2,
                        mb_global: int = 4, prompt_len: int = 32,
                        gen: int = 8, layers: Optional[int] = 8,
                        d_model: int = 128, dynamism: str = "none",
                        requests: int = 16, min_prompt: Optional[int] = None,
                        burst_period: int = 0, burst_len: int = 0,
                        burst_rate: int = 4, lull_rate: int = 1,
                        early_exit_frac: float = 0.0, seed: int = 0,
                        autoscale: bool = False, min_stages: int = 1,
                        queue_high: int = 8, occupancy_low: float = 0.35,
                        patience: int = 2, cooldown: int = 4,
                        defrag_every: int = 0, job_manager: str = "inproc",
                        job_manager_dir: Optional[str] = None,
                        resize_at=None, max_ticks: int = 100000):
    """Continuous-batching serving on engine worlds; returns the server's
    report dict (completions, resizes, autoscale decisions, latency)."""
    import tempfile

    from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
    from repro.cluster.rpc import FileJobManager, spawn_file_manager
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.dynamics.config import DynamicsConfig
    from repro.pipeline.pipeline import PipelineShapes
    from repro.serve import ElasticServer, make_trace

    cfg = get_config(arch)
    if layers is not None:
        cfg = reduced_config(cfg, num_layers=layers, d_model=d_model,
                             num_heads=4, num_kv_heads=2, d_ff=2 * d_model,
                             vocab_size=512)
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig(kind=dynamism)
    shapes = PipelineShapes(micro, mb_global, prompt_len,
                            cache_len=prompt_len + gen)
    trace = make_trace(requests, prompt_len=prompt_len, max_gen=gen,
                       vocab_size=cfg.vocab_size, seed=seed,
                       min_prompt=min_prompt or max(1, prompt_len // 2),
                       burst_period=burst_period, burst_len=burst_len,
                       burst_rate=burst_rate, lull_rate=lull_rate,
                       early_exit_frac=early_exit_frac)
    scaler = None
    if autoscale:
        scaler = Autoscaler(AutoscalerConfig(
            min_stages=max(1, min_stages), max_stages=stages,
            patience=patience, cooldown=cooldown, queue_high=queue_high,
            occupancy_low=occupancy_low))
    jm = jm_proc = None
    if job_manager == "file":
        if job_manager_dir:
            import os as _os
            _os.makedirs(job_manager_dir, exist_ok=True)
            jm_dir = tempfile.mkdtemp(prefix="run_", dir=job_manager_dir)
        else:
            jm_dir = tempfile.mkdtemp(prefix="dynmo_serve_jm_")
        jm_proc = spawn_file_manager(jm_dir, stages)
        jm = FileJobManager(jm_dir, timeout_s=60.0)
    elif job_manager != "inproc":
        raise ValueError(f"unknown job manager {job_manager!r}")
    srv = ElasticServer(cfg, dcfg, dyncfg, shapes, job_manager=jm,
                        scaler=scaler, min_stages=min_stages, seed=seed,
                        defrag_every=defrag_every)
    try:
        report = srv.serve(trace, autoscale=autoscale, resize_at=resize_at,
                           max_ticks=max_ticks)
    finally:
        srv.close()
        if jm is not None:
            jm.close()
        if jm_proc is not None:
            try:
                jm_proc.wait(timeout=10)
            except Exception:
                jm_proc.kill()
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--mb-global", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--dynamism", default="none")
    ap.add_argument("--rebalance-every", type=int, default=0)
    # ---- elastic continuous-batching path
    ap.add_argument("--elastic", action="store_true",
                    help="serve a request trace through the continuous-"
                         "batching scheduler on elastic engine worlds")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--min-prompt", type=int, default=None)
    ap.add_argument("--burst-period", type=int, default=0)
    ap.add_argument("--burst-len", type=int, default=0)
    ap.add_argument("--burst-rate", type=int, default=4)
    ap.add_argument("--lull-rate", type=int, default=1)
    ap.add_argument("--early-exit-frac", type=float, default=0.0)
    ap.add_argument("--defrag-every", type=int, default=0)
    ap.add_argument("--autoscale", action="store_true",
                    help="queue-depth/occupancy watermark scaling")
    ap.add_argument("--min-stages", type=int, default=1)
    ap.add_argument("--queue-high", type=int, default=8)
    ap.add_argument("--occupancy-low", type=float, default=0.35)
    ap.add_argument("--patience", type=int, default=2)
    ap.add_argument("--cooldown", type=int, default=4)
    ap.add_argument("--job-manager", default="inproc",
                    choices=["inproc", "file"])
    ap.add_argument("--job-manager-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.elastic:
        rep = run_elastic_serving(
            args.arch, stages=args.stages, micro=args.micro,
            mb_global=args.mb_global, prompt_len=args.prompt_len,
            gen=args.gen, layers=args.layers, d_model=args.d_model,
            dynamism=args.dynamism, requests=args.requests,
            min_prompt=args.min_prompt, burst_period=args.burst_period,
            burst_len=args.burst_len, burst_rate=args.burst_rate,
            lull_rate=args.lull_rate, early_exit_frac=args.early_exit_frac,
            seed=args.seed, autoscale=args.autoscale,
            min_stages=args.min_stages, queue_high=args.queue_high,
            occupancy_low=args.occupancy_low, patience=args.patience,
            cooldown=args.cooldown, defrag_every=args.defrag_every,
            job_manager=args.job_manager,
            job_manager_dir=args.job_manager_dir)
        kinds = [r["kind"] for r in rep["resizes"]]
        print(f"served {len(rep['completions'])} requests / "
              f"{rep['total_tokens']} tokens in {rep['wall_s']:.1f}s "
              f"({rep['tokens_per_s']:.1f} tok/s); "
              f"p50/p95 token latency "
              f"{rep['latency_p50_s'] * 1e3:.0f}/"
              f"{rep['latency_p95_s'] * 1e3:.0f}ms; "
              f"resizes={kinds}; "
              f"stages {rep['stages_history'][0]}->"
              f"{rep['stages_history'][-1]}")
        for d in rep["autoscale_decisions"]:
            print(f"  autoscale @tick {d['step']}: {d['action']} "
                  f"({d['reason']})")
        return
    out = run_serving(
        args.arch, stages=args.stages, micro=args.micro,
        mb_global=args.mb_global, prompt_len=args.prompt_len, gen=args.gen,
        layers=args.layers, d_model=args.d_model, dynamism=args.dynamism,
        rebalance_every=args.rebalance_every)
    print(f"generated {out['tokens'].shape} in {out['wall_s']:.1f}s "
          f"({out['tokens_per_s']:.1f} tok/s); final lps={out['final_lps']}")


if __name__ == "__main__":
    main()
