"""Serving driver: batched-request generation through the pipelined
prefill + decode path, with optional DynMo rebalancing between rounds.

CPU-scale usage:
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \
      --arch smollm-360m --layers 8 --stages 4 --gen 16 --dynamism early_exit
"""
from __future__ import annotations

import os
if os.environ.get("REPRO_TRAIN_DEVICES"):       # must precede jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_TRAIN_DEVICES"])

import argparse
import time
from typing import Optional

import numpy as np


def run_serving(arch: str, *, stages: int = 4, micro: int = 2,
                mb_global: int = 4, prompt_len: int = 32, gen: int = 8,
                layers: Optional[int] = 8, d_model: int = 128,
                dynamism: str = "none", rebalance_every: int = 0,
                seed: int = 0, mesh=None):
    import jax
    import jax.numpy as jnp
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.core.controller import ControllerConfig, DynMoController
    from repro.core.cost_model import LayerDynState, cost_vector
    from repro.core.profiler import LayerProfile
    from repro.dynamics.config import DynamicsConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.pipeline.pipeline import (PipelineShapes, build_decode_fn,
                                         build_prefill_fn)

    cfg = get_config(arch)
    if layers is not None:
        cfg = reduced_config(cfg, num_layers=layers, d_model=d_model,
                             num_heads=4, num_kv_heads=2, d_ff=2 * d_model,
                             vocab_size=512)
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig(kind=dynamism)
    mesh = mesh or make_host_mesh(data=1, model=stages)
    cache_len = prompt_len + gen
    shapes = PipelineShapes(micro, mb_global, prompt_len,
                            cache_len=cache_len)

    params = M.init_params(jax.random.PRNGKey(seed), cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    cache = M.init_cache(cfg, dcfg, micro, mb_global, cache_len)
    prefill = jax.jit(build_prefill_fn(cfg, dcfg, dyncfg, mesh, shapes))
    decode = jax.jit(build_decode_fn(cfg, dcfg, dyncfg, mesh, shapes),
                     donate_argnums=(3,))
    ctrl = DynMoController(
        cfg, dcfg, dyncfg,
        ControllerConfig(method="partition", cost_by="time",
                         rebalance_every=max(1, rebalance_every)))

    rng = np.random.RandomState(seed)
    tokens = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (micro, mb_global, prompt_len)),
        jnp.int32)
    outs = []
    t0 = time.perf_counter()
    with mesh:
        ids, cache = prefill(params, assignment, dyn, cache,
                             {"tokens": tokens})
        outs.append(np.asarray(ids))
        for g in range(1, gen):
            ids, lp, cache = decode(params, assignment, dyn, cache, ids,
                                    jnp.int32(prompt_len + g - 1))
            outs.append(np.asarray(ids))
            if rebalance_every and g % rebalance_every == 0:
                # serving-time profile: survival-curve cost vector
                L = cfg.total_blocks()
                states = [LayerDynState() for _ in range(L)]
                t = cost_vector(cfg, mb_global, prompt_len + g, states,
                                by="time")
                prof = LayerProfile(
                    t, cost_vector(cfg, mb_global, prompt_len + g, states,
                                   by="param") * 2,
                    np.zeros(stages), states)
                new_lps, ev = ctrl.decide(prof, g)
                if new_lps is not None:
                    params, _, dyn, assignment, cache = ctrl.apply(
                        new_lps, params, None, dyn, cache)
    wall = time.perf_counter() - t0
    gen_tokens = np.stack(outs, axis=-1)
    tps = micro * mb_global * gen / wall
    return {"tokens": gen_tokens, "wall_s": wall, "tokens_per_s": tps,
            "final_lps": ctrl.lps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--mb-global", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--dynamism", default="none")
    ap.add_argument("--rebalance-every", type=int, default=0)
    args = ap.parse_args()
    out = run_serving(
        args.arch, stages=args.stages, micro=args.micro,
        mb_global=args.mb_global, prompt_len=args.prompt_len, gen=args.gen,
        layers=args.layers, d_model=args.d_model, dynamism=args.dynamism,
        rebalance_every=args.rebalance_every)
    print(f"generated {out['tokens'].shape} in {out['wall_s']:.1f}s "
          f"({out['tokens_per_s']:.1f} tok/s); final lps={out['final_lps']}")


if __name__ == "__main__":
    main()
