"""Training step assembly + CLI trainer.

``make_train_step`` wires the pipelined loss, optimizer, and freeze masking
into one jitted step.  The CLI driver runs real (CPU-scale) training with the
DynMo controller in the loop: dynamism events mutate the dyn state, the
profiler folds the step's stats, and rebalances migrate layers live.

Usage (CPU integration scale):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --layers 8 --d-model 128 --stages 4 --steps 50 --dynamism pruning
"""
from __future__ import annotations

import os
if os.environ.get("REPRO_TRAIN_DEVICES"):       # must precede jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_TRAIN_DEVICES"])

import argparse
import dataclasses
import functools
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DistConfig, ModelConfig, get_config, \
    reduced_config
from repro.core.controller import ControllerConfig, DynMoController
from repro.dynamics.config import DynamicsConfig
from repro.dynamics import pruning as prn
from repro.dynamics.trajectories import zhu_gupta_sparsity
from repro.models import model as M
from repro.optim.optimizers import OptConfig, make_optimizer
from repro.optim.schedule import cosine_schedule
from repro.pipeline.pipeline import PipelineShapes, build_loss_fn


def make_train_step(cfg: ModelConfig, dcfg: DistConfig,
                    dyncfg: DynamicsConfig, mesh, shapes: PipelineShapes,
                    opt_cfg: Optional[OptConfig] = None):
    """Returns (init_opt_fn, train_step) with
    train_step(params, opt_state, assignment, dyn, batch, lr)
      -> (params, opt_state, loss, stats, gnorm)."""
    opt_cfg = opt_cfg or OptConfig(name=dcfg.optimizer)
    loss_fn = build_loss_fn(cfg, dcfg, dyncfg, mesh, shapes)
    init_fn, update_fn = make_optimizer(opt_cfg)

    def train_step(params, opt_state, assignment, dyn, batch, lr):
        (loss, stats), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, assignment, dyn, batch)
        params, opt_state, gnorm = update_fn(
            grads, opt_state, params, lr, frozen=dyn.get("frozen"))
        return params, opt_state, loss, stats, gnorm

    return init_fn, train_step


# ---------------------------------------------------------------------------
# CLI integration trainer (CPU scale, real rebalancing)
# ---------------------------------------------------------------------------
def run_training(arch: str, *, steps: int = 50, stages: int = 4,
                 num_micro: int = 4, mb_global: int = 4, seq: int = 64,
                 layers: Optional[int] = None, d_model: int = 128,
                 dynamism: str = "none", rebalance_every: int = 10,
                 balancer: str = "diffusion", ckpt_dir: Optional[str] = None,
                 log_every: int = 10, seed: int = 0,
                 kernel_impl: str = "scan",
                 dyn_overrides: Optional[Dict[str, Any]] = None,
                 mesh=None) -> Dict[str, Any]:
    from repro.data.loader import DataConfig, make_loader
    from repro.launch.mesh import make_host_mesh
    cfg = get_config(arch)
    if layers is not None:
        cfg = reduced_config(cfg, num_layers=layers, d_model=d_model,
                             num_heads=4, num_kv_heads=2, d_ff=2 * d_model,
                             vocab_size=512)
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32", kernel_impl=kernel_impl)
    dyncfg = DynamicsConfig(kind=dynamism, **(dyn_overrides or {}))
    mesh = mesh or make_host_mesh(data=1, model=stages)
    shapes = PipelineShapes(num_micro=num_micro, mb_global=mb_global,
                            seq=seq)

    rng = jax.random.PRNGKey(seed)
    params = M.init_params(rng, cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    init_opt, train_step = make_train_step(cfg, dcfg, dyncfg, mesh, shapes)
    opt_state = init_opt(params)
    step_jit = jax.jit(train_step, donate_argnums=(0, 1))

    ctrl = DynMoController(
        cfg, dcfg, dyncfg,
        ControllerConfig(method=balancer, rebalance_every=rebalance_every))
    loader = make_loader(cfg, DataConfig(num_micro, mb_global, seq,
                                         seed=seed))
    ckpt = None
    if ckpt_dir:
        from repro.checkpoint.checkpoint import CheckpointManager
        ckpt = CheckpointManager(ckpt_dir, every=max(10, steps // 5))

    losses, events = [], []
    t0 = time.perf_counter()
    tokens_per_step = num_micro * mb_global * seq
    with mesh:
        for step, batch in enumerate(loader):
            if step >= steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            lr = cosine_schedule(jnp.float32(step), steps, 3e-4, warmup=10)
            params, opt_state, loss, stats, gnorm = step_jit(
                params, opt_state, assignment, dyn, batch, lr)
            losses.append(float(loss))

            # ---- dynamism events (black-box to the controller)
            if dynamism == "pruning" and step and step % 10 == 0:
                sp = zhu_gupta_sparsity(
                    step * 100, dataclasses.replace(
                        dyncfg, prune_start_iter=0, prune_end_iter=steps * 100,
                        prune_frequency=1))
                keep = prn.target_keep_blocks(
                    cfg, cfg.total_blocks(), sp)
                dyn = dict(dyn)
                dyn["ff_mask"] = prn.global_block_prune(
                    cfg, params["stages"], assignment["tags"], keep)
            if dynamism == "freezing" and step and step % 10 == 0:
                front = int(cfg.total_blocks() * min(0.6, step / steps))
                fr = np.zeros_like(np.asarray(dyn["frozen"]))
                g = 0
                tags_np = np.asarray(assignment["tags"])
                for s in range(tags_np.shape[0]):
                    for l in range(tags_np.shape[1]):
                        if tags_np[s, l] != 0:
                            if g < front:
                                fr[s, l] = 1.0
                            g += 1
                dyn = dict(dyn)
                dyn["frozen"] = jnp.asarray(fr)

            # ---- DynMo controller
            stats_np = jax.tree.map(np.asarray, stats)
            params, opt_state, dyn, new_assignment, _, ev = ctrl.step(
                step + 1, stats_np, np.asarray(assignment["tags"]),
                shapes.num_micro, tokens_per_step, seq,
                params, opt_state, dyn,
                frozen=np.asarray(dyn["frozen"]))
            if new_assignment is not None:
                assignment = new_assignment
            if ev is not None and ev.rebalanced:
                events.append(ev)
            if ckpt:
                ckpt.maybe_save(step, params, opt_state, dyn, ctrl.lps)
            if step % log_every == 0:
                print(f"step {step:4d} loss {float(loss):.4f} "
                      f"gnorm {float(gnorm):.3f} lps={ctrl.lps}")
    wall = time.perf_counter() - t0
    return {"losses": losses, "events": events, "wall_s": wall,
            "final_lps": ctrl.lps, "params": params,
            "assignment": assignment, "tokens_per_step": tokens_per_step}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--num-micro", type=int, default=4)
    ap.add_argument("--mb-global", type=int, default=4)
    ap.add_argument("--dynamism", default="none")
    ap.add_argument("--kernel-impl", default="scan",
                    choices=["reference", "scan", "pallas"])
    ap.add_argument("--balancer", default="diffusion")
    ap.add_argument("--rebalance-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    out = run_training(
        args.arch, steps=args.steps, stages=args.stages, layers=args.layers,
        d_model=args.d_model, seq=args.seq, num_micro=args.num_micro,
        mb_global=args.mb_global, dynamism=args.dynamism,
        kernel_impl=args.kernel_impl, balancer=args.balancer,
        rebalance_every=args.rebalance_every, ckpt_dir=args.ckpt_dir)
    print(f"done: loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"in {out['wall_s']:.1f}s; rebalances={len(out['events'])}")


if __name__ == "__main__":
    main()
