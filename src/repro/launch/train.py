"""CLI trainer — a thin adapter over ``repro.api`` (RunSpec + Session).

The training loop itself lives in ``repro.api.session.Session.train``; this
module only (1) resolves a ``RunSpec`` from the CLI (``--config run.json``,
auto-generated dotted spec flags, the historical flag surface as aliases,
and ``--set path=value`` overrides — see ``repro.api.cli``) and (2) keeps
``run_training(...)`` as a **deprecation-shim** kwarg API: it builds the
equivalent ``RunSpec`` internally, so every pre-existing caller produces
bit-identical runs to the spec path.

Usage (CPU integration scale, 4 forced host devices):
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.train \
      --config configs/scenarios/early_exit.json
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.train \
      --arch smollm-360m --layers 8 --d-model 128 --stages 4 --steps 30 \
      --dynamism pruning --repack --async-controller --autoscale \
      --job-manager file --simulate-recover 18 \
      --set controller.repack.policy=first_fit
"""
from __future__ import annotations

import os
if os.environ.get("REPRO_TRAIN_DEVICES"):       # must precede jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_TRAIN_DEVICES"])

import argparse
from typing import Any, Dict, Optional

from repro.api.cli import (TRAIN_ALIASES, TRAIN_CLI_DEFAULTS,
                           add_alias_flags, add_config_args, add_spec_flags,
                           build_spec, maybe_dump)
from repro.api.session import Session
from repro.api.specs import (ClusterSpec, ControllerSpec, DynamicsSpec,
                             ModelSpec, ParallelSpec, RepackSpec, RunSpec)
from repro.launch.engine import ElasticEngine, make_train_step  # noqa: F401
# make_train_step / ElasticEngine are re-exported for back-compat
# (tests/examples import them from here); engine.py owns step assembly.


def train_spec(arch: str, *, steps: int = 50, stages: int = 4,
               num_micro: int = 4, mb_global: int = 4, seq: int = 64,
               layers: Optional[int] = None, d_model: int = 128,
               dynamism: str = "none", rebalance_every: int = 10,
               balancer: str = "diffusion", ckpt_dir: Optional[str] = None,
               log_every: int = 10, seed: int = 0,
               kernel_impl: str = "scan",
               dyn_overrides: Optional[Dict[str, Any]] = None,
               repack: bool = False, repack_policy: str = "adjacent",
               repack_mem_cap: float = 1.1, repack_target: int = 1,
               grow_back: Optional[int] = None,
               async_controller: bool = False, async_drain: bool = False,
               autoscale: bool = False,
               autoscale_watermark: bool = False,
               heartbeat_timeout: float = 3.0,
               simulate_recover: Optional[int] = None,
               job_manager: str = "inproc",
               job_manager_dir: Optional[str] = None,
               tenant_id: Optional[str] = None, priority: int = 0,
               manager_url: Optional[str] = None,
               straggler: Optional[Dict[int, float]] = None,
               measure_stage_times: bool = False) -> RunSpec:
    """The ``RunSpec`` equivalent of the legacy ``run_training`` kwargs —
    the single place the old vocabulary maps onto the spec schema."""
    return RunSpec(
        model=ModelSpec(arch=arch, layers=layers, d_model=d_model),
        parallel=ParallelSpec(stages=stages, num_micro=num_micro,
                              mb_global=mb_global, seq=seq,
                              kernel_impl=kernel_impl),
        dynamics=DynamicsSpec(kind=dynamism, **(dyn_overrides or {})),
        controller=ControllerSpec(
            balancer=balancer, rebalance_every=rebalance_every,
            repack=RepackSpec(enabled=repack, policy=repack_policy,
                              mem_cap=repack_mem_cap,
                              target=max(1, repack_target)),
            async_decide=async_controller, async_drain=async_drain,
            straggler=straggler,
            measure_stage_times=measure_stage_times),
        cluster=ClusterSpec(job_manager=job_manager,
                            job_manager_dir=job_manager_dir,
                            tenant_id=tenant_id, priority=priority,
                            manager_url=manager_url,
                            autoscale=autoscale,
                            autoscale_watermark=autoscale_watermark,
                            heartbeat_timeout=heartbeat_timeout,
                            simulate_recover=simulate_recover,
                            grow_back=grow_back),
        steps=steps, seed=seed, log_every=log_every, ckpt_dir=ckpt_dir)


def run_training(arch: str, **kwargs) -> Dict[str, Any]:
    """Legacy kwarg entry point (deprecation shim).

    Builds the equivalent ``RunSpec`` and runs it through a ``Session`` —
    new code should do that directly:

        with Session(train_spec(arch, ...)) as s:
            report = s.train()
    """
    spec = train_spec(arch, **kwargs)
    with Session(spec) as s:
        return s.train()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="DynMo trainer (config-first: --config RUN.JSON; "
                    "flags below override spec fields)")
    add_config_args(ap)
    ap.add_argument("--resume", default=None, metavar="CKPT_DIR",
                    help="resume from the newest safe point in this "
                         "directory; the safe point carries the producing "
                         "RunSpec, so every other flag is ignored")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the session's structured telemetry stream "
                         "(one JSON record per rebalance / resize / "
                         "relayout / autoscale / log event) to this file")
    add_alias_flags(ap, TRAIN_ALIASES)
    add_spec_flags(ap)
    args = ap.parse_args(argv)
    if args.resume:
        sess = Session.resume(args.resume)
    else:
        spec = build_spec(args, TRAIN_ALIASES,
                          cli_defaults=TRAIN_CLI_DEFAULTS)
        if maybe_dump(args, spec):
            return
        sess = Session(spec)
    with sess as s:
        out = s.train()
    if args.events_out:
        import dataclasses
        import json
        with open(args.events_out, "w") as f:
            json.dump([dataclasses.asdict(ev) for ev in sess.events], f,
                      indent=1)
        print(f"wrote {len(sess.events)} events to {args.events_out}")
    ctl = out["controller"]
    print(f"done: loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"in {out['wall_s']:.1f}s; rebalances={len(out['events'])}; "
          f"resizes={len(out['resizes'])}; "
          f"relayouts={len(out['relayouts'])}; "
          f"final stages={out['final_stages']}; "
          f"controller[{ctl['mode']}] decided={ctl['decided']} "
          f"dropped={ctl['dropped']} stale={ctl['stale_rejected']}")
    for rz in out["resizes"]:
        print(f"  {rz['kind']} @step {rz['step']}: {rz['from_stages']}->"
              f"{rz['to_stages']} stages, workers {rz['workers']}, "
              f"{rz['seconds']*1e3:.0f}ms, ticks {rz['ticks_before']}->"
              f"{rz['ticks_after']}")
    for d in out["autoscale_decisions"]:
        print(f"  autoscale @step {d['step']}: {d['action']} "
              f"x{d['workers']} ({d['reason']})")


if __name__ == "__main__":
    main()
