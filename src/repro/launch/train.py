"""CLI trainer on top of the elastic engine and the cluster control plane.

``run_training`` drives the DynMo loop end-to-end: dynamism events mutate
the dyn state, the ``ControlPlane`` folds the step's stats through
profile→decide — inline or on a background thread (``--async-controller``,
paper §3.3.1: zero decision latency on the training thread) — rebalances
migrate layers live at safe points, and a repack decision triggers an
in-process shrink onto fewer workers via ``repro.launch.engine.ElasticEngine``.

Released workers cross the job-manager boundary (``--job-manager file``
puts a real process on the other side); re-expansion is signal-driven with
``--autoscale`` (heartbeat recoveries + throughput watermark, replacing the
legacy fixed-step ``--grow-back N``, which remains for back-compat).

Usage (CPU integration scale, 4 forced host devices):
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.train \
      --arch smollm-360m --layers 8 --d-model 128 --stages 4 --steps 30 \
      --dynamism pruning --repack --async-controller --autoscale \
      --job-manager file --simulate-recover 18
"""
from __future__ import annotations

import os
if os.environ.get("REPRO_TRAIN_DEVICES"):       # must precede jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_TRAIN_DEVICES"])

import argparse
import dataclasses
import tempfile
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
from repro.cluster.rpc import FileJobManager, spawn_file_manager
from repro.cluster.service import ControlPlane, StatsSnapshot
from repro.configs.base import DistConfig, ModelConfig, get_config, \
    reduced_config
from repro.core.controller import ControllerConfig, DynMoController
from repro.dynamics.config import DynamicsConfig
from repro.dynamics import pruning as prn
from repro.dynamics.trajectories import zhu_gupta_sparsity
from repro.launch.engine import ElasticEngine, make_train_step  # noqa: F401
# make_train_step is re-exported for back-compat (tests/examples import it
# from here); it moved to engine.py, which owns step assembly now.
from repro.optim.schedule import cosine_schedule
from repro.pipeline.pipeline import PipelineShapes
from repro.runtime.fault_tolerance import HeartbeatMonitor, StragglerDetector


def _parse_straggler(spec: Optional[str]) -> Optional[Dict[int, float]]:
    """"2:1.5,3:1.2" → {2: 1.5, 3: 1.2}."""
    if not spec:
        return None
    out: Dict[int, float] = {}
    for part in spec.split(","):
        s, m = part.split(":")
        out[int(s)] = float(m)
    return out


# ---------------------------------------------------------------------------
# CLI integration trainer (CPU scale, real rebalancing + live elasticity)
# ---------------------------------------------------------------------------
def run_training(arch: str, *, steps: int = 50, stages: int = 4,
                 num_micro: int = 4, mb_global: int = 4, seq: int = 64,
                 layers: Optional[int] = None, d_model: int = 128,
                 dynamism: str = "none", rebalance_every: int = 10,
                 balancer: str = "diffusion", ckpt_dir: Optional[str] = None,
                 log_every: int = 10, seed: int = 0,
                 kernel_impl: str = "scan",
                 dyn_overrides: Optional[Dict[str, Any]] = None,
                 repack: bool = False, repack_policy: str = "adjacent",
                 repack_mem_cap: float = 1.1, repack_target: int = 1,
                 grow_back: Optional[int] = None,
                 async_controller: bool = False, async_drain: bool = False,
                 autoscale: bool = False,
                 autoscale_watermark: bool = False,
                 heartbeat_timeout: float = 3.0,
                 simulate_recover: Optional[int] = None,
                 job_manager: str = "inproc",
                 job_manager_dir: Optional[str] = None,
                 straggler: Optional[Dict[int, float]] = None,
                 measure_stage_times: bool = False
                 ) -> Dict[str, Any]:
    from repro.data.loader import DataConfig, make_loader
    cfg = get_config(arch)
    if layers is not None:
        cfg = reduced_config(cfg, num_layers=layers, d_model=d_model,
                             num_heads=4, num_kv_heads=2, d_ff=2 * d_model,
                             vocab_size=512)
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32", kernel_impl=kernel_impl)
    dyncfg = DynamicsConfig(kind=dynamism, **(dyn_overrides or {}))
    shapes = PipelineShapes(num_micro=num_micro, mb_global=mb_global,
                            seq=seq)
    tokens_per_step = num_micro * mb_global * seq

    # ---- job-manager boundary (in-process pool or file RPC to a server
    # process — release/grant actually leave this process in file mode)
    jm = jm_proc = None
    if job_manager == "file":
        # always a FRESH directory (a unique subdir when the caller names a
        # location): leftover req/resp files from a previous run would be
        # replayed by the new server and misread by the new client
        if job_manager_dir:
            os.makedirs(job_manager_dir, exist_ok=True)
            jm_dir = tempfile.mkdtemp(prefix="run_", dir=job_manager_dir)
        else:
            jm_dir = tempfile.mkdtemp(prefix="dynmo_jm_")
        jm_proc = spawn_file_manager(jm_dir, stages)
        jm = FileJobManager(jm_dir, timeout_s=60.0)
    elif job_manager != "inproc":
        raise ValueError(f"unknown job manager {job_manager!r}")

    engine = ElasticEngine(cfg, dcfg, dyncfg, shapes, data=1,
                           job_manager=jm)
    state = engine.init_state(jax.random.PRNGKey(seed))

    ccfg = ControllerConfig(method=balancer, rebalance_every=rebalance_every,
                            repack=repack, repack_policy=repack_policy,
                            repack_target=max(1, repack_target))
    if repack:
        # per-worker memory budget: capacity factor × the dtype-correct
        # per-stage footprint of the UNPRUNED model under a uniform split —
        # consolidation becomes feasible once dynamism shrinks the model
        from repro.core.cost_model import stage_memory_budget
        ccfg.repack_mem_cap = stage_memory_budget(
            cfg, tokens_per_step, seq, dcfg.bytes_per_param, stages,
            cap_factor=repack_mem_cap)
    det = StragglerDetector(stages) \
        if (straggler or measure_stage_times) else None
    ctrl = DynMoController(cfg, dcfg, dyncfg, ccfg, straggler=det)
    cp = ControlPlane(ctrl, async_mode=async_controller,
                      epoch_fn=lambda: engine.epoch)

    # ---- autoscaler: heartbeats + throughput watermark (replaces
    # --grow-back); the monitor runs on a step-granular simulated clock so
    # CI runs are deterministic
    monitor = scaler = None
    sim_clock = [0.0]
    if autoscale:
        monitor = HeartbeatMonitor(stages, timeout_s=heartbeat_timeout,
                                   clock=lambda: sim_clock[0])
        scaler = Autoscaler(
            AutoscalerConfig(min_stages=max(1, repack_target),
                             max_stages=stages,
                             watermark=autoscale_watermark), monitor)

    loader = make_loader(cfg, DataConfig(num_micro, mb_global, seq,
                                         seed=seed))
    ckpt = None
    if ckpt_dir:
        from repro.checkpoint.checkpoint import CheckpointManager
        ckpt = CheckpointManager(ckpt_dir, every=max(10, steps // 5))

    def after_resize(step: int, kind: str) -> None:
        cp.rebind(engine.dcfg_for(state.stages), state.lps)
        if scaler is not None:
            scaler.note_resize(step, state.stages)
        rz = engine.resizes[-1]
        if monitor is not None and rz.kind == "shrink":
            # released workers leave the heartbeat set deliberately; a
            # later revive is the recovery signal the autoscaler grows on
            for w in rz.workers:
                monitor.expire(w)
        if monitor is not None and rz.kind == "grow":
            # regranted workers (any grow path: recovery, watermark,
            # legacy --grow-back) must beat again — without the revive
            # they would stay marked failed and a later real death of the
            # same worker could never be detected
            for w in rz.workers:
                monitor.revive(w)
        print(f"step {step:4d} {kind.upper()} {rz.from_stages}->"
              f"{rz.to_stages} stages; workers {rz.workers}; "
              f"pool active={engine.jm.num_active}; schedule "
              f"{rz.ticks_before}->{rz.ticks_after} ticks")

    losses, events, step_times, stages_hist = [], [], [], []
    last_measured = None
    t0 = time.perf_counter()
    try:
        for step, batch in enumerate(loader):
            if step >= steps:
                break
            t_step = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            lr = cosine_schedule(jnp.float32(step), steps, 3e-4, warmup=10)
            loss, stats, gnorm = engine.step(state, batch, lr)
            # one scalar sync for the loss curve; the full per-slot stats
            # tree stays on device until controller cadence (§3.3.1)
            losses.append(float(loss))
            step_times.append(time.perf_counter() - t_step)
            stages_hist.append(state.stages)

            # ---- dynamism events (black-box to the controller)
            if dynamism == "pruning" and step and step % 10 == 0:
                sp = zhu_gupta_sparsity(
                    step * 100, dataclasses.replace(
                        dyncfg, prune_start_iter=0,
                        prune_end_iter=steps * 100, prune_frequency=1))
                keep = prn.target_keep_blocks(
                    cfg, cfg.total_blocks(), sp)
                dyn = dict(state.dyn)
                dyn["ff_mask"] = prn.global_block_prune(
                    cfg, state.params["stages"], state.assignment["tags"],
                    keep)
                state.dyn = dyn
            if dynamism == "freezing" and step and step % 10 == 0:
                front = int(cfg.total_blocks() * min(0.6, step / steps))
                fr = np.zeros_like(np.asarray(state.dyn["frozen"]))
                g = 0
                tags_np = np.asarray(state.assignment["tags"])
                for s in range(tags_np.shape[0]):
                    for l in range(tags_np.shape[1]):
                        if tags_np[s, l] != 0:
                            if g < front:
                                fr[s, l] = 1.0
                            g += 1
                dyn = dict(state.dyn)
                dyn["frozen"] = jnp.asarray(fr)
                state.dyn = dyn

            # ---- heartbeats (simulated per-step liveness: active workers
            # beat; released/dead ones go silent and time out)
            if monitor is not None:
                sim_clock[0] = float(step)
                for w in engine.stage_workers:
                    monitor.beat(w)
                if simulate_recover is not None and step == simulate_recover:
                    for w in range(stages):
                        if w not in engine.stage_workers:
                            monitor.revive(w)

            # ---- publish stats to the control plane on cadence (the only
            # device→host stats sync; in async mode this is a pointer swap)
            if ctrl.cadence(step + 1):
                measured = None
                if measure_stage_times:
                    # real per-stage wall times from the engine's stage
                    # probe — cadence-gated here so the hot path stays
                    # sync-free (the probe is a per-stage host sync)
                    measured = engine.measure_stage_times(state, batch)
                    last_measured = measured
                if straggler:
                    # simulation knob: a straggling WORKER multiplies its
                    # stage's wall time; feed the detector the same shape a
                    # real per-worker timer would report (or skew the
                    # measured times when both are on).  Keyed by WORKER
                    # id — after an evict/resize the slow machine keeps its
                    # id but sits at a different stage index
                    if measured is None:
                        share = np.asarray(state.lps, np.float64)
                        measured = share / share.sum() * step_times[-1]
                    measured = measured * np.array(
                        [straggler.get(engine.stage_workers[s], 1.0)
                         for s in range(state.stages)])
                cp.publish(StatsSnapshot(
                    iteration=step + 1, epoch=engine.epoch,
                    stats=engine.stats_to_host(state, stats),
                    tags=np.asarray(state.assignment["tags"]),
                    num_micro=shapes.num_micro, tokens=tokens_per_step,
                    seq=seq, frozen=np.asarray(state.dyn["frozen"]),
                    stage_times=measured))
                if async_drain:
                    cp.drain()

            # ---- safe point: apply the newest finished plan (epoch-fenced;
            # a plan decided against a pre-resize world is rejected)
            plan = cp.poll(engine.epoch)
            if plan is not None:
                if plan.event is not None and plan.event.rebalanced:
                    events.append(plan.event)
                if (plan.resize is not None
                        and plan.resize.target_stages < state.stages):
                    state = engine.shrink(state, plan.resize.target_stages,
                                          plan.resize.layers_per_stage,
                                          step=step)
                    after_resize(step, f"shrink[{plan.resize.policy}]")
                elif plan.new_lps is not None:
                    p, o, d, new_assignment, _ = cp.apply(
                        plan, state.params, state.opt_state, state.dyn)
                    state.params, state.opt_state, state.dyn = p, o, d
                    state.assignment = new_assignment
                    state.lps = list(cp.ctrl.lps)

            # ---- autoscaler: heartbeat + watermark signals
            if scaler is not None:
                d = scaler.observe(step, step_times[-1], state.stages,
                                   engine.stage_workers, tokens_per_step)
                if d.action == "evict":
                    state = engine.evict(state, d.ids, step=step)
                    after_resize(step, "evict")
                elif d.action == "grow" and state.stages < stages:
                    prev = state.stages
                    state = engine.grow(state, d.workers, step=step)
                    if state.stages > prev:   # pool may grant nothing
                        # granted workers stay for this job: stop planning
                        # resizes so ordinary rebalancing keeps running
                        cp.with_ctrl(
                            lambda c: setattr(c.ccfg, "repack", False))
                        after_resize(step, "grow")
                elif (d.action == "shrink"
                        and state.stages > max(1, repack_target)):
                    state = engine.shrink(
                        state, max(max(1, repack_target),
                                   state.stages - d.workers), step=step)
                    after_resize(step, "shrink[watermark]")

            # ---- legacy fixed-step growth (back-compat; superseded by
            # --autoscale)
            if (grow_back and engine.last_shrink_step is not None
                    and state.stages < stages
                    and step >= engine.last_shrink_step + grow_back):
                prev_stages = state.stages
                state = engine.grow(state, stages - state.stages, step=step)
                if state.stages > prev_stages:
                    cp.with_ctrl(lambda c: setattr(c.ccfg, "repack", False))
                    after_resize(step, "grow")
            if ckpt:
                ckpt.maybe_save(step, state.params, state.opt_state,
                                state.dyn, state.lps)
            if step % log_every == 0:
                print(f"step {step:4d} loss {float(loss):.4f} "
                      f"gnorm {float(gnorm):.3f} S={state.stages} "
                      f"lps={state.lps}")
    finally:
        cp.close()
        if jm is not None:
            jm.close()                      # tells the server to exit
        if jm_proc is not None:
            try:
                jm_proc.wait(timeout=10)
            except Exception:
                jm_proc.kill()
    wall = time.perf_counter() - t0
    return {"losses": losses, "events": events, "wall_s": wall,
            "final_lps": list(state.lps), "params": state.params,
            "assignment": state.assignment,
            "tokens_per_step": tokens_per_step,
            "step_times": step_times, "stages_history": stages_hist,
            "resizes": [dataclasses.asdict(e) for e in engine.resizes],
            "pool_log": list(engine.jm.log),
            "final_stages": state.stages,
            "measured_stage_times": (list(map(float, last_measured))
                                     if last_measured is not None else None),
            "controller": {
                "mode": "async" if async_controller else "inline",
                "published": cp.published, "decided": cp.decided,
                "dropped": cp.dropped,
                "stale_rejected": cp.stale_rejected},
            "autoscale_decisions": ([dataclasses.asdict(d)
                                     for d in scaler.decisions]
                                    if scaler is not None else [])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--num-micro", type=int, default=4)
    ap.add_argument("--mb-global", type=int, default=4)
    ap.add_argument("--dynamism", default="none")
    ap.add_argument("--kernel-impl", default="scan",
                    choices=["reference", "scan", "pallas"])
    ap.add_argument("--balancer", default="diffusion")
    ap.add_argument("--rebalance-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--repack", action="store_true",
                    help="enable live worker consolidation (paper Alg. 2)")
    ap.add_argument("--repack-policy", default="adjacent",
                    choices=["adjacent", "first_fit"])
    ap.add_argument("--repack-mem-cap", type=float, default=1.1,
                    help="per-worker memory budget as a multiple of the "
                         "unpruned per-stage footprint")
    ap.add_argument("--repack-target", type=int, default=1,
                    help="never consolidate below this many workers")
    ap.add_argument("--grow-back", type=int, default=None,
                    help="legacy: re-expand N steps after a shrink "
                         "(prefer --autoscale)")
    ap.add_argument("--async-controller", action="store_true",
                    help="run profile->decide on a background thread "
                         "(double-buffered stats mailbox, epoch-fenced "
                         "plans)")
    ap.add_argument("--async-drain", action="store_true",
                    help="deterministic async mode: block for each "
                         "decision (parity testing)")
    ap.add_argument("--autoscale", action="store_true",
                    help="signal-driven shrink/grow: heartbeat failures/"
                         "recoveries (+ throughput watermark with "
                         "--autoscale-watermark)")
    ap.add_argument("--autoscale-watermark", action="store_true",
                    help="also scale on the per-worker throughput "
                         "watermark (wall-clock based — leave off on "
                         "noisy shared machines)")
    ap.add_argument("--heartbeat-timeout", type=float, default=3.0,
                    help="missed-beat timeout in steps (simulated clock)")
    ap.add_argument("--simulate-recover", type=int, default=None,
                    help="revive all non-active workers at this step "
                         "(heartbeat-recovery demo)")
    ap.add_argument("--job-manager", default="inproc",
                    choices=["inproc", "file"],
                    help="'file' puts the WorkerPool behind a file-RPC "
                         "server in a separate process")
    ap.add_argument("--job-manager-dir", default=None)
    ap.add_argument("--straggler", default=None,
                    help="simulate slow workers, e.g. '2:1.5' (stage 2 "
                         "runs 1.5x slow); the detector feeds the "
                         "balancer")
    ap.add_argument("--measure-stage-times", action="store_true",
                    help="feed MEASURED per-stage wall times (engine stage "
                         "probe, controller cadence only) into the "
                         "straggler detector instead of the --straggler "
                         "simulation")
    args = ap.parse_args()
    out = run_training(
        args.arch, steps=args.steps, stages=args.stages, layers=args.layers,
        d_model=args.d_model, seq=args.seq, num_micro=args.num_micro,
        mb_global=args.mb_global, dynamism=args.dynamism,
        kernel_impl=args.kernel_impl, balancer=args.balancer,
        rebalance_every=args.rebalance_every, ckpt_dir=args.ckpt_dir,
        repack=args.repack, repack_policy=args.repack_policy,
        repack_mem_cap=args.repack_mem_cap,
        repack_target=args.repack_target, grow_back=args.grow_back,
        async_controller=args.async_controller,
        async_drain=args.async_drain, autoscale=args.autoscale,
        autoscale_watermark=args.autoscale_watermark,
        heartbeat_timeout=args.heartbeat_timeout,
        simulate_recover=args.simulate_recover,
        job_manager=args.job_manager,
        job_manager_dir=args.job_manager_dir,
        straggler=_parse_straggler(args.straggler),
        measure_stage_times=args.measure_stage_times)
    ctl = out["controller"]
    print(f"done: loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"in {out['wall_s']:.1f}s; rebalances={len(out['events'])}; "
          f"resizes={len(out['resizes'])}; "
          f"final stages={out['final_stages']}; "
          f"controller[{ctl['mode']}] decided={ctl['decided']} "
          f"dropped={ctl['dropped']} stale={ctl['stale_rejected']}")
    for rz in out["resizes"]:
        print(f"  {rz['kind']} @step {rz['step']}: {rz['from_stages']}->"
              f"{rz['to_stages']} stages, workers {rz['workers']}, "
              f"{rz['seconds']*1e3:.0f}ms, ticks {rz['ticks_before']}->"
              f"{rz['ticks_after']}")
    for d in out["autoscale_decisions"]:
        print(f"  autoscale @step {d['step']}: {d['action']} "
              f"x{d['workers']} ({d['reason']})")


if __name__ == "__main__":
    main()
