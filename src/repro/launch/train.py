"""CLI trainer on top of the elastic engine.

``run_training`` drives the DynMo loop end-to-end: dynamism events mutate
the dyn state, the profiler folds the step's stats on controller cadence,
rebalances migrate layers live, and — with ``--repack`` — the controller's
consolidation decision triggers an in-process shrink onto fewer workers via
``repro.launch.engine.ElasticEngine`` (released workers go back to the
``WorkerPool``; ``--grow-back N`` re-expands N steps later).

Usage (CPU integration scale, 4 forced host devices):
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python -m repro.launch.train \
      --arch smollm-360m --layers 8 --d-model 128 --stages 4 --steps 50 \
      --dynamism pruning --repack
"""
from __future__ import annotations

import os
if os.environ.get("REPRO_TRAIN_DEVICES"):       # must precede jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_TRAIN_DEVICES"])

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DistConfig, ModelConfig, get_config, \
    reduced_config
from repro.core.controller import ControllerConfig, DynMoController
from repro.dynamics.config import DynamicsConfig
from repro.dynamics import pruning as prn
from repro.dynamics.trajectories import zhu_gupta_sparsity
from repro.launch.engine import ElasticEngine, make_train_step  # noqa: F401
# make_train_step is re-exported for back-compat (tests/examples import it
# from here); it moved to engine.py, which owns step assembly now.
from repro.optim.schedule import cosine_schedule
from repro.pipeline.pipeline import PipelineShapes


# ---------------------------------------------------------------------------
# CLI integration trainer (CPU scale, real rebalancing + live elasticity)
# ---------------------------------------------------------------------------
def run_training(arch: str, *, steps: int = 50, stages: int = 4,
                 num_micro: int = 4, mb_global: int = 4, seq: int = 64,
                 layers: Optional[int] = None, d_model: int = 128,
                 dynamism: str = "none", rebalance_every: int = 10,
                 balancer: str = "diffusion", ckpt_dir: Optional[str] = None,
                 log_every: int = 10, seed: int = 0,
                 kernel_impl: str = "scan",
                 dyn_overrides: Optional[Dict[str, Any]] = None,
                 repack: bool = False, repack_policy: str = "adjacent",
                 repack_mem_cap: float = 1.1, repack_target: int = 1,
                 grow_back: Optional[int] = None) -> Dict[str, Any]:
    from repro.data.loader import DataConfig, make_loader
    cfg = get_config(arch)
    if layers is not None:
        cfg = reduced_config(cfg, num_layers=layers, d_model=d_model,
                             num_heads=4, num_kv_heads=2, d_ff=2 * d_model,
                             vocab_size=512)
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32", kernel_impl=kernel_impl)
    dyncfg = DynamicsConfig(kind=dynamism, **(dyn_overrides or {}))
    shapes = PipelineShapes(num_micro=num_micro, mb_global=mb_global,
                            seq=seq)
    tokens_per_step = num_micro * mb_global * seq

    engine = ElasticEngine(cfg, dcfg, dyncfg, shapes, data=1)
    state = engine.init_state(jax.random.PRNGKey(seed))

    ccfg = ControllerConfig(method=balancer, rebalance_every=rebalance_every,
                            repack=repack, repack_policy=repack_policy,
                            repack_target=max(1, repack_target))
    if repack:
        # per-worker memory budget: capacity factor × the dtype-correct
        # per-stage footprint of the UNPRUNED model under a uniform split —
        # consolidation becomes feasible once dynamism shrinks the model
        from repro.core.cost_model import stage_memory_budget
        ccfg.repack_max_mem = stage_memory_budget(
            cfg, tokens_per_step, seq, dcfg.bytes_per_param, stages,
            cap_factor=repack_mem_cap)
    ctrl = DynMoController(cfg, dcfg, dyncfg, ccfg)

    loader = make_loader(cfg, DataConfig(num_micro, mb_global, seq,
                                         seed=seed))
    ckpt = None
    if ckpt_dir:
        from repro.checkpoint.checkpoint import CheckpointManager
        ckpt = CheckpointManager(ckpt_dir, every=max(10, steps // 5))

    losses, events, step_times, stages_hist = [], [], [], []
    t0 = time.perf_counter()
    for step, batch in enumerate(loader):
        if step >= steps:
            break
        t_step = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        lr = cosine_schedule(jnp.float32(step), steps, 3e-4, warmup=10)
        loss, stats, gnorm = engine.step(state, batch, lr)
        # one scalar sync for the loss curve; the full per-slot stats tree
        # stays on device until controller cadence (§3.3.1)
        losses.append(float(loss))
        step_times.append(time.perf_counter() - t_step)
        stages_hist.append(state.stages)

        # ---- dynamism events (black-box to the controller)
        if dynamism == "pruning" and step and step % 10 == 0:
            sp = zhu_gupta_sparsity(
                step * 100, dataclasses.replace(
                    dyncfg, prune_start_iter=0, prune_end_iter=steps * 100,
                    prune_frequency=1))
            keep = prn.target_keep_blocks(
                cfg, cfg.total_blocks(), sp)
            dyn = dict(state.dyn)
            dyn["ff_mask"] = prn.global_block_prune(
                cfg, state.params["stages"], state.assignment["tags"], keep)
            state.dyn = dyn
        if dynamism == "freezing" and step and step % 10 == 0:
            front = int(cfg.total_blocks() * min(0.6, step / steps))
            fr = np.zeros_like(np.asarray(state.dyn["frozen"]))
            g = 0
            tags_np = np.asarray(state.assignment["tags"])
            for s in range(tags_np.shape[0]):
                for l in range(tags_np.shape[1]):
                    if tags_np[s, l] != 0:
                        if g < front:
                            fr[s, l] = 1.0
                        g += 1
            dyn = dict(state.dyn)
            dyn["frozen"] = jnp.asarray(fr)
            state.dyn = dyn

        # ---- DynMo controller (device→host sync only on cadence)
        if ctrl.cadence(step + 1):
            stats_np = engine.stats_to_host(state, stats)
            p, o, d, new_assignment, _, ev = ctrl.step(
                step + 1, stats_np, np.asarray(state.assignment["tags"]),
                shapes.num_micro, tokens_per_step, seq,
                state.params, state.opt_state, state.dyn,
                frozen=np.asarray(state.dyn["frozen"]))
            state.params, state.opt_state, state.dyn = p, o, d
            if new_assignment is not None:
                state.assignment = new_assignment
                state.lps = list(ctrl.lps)
            if ev is not None and ev.rebalanced:
                events.append(ev)
            plan = ctrl.take_resize()
            if plan is not None and plan.target_stages < state.stages:
                state = engine.shrink(state, plan.target_stages,
                                      plan.layers_per_stage, step=step)
                ctrl.rebind(engine.dcfg_for(state.stages), state.lps)
                rz = engine.resizes[-1]
                print(f"step {step:4d} SHRINK {rz.from_stages}->"
                      f"{rz.to_stages} stages ({plan.policy}); released "
                      f"workers {rz.workers}; pool active="
                      f"{engine.pool.num_active}; schedule "
                      f"{rz.ticks_before}->{rz.ticks_after} ticks")
        if (grow_back and engine.last_shrink_step is not None
                and state.stages < stages
                and step >= engine.last_shrink_step + grow_back):
            prev_stages = state.stages
            state = engine.grow(state, stages - state.stages, step=step)
            if state.stages > prev_stages:    # pool may grant nothing yet
                ctrl.rebind(engine.dcfg_for(state.stages), state.lps)
                # granted workers stay for this job: stop planning resizes
                # so ordinary rebalancing keeps running (a pending plan
                # would otherwise suppress it every cadence)
                ctrl.ccfg.repack = False
                rz = engine.resizes[-1]
                print(f"step {step:4d} GROW {rz.from_stages}->"
                      f"{rz.to_stages} stages; granted workers "
                      f"{rz.workers}; pool active="
                      f"{engine.pool.num_active}")
        if ckpt:
            ckpt.maybe_save(step, state.params, state.opt_state, state.dyn,
                            ctrl.lps)
        if step % log_every == 0:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} S={state.stages} "
                  f"lps={ctrl.lps}")
    wall = time.perf_counter() - t0
    return {"losses": losses, "events": events, "wall_s": wall,
            "final_lps": ctrl.lps, "params": state.params,
            "assignment": state.assignment,
            "tokens_per_step": tokens_per_step,
            "step_times": step_times, "stages_history": stages_hist,
            "resizes": [dataclasses.asdict(e) for e in engine.resizes],
            "pool_log": list(engine.pool.log),
            "final_stages": state.stages}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--num-micro", type=int, default=4)
    ap.add_argument("--mb-global", type=int, default=4)
    ap.add_argument("--dynamism", default="none")
    ap.add_argument("--kernel-impl", default="scan",
                    choices=["reference", "scan", "pallas"])
    ap.add_argument("--balancer", default="diffusion")
    ap.add_argument("--rebalance-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--repack", action="store_true",
                    help="enable live worker consolidation (paper Alg. 2)")
    ap.add_argument("--repack-policy", default="adjacent",
                    choices=["adjacent", "first_fit"])
    ap.add_argument("--repack-mem-cap", type=float, default=1.1,
                    help="per-worker memory budget as a multiple of the "
                         "unpruned per-stage footprint")
    ap.add_argument("--repack-target", type=int, default=1,
                    help="never consolidate below this many workers")
    ap.add_argument("--grow-back", type=int, default=None,
                    help="re-expand to the original stage count N steps "
                         "after a shrink (workers granted back by the pool)")
    args = ap.parse_args()
    out = run_training(
        args.arch, steps=args.steps, stages=args.stages, layers=args.layers,
        d_model=args.d_model, seq=args.seq, num_micro=args.num_micro,
        mb_global=args.mb_global, dynamism=args.dynamism,
        kernel_impl=args.kernel_impl, balancer=args.balancer,
        rebalance_every=args.rebalance_every, ckpt_dir=args.ckpt_dir,
        repack=args.repack, repack_policy=args.repack_policy,
        repack_mem_cap=args.repack_mem_cap,
        repack_target=args.repack_target, grow_back=args.grow_back)
    print(f"done: loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"in {out['wall_s']:.1f}s; rebalances={len(out['events'])}; "
          f"resizes={len(out['resizes'])}; "
          f"final stages={out['final_stages']}")
    for rz in out["resizes"]:
        print(f"  {rz['kind']} @step {rz['step']}: {rz['from_stages']}->"
              f"{rz['to_stages']} stages, workers {rz['workers']}, "
              f"{rz['seconds']*1e3:.0f}ms, ticks {rz['ticks_before']}->"
              f"{rz['ticks_after']}")


if __name__ == "__main__":
    main()
