"""Roofline extraction from AOT-compiled artifacts.

Terms per (arch × shape × mesh), per the task spec:
    compute    = HLO_FLOPs / (chips × peak FLOP/s)
    memory     = HLO_bytes / (chips × HBM bandwidth)
    collective = collective_bytes / (chips × link bandwidth)

XLA's cost_analysis counts loop bodies ONCE (measured, see DESIGN.md §5), so
FLOPs/bytes/collective-bytes come from two *unrolled probe* compiles at
num_micro = 1 and 2: differencing isolates the exact per-tick cost, then the
schedule length T = m + S − 1 extrapolates to the real microbatch count.
collective_bytes are summed from the compiled HLO text (operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).
Memory comes from the full-scale scan-based compile's memory_analysis().

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=(.*?)\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in an (unrolled) HLO.

    Uses the op RESULT shape (for all-gather that's the gathered size; for
    reduce-scatter the scattered size; a consistent, conservative proxy for
    bytes moved per chip).  -done ops are skipped so async start/done pairs
    count once."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        shapes = _SHAPE_RE.findall(m.group(1))
        if not shapes:
            continue
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] = out.get(kind, 0.0) + float(nbytes)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # whole-step, per chip (HLO-level)
    hbm_bytes: float             # whole-step, per chip
    coll_bytes: float            # whole-step, per chip
    chips: int
    model_flops: float = 0.0     # 6·N·D convention, global

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): how much compiled compute is
        'useful' (catches remat/redundancy waste)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU implied by the roofline terms: useful flops
        per chip-second at the bound time."""
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops / self.chips / self.t_bound) / PEAK_FLOPS

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "chips": self.chips,
        }


def cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return dict(ca)


def extrapolate(probe1: Dict[str, float], probe2: Dict[str, float],
                t1: int, t2: int, t_real: int) -> Dict[str, float]:
    """Two-point linear extrapolation in tick count (exact when cost is
    affine in ticks, which it is by construction of the schedule)."""
    out = {}
    keys = set(probe1) | set(probe2)
    for k in keys:
        a, b = probe1.get(k, 0.0), probe2.get(k, 0.0)
        per_tick = (b - a) / max(1, (t2 - t1))
        out[k] = a + per_tick * (t_real - t1)
    return out
