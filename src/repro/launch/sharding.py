"""Sharding rules: map every train/serve-step input to a NamedSharding.

Policy (DESIGN.md §4):
  * stage buffers [S, L_max, ...]   → P("model", None, …, "data"@FSDP-dim)
    (FSDP within a pod; replicated across pods — grads psum over pod)
  * embed [V, d]                    → vocab over "data"
  * head  [d, V]                    → vocab over "data"
  * shared/small                    → replicated (dec_pos sharded on dim 0)
  * batch [m, B, …]                 → B over all DP axes ("pod","data")
  * cache [S, L_max, m, B, …]       → stage over "model", then the largest
    remaining dim divisible by the data size over "data" (batch if possible,
    else kv-heads / cache-capacity — XLA auto-partitions the decode softmax
    over a seq-sharded cache exactly)
  * optimizer moments mirror their parameter's spec (adafactor's factored
    vr/vc drop the corresponding dim from the spec)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes, dp_degree


def _fsdp_dim(shape: Tuple[int, ...], start: int, size: int
              ) -> Optional[int]:
    """Largest dim index ≥ start whose size is divisible by ``size``."""
    best, best_sz = None, 0
    for i in range(start, len(shape)):
        if shape[i] % size == 0 and shape[i] >= size and shape[i] > best_sz:
            best, best_sz = i, shape[i]
    return best


def stage_param_spec(shape: Tuple[int, ...], mesh, fsdp: bool = True) -> P:
    entries = ["model"] + [None] * (len(shape) - 1)
    if fsdp and len(shape) > 2:
        d = _fsdp_dim(shape, 2, mesh.shape["data"])
        if d is not None:
            entries[d] = "data"
    return P(*entries)


def param_shardings(cfg, dcfg, mesh, param_tree_spec: Dict[str, Any]):
    """NamedSharding tree matching model.param_spec(cfg, dcfg)."""
    dsize = mesh.shape["data"]

    def embed_spec(shape):
        return P("data", None) if shape[0] % dsize == 0 else P(None, None)

    def head_spec(shape):
        return P(None, "data") if shape[1] % dsize == 0 else P(None, None)

    out: Dict[str, Any] = {}
    for k, v in param_tree_spec.items():
        if k == "stages":
            out[k] = {f: NamedSharding(
                mesh, stage_param_spec(s.shape, mesh, dcfg.fsdp))
                for f, s in v.items()}
        elif k == "embed":
            out[k] = NamedSharding(mesh, embed_spec(v.shape))
        elif k == "head":
            out[k] = NamedSharding(mesh, head_spec(v.shape))
        elif k == "shared":
            out[k] = {}
            for f, s in v.items():
                if f == "dec_pos" and s.shape[0] % dsize == 0:
                    out[k][f] = NamedSharding(mesh, P("data", None))
                else:
                    out[k][f] = NamedSharding(
                        mesh, P(*([None] * len(s.shape))))
        else:
            out[k] = NamedSharding(mesh, P(*([None] * len(v.shape))))
    return out


def opt_shardings(opt_template, p_shardings, mesh):
    """Mirror each moment to its parameter's spec; factored adafactor moments
    drop the factored dim.  Identified by path: .../m, /v, /vr, /vc."""
    def find_pspec(path) -> Optional[P]:
        node = p_shardings
        for p in path:
            key = getattr(p, "key", None)
            if key is None:
                return None
            if isinstance(node, dict) and key in node:
                node = node[key]
            elif key in ("m", "v", "vr", "vc", "f"):
                continue
            else:
                return None
        return node.spec if isinstance(node, NamedSharding) else None

    def one(path, leaf):
        keys = [getattr(p, "key", "") for p in path]
        pspec = find_pspec(path)
        if pspec is None:
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        entries = list(pspec) + [None] * (leaf.ndim - len(list(pspec)))
        last = keys[-1] if keys else ""
        if last == "vr":           # p.shape[:-1]
            entries = list(pspec)[:-1]
        elif last == "vc":         # p.shape[:-2] + p.shape[-1:]
            sp = list(pspec)
            entries = sp[:-2] + sp[-1:]
        entries = (entries + [None] * leaf.ndim)[:leaf.ndim]
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(one, opt_template)


def batch_shardings(batch_spec: Dict[str, Any], mesh):
    daxes = data_axes(mesh)
    dp = dp_degree(mesh)

    def one(s):
        entries = [None] * len(s.shape)
        if len(s.shape) >= 2 and s.shape[1] % dp == 0:
            entries[1] = daxes if len(daxes) > 1 else daxes[0]
        return NamedSharding(mesh, P(*entries))

    return {k: one(v) for k, v in batch_spec.items()}


def cache_shardings(cache_spec: Dict[str, Any], mesh):
    dsize = mesh.shape["data"]

    def one(s):
        entries = ["model"] + [None] * (len(s.shape) - 1)
        # prefer batch dim (3), else largest divisible dim ≥ 3
        if len(s.shape) > 3 and s.shape[3] % dsize == 0:
            entries[3] = "data"
        else:
            d = _fsdp_dim(s.shape, 3, dsize)
            if d is not None:
                entries[d] = "data"
        return NamedSharding(mesh, P(*entries))

    return {k: one(v) for k, v in cache_spec.items()}


def stage_tree_shardings(tree_spec: Dict[str, Any], mesh):
    """Assignment / dyn arrays: [S, ...] over model."""
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, P(*(["model"] + [None] * (len(s.shape) - 1)))), tree_spec)


def replicated(mesh):
    return NamedSharding(mesh, P())


def attach(sds_tree, shardings_tree):
    """Attach shardings to ShapeDtypeStructs (for AOT .lower)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, shardings_tree)
