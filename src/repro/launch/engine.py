"""Elastic training engine (paper §3.4, Alg. 2 — live consolidation).

``ElasticEngine`` owns the per-stage-count *execution world* — the mesh over
a device subset, the pipeline shapes, the jitted train step, and the
optimizer init — built lazily and cached per active stage count.  A repack
decision from the controller triggers a **live shrink** in the same process:

  1. stage-keyed state is flattened to global layer order and re-split for
     the smaller stage count (one device-side gather per leaf — the weights
     never round-trip through host memory);
  2. the result is placed onto a ``model``-axis submesh over the surviving
     device subset (released devices hold no state afterwards);
  3. the cached (or freshly compiled) smaller world continues training.

The GPipe schedule pays ``num_micro + S - 1`` ticks, so shrinking S is a
real throughput win at equal tokens — packed-empty *shadow* stages (the old
in-mesh repack path) kept paying the full tick count.  The symmetric grow
path re-expands when the ``WorkerPool`` grants recovered workers back.

The checkpoint-coordinated path (repro.checkpoint.elastic + restart) remains
the fallback for multi-node jobs where the job manager must actually
reschedule processes (§3.4.2); see DESIGN.md §Elastic runtime.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.cluster.rpc import InProcessJobManager, JobManagerClient
from repro.configs.base import DistConfig, ModelConfig
from repro.dynamics.config import DynamicsConfig
from repro.launch.mesh import make_submesh
from repro.models import model as M
from repro.optim.optimizers import OptConfig, make_optimizer
from repro.pipeline.pipeline import PipelineShapes, build_loss_fn
from repro.runtime.fault_tolerance import WorkerPool


def make_train_step(cfg: ModelConfig, dcfg: DistConfig,
                    dyncfg: DynamicsConfig, mesh, shapes: PipelineShapes,
                    opt_cfg: Optional[OptConfig] = None):
    """Returns (init_opt_fn, train_step) with
    train_step(params, opt_state, assignment, dyn, batch, lr)
      -> (params, opt_state, loss, stats, gnorm)."""
    opt_cfg = opt_cfg or OptConfig(name=dcfg.optimizer)
    loss_fn = build_loss_fn(cfg, dcfg, dyncfg, mesh, shapes)
    init_fn, update_fn = make_optimizer(opt_cfg)

    def train_step(params, opt_state, assignment, dyn, batch, lr):
        (loss, stats), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, assignment, dyn, batch)
        params, opt_state, gnorm = update_fn(
            grads, opt_state, params, lr, frozen=dyn.get("frozen"))
        return params, opt_state, loss, stats, gnorm

    return init_fn, train_step


def fold_stats(stats, num_stages: int):
    """Materialize the per-slot stats tree on host and restore the
    [S, L_max, ...] layout the profiler expects — shard_map's stacked
    out_spec flattens the stage axis into the slot axis ([S·L_max, ...]).
    This is a full device→host sync of the stats tree: call it on
    controller cadence only, never per step (§3.3.1)."""
    import numpy as np

    def fold(a):
        a = np.asarray(a)
        return a.reshape((num_stages, a.shape[0] // num_stages)
                         + a.shape[1:])

    return jax.tree.map(fold, stats)


@dataclasses.dataclass
class EngineWorld:
    """Everything tied to one active stage count: compiled once, cached."""
    stages: int
    dcfg: DistConfig
    mesh: Any
    init_opt: Any
    step: Any                  # jitted, donating (params, opt_state)
    eval_loss: Any = None      # lazily-jitted loss-only fn (no update)


@dataclasses.dataclass
class EngineState:
    """The training state the engine threads through worlds."""
    params: Any
    opt_state: Any
    dyn: Any
    assignment: Any
    lps: List[int]
    stages: int


@dataclasses.dataclass
class ResizeEvent:
    step: int
    kind: str                  # shrink | grow | evict
    from_stages: int
    to_stages: int
    workers: List[int]         # released (shrink) or granted (grow) ids
    seconds: float
    ticks_before: int
    ticks_after: int


class ElasticEngine:
    """Owns the per-stage-count execution worlds and the live resize paths.

    ``data`` × ``stages`` devices are taken from the front of ``devices``
    (process-global by default); stage s maps to worker column s.  Shrinking
    keeps the first ``data*S_new`` devices and releases the tail to the
    ``WorkerPool``; growing requests them back.
    """

    def __init__(self, cfg: ModelConfig, dcfg: DistConfig,
                 dyncfg: DynamicsConfig, shapes: PipelineShapes, *,
                 opt_cfg: Optional[OptConfig] = None, data: int = 1,
                 devices: Optional[Sequence[Any]] = None,
                 pool: Optional[WorkerPool] = None,
                 job_manager: Optional[JobManagerClient] = None):
        self.cfg, self.base_dcfg, self.dyncfg = cfg, dcfg, dyncfg
        self.shapes = shapes
        self.opt_cfg = opt_cfg
        self.data = data
        self.devices = (list(devices) if devices is not None
                        else list(jax.devices()))
        if job_manager is None:
            # in-process default: same WorkerPool semantics as always
            self.pool: Optional[WorkerPool] = pool or WorkerPool(
                dcfg.num_stages)
            self.jm: JobManagerClient = InProcessJobManager(self.pool)
        else:
            # the real pool lives behind the RPC boundary (its process owns
            # it); release/grant cross it via the client
            self.jm = job_manager
            self.pool = pool
        self.stage_workers: List[int] = list(range(dcfg.num_stages))
        self._worlds: Dict[int, EngineWorld] = {}
        self.resizes: List[ResizeEvent] = []
        self.last_shrink_step: Optional[int] = None
        # world epoch: bumped by every resize; the control plane fences
        # decision plans with it so a plan decided against a stale world
        # (wrong stage count / layer split) is never applied
        self.epoch = 0
        # mirror every pool transition (including ones other engines or the
        # heartbeat path trigger on a shared pool) into an engine-local log
        self.pool_events: List[str] = []
        self._pool_hook = lambda event, worker: self.pool_events.append(
            f"{event}:{worker}")
        if self.pool is not None:
            self.pool.subscribe(self._pool_hook)

    def close(self) -> None:
        """Detach from a (possibly shared) pool; a discarded engine must not
        be pinned alive by the pool's hook list."""
        if self.pool is not None:
            self.pool.unsubscribe(self._pool_hook)

    # -- worlds ------------------------------------------------------------
    def dcfg_for(self, stages: int) -> DistConfig:
        return dataclasses.replace(self.base_dcfg, num_stages=stages)

    def ticks(self, stages: int) -> int:
        return self.shapes.num_micro + stages - 1

    def world(self, stages: int) -> EngineWorld:
        w = self._worlds.get(stages)
        if w is None:
            dcfg = self.dcfg_for(stages)
            mesh = make_submesh(self.data, stages, devices=self.devices)
            init_opt, step_fn = make_train_step(
                self.cfg, dcfg, self.dyncfg, mesh, self.shapes, self.opt_cfg)
            w = EngineWorld(stages=stages, dcfg=dcfg, mesh=mesh,
                            init_opt=init_opt,
                            step=jax.jit(step_fn, donate_argnums=(0, 1)))
            self._worlds[stages] = w
        return w

    # -- placement ---------------------------------------------------------
    def _place(self, world: EngineWorld, params, opt_state, dyn, assignment):
        """device_put onto the world's submesh with the pipeline's layout:
        stage-keyed leaves sharded over ``model`` (leading stage dim),
        everything else replicated — matches the shard_map in_specs, so the
        jitted step needs no input reshard."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        stage_sh = NamedSharding(world.mesh, P("model"))
        repl_sh = NamedSharding(world.mesh, P())
        put_st = lambda t: jax.tree.map(
            lambda a: jax.device_put(a, stage_sh), t)
        put_rp = lambda t: jax.tree.map(
            lambda a: jax.device_put(a, repl_sh), t)
        params = {k: (put_st(v) if k == "stages" else put_rp(v))
                  for k, v in params.items()}

        def walk_opt(node):
            if isinstance(node, dict):
                return {k: (put_st(v) if k == "stages" else walk_opt(v))
                        for k, v in node.items()}
            return jax.device_put(node, repl_sh)

        opt_state = walk_opt(opt_state) if opt_state is not None else None
        return params, opt_state, put_st(dyn), put_st(assignment)

    # -- lifecycle ---------------------------------------------------------
    def init_state(self, rng: jax.Array) -> EngineState:
        stages = self.base_dcfg.num_stages
        world = self.world(stages)
        params = M.init_params(rng, self.cfg, world.dcfg)
        assignment = M.make_assignment(self.cfg, world.dcfg)
        dyn = M.init_dyn(self.cfg, world.dcfg, self.dyncfg)
        opt_state = world.init_opt(params)
        lps = M.uniform_boundaries(self.cfg.total_blocks(), stages)
        params, opt_state, dyn, assignment = self._place(
            world, params, opt_state, dyn, assignment)
        return EngineState(params, opt_state, dyn, assignment, lps, stages)

    def step(self, state: EngineState, batch, lr):
        """One jitted train step in the state's current world; mutates
        ``state.params``/``state.opt_state`` in place, returns
        (loss, stats, gnorm) — stats stay on device (the caller decides when
        to pay the host sync)."""
        w = self.world(state.stages)
        with w.mesh:
            params, opt_state, loss, stats, gnorm = w.step(
                state.params, state.opt_state, state.assignment, state.dyn,
                batch, lr)
        state.params, state.opt_state = params, opt_state
        return loss, stats, gnorm

    @staticmethod
    def stats_to_host(state: EngineState, stats):
        """`fold_stats` for the state's current stage count."""
        return fold_stats(stats, len(state.lps))

    def eval_loss(self, state: EngineState, batch):
        """Loss-only evaluation (no optimizer update) in the current world —
        used by the resize parity checks and the demo."""
        w = self.world(state.stages)
        if w.eval_loss is None:
            w.eval_loss = jax.jit(build_loss_fn(
                self.cfg, w.dcfg, self.dyncfg, w.mesh, self.shapes))
        with w.mesh:
            loss, _ = w.eval_loss(state.params, state.assignment, state.dyn,
                                  batch)
        return loss

    # -- live resize -------------------------------------------------------
    def resize(self, state: EngineState, new_stages: int,
               new_lps: Optional[Sequence[int]] = None) -> EngineState:
        """Reshape all stage-keyed state to ``new_stages`` and place it onto
        that world's submesh — no checkpoint, no restart, no host round-trip.
        Falls back to a uniform split when ``new_lps`` violates the target
        world's slot capacity."""
        from repro.checkpoint.elastic import elastic_restore
        world = self.world(new_stages)
        if new_lps is not None and (
                len(new_lps) != new_stages
                or max(new_lps) > world.dcfg.slots_for(self.cfg)):
            new_lps = None
        params, opt_state, dyn, assignment, lps = elastic_restore(
            self.cfg, self.dcfg_for(state.stages), world.dcfg,
            state.params, state.opt_state, state.dyn, state.lps, new_lps)
        params, opt_state, dyn, assignment = self._place(
            world, params, opt_state, dyn, assignment)
        self.epoch += 1
        return EngineState(params, opt_state, dyn, assignment, lps,
                           new_stages)

    def shrink(self, state: EngineState, target_stages: int,
               new_lps: Optional[Sequence[int]] = None,
               step: int = -1) -> EngineState:
        """Live consolidation: rebuild on fewer workers, release the tail of
        the stage→worker map back to the job manager."""
        assert target_stages < state.stages
        t0 = time.perf_counter()
        new_state = self.resize(state, target_stages, new_lps)
        released = self.stage_workers[target_stages:]
        self.stage_workers = self.stage_workers[:target_stages]
        self.jm.release(released)
        self.resizes.append(ResizeEvent(
            step=step, kind="shrink", from_stages=state.stages,
            to_stages=target_stages, workers=list(released),
            seconds=time.perf_counter() - t0,
            ticks_before=self.ticks(state.stages),
            ticks_after=self.ticks(target_stages)))
        self.last_shrink_step = step
        return new_state

    def evict(self, state: EngineState, workers: Sequence[int],
              step: int = -1) -> EngineState:
        """Failure path: rebuild the pipeline WITHOUT ``workers`` (dead —
        reported to the job manager as failed, not released; they are not
        grantable until the manager revives them).  Unlike ``shrink`` the
        lost workers may sit anywhere in the stage→worker map."""
        lost = [w for w in workers if w in self.stage_workers]
        if not lost:
            return state
        target = len(self.stage_workers) - len(lost)
        assert target >= 1, "cannot evict every worker"
        t0 = time.perf_counter()
        new_state = self.resize(state, target)
        self.stage_workers = [w for w in self.stage_workers
                              if w not in set(lost)]
        for w in lost:
            self.jm.fail(w)
        self.resizes.append(ResizeEvent(
            step=step, kind="evict", from_stages=state.stages,
            to_stages=target, workers=list(lost),
            seconds=time.perf_counter() - t0,
            ticks_before=self.ticks(state.stages),
            ticks_after=self.ticks(target)))
        self.last_shrink_step = step
        return new_state

    def grow(self, state: EngineState, n_workers: int,
             step: int = -1) -> EngineState:
        """Re-expansion: request workers back from the pool and rebuild the
        pipeline over the larger device subset.  Grows by however many the
        pool actually grants (possibly zero)."""
        t0 = time.perf_counter()
        granted = self.jm.request(n_workers)
        if not granted:
            return state
        target = state.stages + len(granted)
        new_state = self.resize(state, target)
        self.stage_workers = self.stage_workers + granted
        self.resizes.append(ResizeEvent(
            step=step, kind="grow", from_stages=state.stages,
            to_stages=target, workers=list(granted),
            seconds=time.perf_counter() - t0,
            ticks_before=self.ticks(state.stages),
            ticks_after=self.ticks(target)))
        return new_state
