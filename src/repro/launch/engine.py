"""Elastic training engine (paper §3.4, Alg. 2 — live consolidation).

``ElasticEngine`` owns the per-stage-count *execution world* — the mesh over
a device subset, the pipeline shapes, the jitted train step, and the
optimizer init — built lazily and cached per active stage count.  A repack
decision from the controller triggers a **live shrink** in the same process:

  1. stage-keyed state is flattened to global layer order and re-split for
     the smaller stage count (one device-side gather per leaf — the weights
     never round-trip through host memory);
  2. the result is placed onto a ``model``-axis submesh over the surviving
     device subset (released devices hold no state afterwards);
  3. the cached (or freshly compiled) smaller world continues training.

The GPipe schedule pays ``num_micro + S - 1`` ticks, so shrinking S is a
real throughput win at equal tokens — packed-empty *shadow* stages (the old
in-mesh repack path) kept paying the full tick count.  The symmetric grow
path re-expands when the ``WorkerPool`` grants recovered workers back.

The checkpoint-coordinated path (repro.checkpoint.elastic + restart) remains
the fallback for multi-node jobs where the job manager must actually
reschedule processes (§3.4.2); see DESIGN.md §Elastic runtime.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.cluster.rpc import (InProcessJobManager, JobManagerClient,
                               JobManagerUnavailable)
from repro.configs.base import DistConfig, ModelConfig
from repro.dynamics.config import DynamicsConfig
from repro.launch.mesh import make_submesh
from repro.models import model as M
from repro.optim.optimizers import OptConfig, make_optimizer
from repro.pipeline.pipeline import (PipelineShapes, build_decode_fn,
                                     build_loss_fn, build_prefill_fn)
from repro.runtime.fault_tolerance import WorkerPool


@jax.jit
def _pack_pages(pool, scratch_k, scratch_v, table, mask):
    """Scatter prompt pages from a dense prefill scratch into the pool.

    pool: {kp, vp: [S, L, pool+1, page, kv, hd]}; scratch_k/v:
    [S, L, m, B, cap, kv, hd] with cap == J * page; table/mask: [m, B, J].
    Unmasked or unmapped (-1) entries are steered at the trash block.
    """
    kp, vp = pool["kp"], pool["vp"]
    page = kp.shape[3]
    trash = kp.shape[2] - 1
    m, b, j = table.shape
    blk = jnp.where(mask & (table >= 0), table, trash).reshape(m * b * j)

    def pages(sc):
        s_, l_, m_, b_, cap, kv, hd = sc.shape
        return sc.reshape(s_, l_, m_ * b_ * (cap // page), page, kv, hd)

    return {"kp": kp.at[:, :, blk].set(pages(scratch_k).astype(kp.dtype)),
            "vp": vp.at[:, :, blk].set(pages(scratch_v).astype(vp.dtype))}


@jax.jit
def _copy_block(pool, src, dst):
    """Duplicate one physical block (CoW fork) in every stage-slot pool."""
    return {k: v.at[:, :, dst].set(v[:, :, src]) for k, v in pool.items()}


def make_train_step(cfg: ModelConfig, dcfg: DistConfig,
                    dyncfg: DynamicsConfig, mesh, shapes: PipelineShapes,
                    opt_cfg: Optional[OptConfig] = None, stage_timer=None):
    """Returns (init_opt_fn, train_step) with
    train_step(params, opt_state, assignment, dyn, batch, lr)
      -> (params, opt_state, loss, stats, gnorm).
    ``stage_timer`` threads an ``obs.timing.StageTimer`` into the pipelined
    loss (in-step stage timing, DESIGN.md §15)."""
    opt_cfg = opt_cfg or OptConfig(name=dcfg.optimizer)
    loss_fn = build_loss_fn(cfg, dcfg, dyncfg, mesh, shapes,
                            stage_timer=stage_timer)
    init_fn, update_fn = make_optimizer(opt_cfg)

    def train_step(params, opt_state, assignment, dyn, batch, lr):
        (loss, stats), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, assignment, dyn, batch)
        params, opt_state, gnorm = update_fn(
            grads, opt_state, params, lr, frozen=dyn.get("frozen"))
        return params, opt_state, loss, stats, gnorm

    return init_fn, train_step


def fold_stats(stats, num_stages: int):
    """Materialize the per-slot stats tree on host and restore the
    [S, L_max, ...] layout the profiler expects — shard_map's stacked
    out_spec flattens the stage axis into the slot axis ([S·L_max, ...]).
    This is a full device→host sync of the stats tree: call it on
    controller cadence only, never per step (§3.3.1)."""
    import numpy as np

    def fold(a):
        a = np.asarray(a)
        return a.reshape((num_stages, a.shape[0] // num_stages)
                         + a.shape[1:])

    return jax.tree.map(fold, stats)


@dataclasses.dataclass
class EngineWorld:
    """Everything tied to one active stage count: compiled once, cached.

    The serving path shares the cache: ``prefill``/``decode`` are built
    lazily per world next to the train step, so an elastic server reuses
    the same submesh/epoch/job-manager machinery as the trainer."""
    stages: int
    dcfg: DistConfig
    mesh: Any
    init_opt: Any
    step: Any                  # jitted, donating (params, opt_state)
    eval_loss: Any = None      # lazily-jitted loss-only fn (no update)
    prefill: Any = None        # lazily-jitted serving prefill
    decode: Any = None         # {live_micros: jitted decode} (donates cache)
    stage_probe: Any = None    # lazily-jitted single-stage forward (timers)
    timer: Any = None          # obs.timing.StageTimer (in-step timing on)
    stepped: bool = False      # first step() on this world pays compile


@dataclasses.dataclass
class EngineState:
    """The training/serving state the engine threads through worlds.
    ``cache`` is the stacked decode KV cache ([S, L_max, ...] leaves) when
    the engine serves; it re-splits with the rest on every resize."""
    params: Any
    opt_state: Any
    dyn: Any
    assignment: Any
    lps: List[int]
    stages: int
    cache: Any = None


@dataclasses.dataclass
class ResizeEvent:
    step: int
    kind: str                  # shrink | grow | evict
    from_stages: int
    to_stages: int
    workers: List[int]         # released (shrink) or granted (grow) ids
    seconds: float
    ticks_before: int
    ticks_after: int


class ElasticEngine:
    """Owns the per-stage-count execution worlds and the live resize paths.

    ``data`` × ``stages`` devices are taken from the front of ``devices``
    (process-global by default); stage s maps to worker column s.  Shrinking
    keeps the first ``data*S_new`` devices and releases the tail to the
    ``WorkerPool``; growing requests them back.
    """

    def __init__(self, cfg: ModelConfig, dcfg: DistConfig,
                 dyncfg: DynamicsConfig, shapes: PipelineShapes, *,
                 opt_cfg: Optional[OptConfig] = None, data: int = 1,
                 devices: Optional[Sequence[Any]] = None,
                 pool: Optional[WorkerPool] = None,
                 job_manager: Optional[JobManagerClient] = None,
                 in_step_timing: bool = False,
                 paged=None, temperature: float = 0.0):
        self.cfg, self.base_dcfg, self.dyncfg = cfg, dcfg, dyncfg
        self.shapes = shapes
        self.opt_cfg = opt_cfg
        self.data = data
        self.in_step_timing = in_step_timing
        # serving options: ``paged`` is a PagedKVConfig (block-paged KV pool
        # instead of per-lane contiguous lines); ``temperature`` > 0 builds
        # sampling decode variants (0 keeps the argmax graph bit-exact)
        self.paged = paged
        self.temperature = float(temperature)
        self.last_step_compiled = False
        self.last_moe_drop = None   # serve telemetry (see _note_moe_drop)
        self.devices = (list(devices) if devices is not None
                        else list(jax.devices()))
        if job_manager is None:
            # in-process default: same WorkerPool semantics as always
            self.pool: Optional[WorkerPool] = pool or WorkerPool(
                dcfg.num_stages)
            self.jm: JobManagerClient = InProcessJobManager(self.pool)
        else:
            # the real pool lives behind the RPC boundary (its process owns
            # it); release/grant cross it via the client
            self.jm = job_manager
            self.pool = pool
        self.stage_workers: List[int] = list(range(dcfg.num_stages))
        # worker id -> device column (a list of ``data`` devices).  Bound
        # positionally at init; a worker GRANTED later under a never-seen
        # id (the job manager provisioned a fresh process, not a revival)
        # is bound to a free column on arrival — device discovery survives
        # process-set changes instead of assuming id == device index.
        S0 = dcfg.num_stages
        assert len(self.devices) >= data * S0, (
            f"need {data * S0} devices, have {len(self.devices)}")
        self._columns: List[List[Any]] = [
            [self.devices[d * S0 + s] for d in range(data)]
            for s in range(S0)]
        self.worker_column: Dict[int, int] = {w: w for w in range(S0)}
        self._worlds: Dict[Any, EngineWorld] = {}
        # ops the job manager must eventually hear about, queued while it
        # is unreachable (degraded mode: training continues, bookkeeping
        # catches up when the manager comes back)
        self._pending_jm: List[Any] = []
        self.degraded_events: List[str] = []
        self.resizes: List[ResizeEvent] = []
        self.last_shrink_step: Optional[int] = None
        # world epoch: bumped by every resize; the control plane fences
        # decision plans with it so a plan decided against a stale world
        # (wrong stage count / layer split) is never applied
        self.epoch = 0
        # mirror every pool transition (including ones other engines or the
        # heartbeat path trigger on a shared pool) into an engine-local log
        self.pool_events: List[str] = []
        self._pool_hook = lambda event, worker: self.pool_events.append(
            f"{event}:{worker}")
        if self.pool is not None:
            self.pool.subscribe(self._pool_hook)

    def close(self) -> None:
        """Detach from a (possibly shared) pool; a discarded engine must not
        be pinned alive by the pool's hook list."""
        if self.pool is not None:
            self.pool.unsubscribe(self._pool_hook)

    # -- worlds ------------------------------------------------------------
    def dcfg_for(self, stages: int) -> DistConfig:
        return dataclasses.replace(self.base_dcfg, num_stages=stages)

    def ticks(self, stages: int) -> int:
        return self.shapes.num_micro + stages - 1

    def _devices_for(self, workers: Sequence[int]) -> List[Any]:
        """Flat (data-major) device list for a worker list: stage s runs on
        worker ``workers[s]``'s bound column."""
        cols = [self._columns[self.worker_column[w]] for w in workers]
        return [cols[s][d] for d in range(self.data)
                for s in range(len(workers))]

    def _bind_new_workers(self, granted: Sequence[int]
                          ) -> tuple:
        """Bind device columns for granted workers.  Known ids keep their
        binding; NEVER-seen ids (the manager provisioned a fresh process)
        get a free column.  Returns (accepted, rejected) — a grant with no
        free hardware column behind it cannot be executed and must go back
        to the manager."""
        used = {self.worker_column[w] for w in self.stage_workers
                if w in self.worker_column}
        accepted: List[int] = []
        rejected: List[int] = []
        for w in granted:
            col = self.worker_column.get(w)
            if col is not None and col not in used:
                used.add(col)
                accepted.append(w)
                continue
            # unknown id — or a stale binding whose column was re-assigned
            # while this worker was away: (re-)bind to a free column
            free = [c for c in range(len(self._columns)) if c not in used]
            if not free:
                rejected.append(w)
                continue
            self.worker_column[w] = free[0]
            used.add(free[0])
            accepted.append(w)
        return accepted, rejected

    def bind_workers(self, workers: Sequence[int]) -> None:
        """Adopt a restored stage→worker map (checkpoint resume): workers
        are bound to columns positionally, replacing the init bindings."""
        assert len(workers) <= len(self._columns)
        self.stage_workers = list(workers)
        for s, w in enumerate(self.stage_workers):
            self.worker_column[w] = s

    def world(self, stages: int,
              workers: Optional[Sequence[int]] = None) -> EngineWorld:
        if workers is None:
            workers = self.stage_workers[:stages]
        assert len(workers) == stages, (workers, stages)
        devs = self._devices_for(workers)
        key = (stages, tuple(d.id for d in devs))
        w = self._worlds.get(key)
        if w is None:
            dcfg = self.dcfg_for(stages)
            mesh = make_submesh(self.data, stages, devices=devs)
            timer = None
            if self.in_step_timing:
                from repro.obs.timing import StageTimer
                timer = StageTimer(stages)
            init_opt, step_fn = make_train_step(
                self.cfg, dcfg, self.dyncfg, mesh, self.shapes, self.opt_cfg,
                stage_timer=timer)
            w = EngineWorld(stages=stages, dcfg=dcfg, mesh=mesh,
                            init_opt=init_opt,
                            step=jax.jit(step_fn, donate_argnums=(0, 1)),
                            timer=timer)
            self._worlds[key] = w
        return w

    # -- degraded-mode job-manager calls (DESIGN.md §12) -------------------
    def _flush_pending_jm(self) -> bool:
        """Replay queued release/fail bookkeeping in order; True when the
        queue drained (manager reachable again)."""
        while self._pending_jm:
            kind, arg = self._pending_jm[0]
            try:
                if kind == "release":
                    self.jm.release(arg)
                else:
                    self.jm.fail(arg)
            except JobManagerUnavailable:
                return False
            self._pending_jm.pop(0)
            self.degraded_events.append(f"replayed {kind}:{arg}")
        return True

    def _jm_release(self, workers: Sequence[int]) -> None:
        workers = list(workers)
        if self._flush_pending_jm():
            try:
                self.jm.release(workers)
                return
            except JobManagerUnavailable:
                pass
        self._pending_jm.append(("release", workers))
        self.degraded_events.append(f"release deferred: {workers}")

    def _jm_fail(self, worker: int) -> None:
        if self._flush_pending_jm():
            try:
                self.jm.fail(worker)
                return
            except JobManagerUnavailable:
                pass
        self._pending_jm.append(("fail", worker))
        self.degraded_events.append(f"fail deferred: {worker}")

    # -- placement ---------------------------------------------------------
    def _place(self, world: EngineWorld, params, opt_state, dyn, assignment,
               cache=None):
        """device_put onto the world's submesh with the pipeline's layout:
        stage-keyed leaves sharded over ``model`` (leading stage dim),
        everything else replicated — matches the shard_map in_specs, so the
        jitted step needs no input reshard."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        stage_sh = NamedSharding(world.mesh, P("model"))
        repl_sh = NamedSharding(world.mesh, P())
        put_st = lambda t: jax.tree.map(
            lambda a: jax.device_put(a, stage_sh), t)
        put_rp = lambda t: jax.tree.map(
            lambda a: jax.device_put(a, repl_sh), t)
        params = {k: (put_st(v) if k == "stages" else put_rp(v))
                  for k, v in params.items()}

        def walk_opt(node):
            if isinstance(node, dict):
                return {k: (put_st(v) if k == "stages" else walk_opt(v))
                        for k, v in node.items()}
            return jax.device_put(node, repl_sh)

        opt_state = walk_opt(opt_state) if opt_state is not None else None
        cache = put_st(cache) if cache is not None else None
        return params, opt_state, put_st(dyn), put_st(assignment), cache

    # -- lifecycle ---------------------------------------------------------
    def init_state(self, rng: jax.Array, *, with_opt: bool = True,
                   with_cache: bool = False, stages: Optional[int] = None,
                   lps: Optional[Sequence[int]] = None) -> EngineState:
        """``with_opt=False`` skips the optimizer (serving: no moments);
        ``with_cache=True`` allocates the stacked decode KV cache from the
        engine's shapes (requires ``shapes.cache_len > 0``).  ``stages`` /
        ``lps`` override the base world — the checkpoint-resume path builds
        templates at the stage count the run died at, not at the spec's
        maximum.  When ``stages`` is given the caller must have bound the
        matching workers first (``bind_workers``)."""
        stages = stages if stages is not None else self.base_dcfg.num_stages
        world = self.world(stages)
        params = M.init_params(rng, self.cfg, world.dcfg)
        lps = (list(lps) if lps is not None
               else M.uniform_boundaries(self.cfg.total_blocks(), stages))
        assignment = M.make_assignment(self.cfg, world.dcfg, lps)
        dyn = M.init_dyn(self.cfg, world.dcfg, self.dyncfg)
        opt_state = world.init_opt(params) if with_opt else None
        cache = None
        if with_cache:
            assert self.shapes.cache_len > 0, "shapes.cache_len required"
            if self.paged is not None:
                cache = M.init_paged_cache(self.cfg, world.dcfg,
                                           self.paged.pool_pages,
                                           self.paged.page_size)
            else:
                cache = M.init_cache(self.cfg, world.dcfg,
                                     self.shapes.num_micro,
                                     self.shapes.mb_global,
                                     self.shapes.cache_len)
        params, opt_state, dyn, assignment, cache = self._place(
            world, params, opt_state, dyn, assignment, cache)
        return EngineState(params, opt_state, dyn, assignment, lps, stages,
                           cache)

    def step(self, state: EngineState, batch, lr):
        """One jitted train step in the state's current world; mutates
        ``state.params``/``state.opt_state`` in place, returns
        (loss, stats, gnorm) — stats stay on device (the caller decides when
        to pay the host sync)."""
        w = self.world(state.stages)
        self.last_step_compiled = not w.stepped
        w.stepped = True
        with w.mesh:
            params, opt_state, loss, stats, gnorm = w.step(
                state.params, state.opt_state, state.assignment, state.dyn,
                batch, lr)
        state.params, state.opt_state = params, opt_state
        return loss, stats, gnorm

    @staticmethod
    def stats_to_host(state: EngineState, stats):
        """`fold_stats` for the state's current stage count."""
        return fold_stats(stats, len(state.lps))

    def eval_loss(self, state: EngineState, batch):
        """Loss-only evaluation (no optimizer update) in the current world —
        used by the resize parity checks and the demo."""
        w = self.world(state.stages)
        if w.eval_loss is None:
            w.eval_loss = jax.jit(build_loss_fn(
                self.cfg, w.dcfg, self.dyncfg, w.mesh, self.shapes))
        with w.mesh:
            loss, _ = w.eval_loss(state.params, state.assignment, state.dyn,
                                  batch)
        return loss

    # -- serving -----------------------------------------------------------
    def serve_fns(self, stages: int, live_micros: Optional[int] = None):
        """(prefill, decode) for the given stage count, built lazily on the
        world next to its train step — the elastic server's resize path gets
        compiled serving fns per world exactly like the trainer does.
        ``decode`` donates the cache argument (arg 3).

        Decode variants are cached per live microbatch count: a variant
        compiled for ``live_micros < num_micro`` runs ``live + S - 1`` ticks
        instead of ``num_micro + S - 1``, so all-empty trailing microbatch
        rows cost nothing (inputs keep their full shapes)."""
        w = self.world(stages)
        mv = self.shapes.num_micro if live_micros is None else live_micros
        if w.prefill is None:
            w.prefill = jax.jit(build_prefill_fn(
                self.cfg, w.dcfg, self.dyncfg, w.mesh, self.shapes,
                stage_timer=w.timer))
            w.decode = {}
        if mv not in w.decode:
            w.decode[mv] = jax.jit(build_decode_fn(
                self.cfg, w.dcfg, self.dyncfg, w.mesh, self.shapes,
                stage_timer=w.timer, paged=self.paged is not None,
                temperature=self.temperature, num_micro=mv),
                donate_argnums=(3,))
        return w.prefill, w.decode[mv]

    def prefill(self, state: EngineState, batch, cache=None):
        """Run prefill in the state's world; returns (last_ids, new_cache).
        The caller owns cache merging (continuous batching overwrites only
        admitted lanes).  ``cache`` overrides ``state.cache`` as the target
        — the paged server prefills into a disposable dense scratch, then
        packs the admitted lanes' pages into the pool.
        ``self.last_moe_drop`` holds the call's mean MoE capacity-drop
        fraction (device scalar; None for non-MoE archs)."""
        pf, _ = self.serve_fns(state.stages)
        target = state.cache if cache is None else cache
        with self.world(state.stages).mesh:
            ids, new_cache, drop = pf(state.params, state.assignment,
                                      state.dyn, target, batch)
        self._note_moe_drop(drop)
        return ids, new_cache

    def decode(self, state: EngineState, tokens, pos, *, page_table=None,
               seeds=None, live_micros: Optional[int] = None):
        """One decode step in the state's world; replaces ``state.cache``
        (the jitted fn donates the old buffer) and returns (ids, logprobs).
        ``page_table`` [m, B, J] int32 is required iff the engine is paged;
        ``seeds`` [m, B] int32 iff temperature > 0; ``live_micros`` selects
        the per-micro-count decode variant.
        ``self.last_moe_drop`` as in :meth:`prefill`."""
        _, dec = self.serve_fns(state.stages, live_micros)
        args = [state.params, state.assignment, state.dyn, state.cache,
                tokens, pos]
        if self.paged is not None:
            assert page_table is not None, "paged decode needs a page table"
            args.append(jnp.asarray(page_table, jnp.int32))
        if self.temperature > 0.0:
            assert seeds is not None, "sampling decode needs per-lane seeds"
            args.append(jnp.asarray(seeds, jnp.int32))
        with self.world(state.stages).mesh:
            ids, lp, cache, drop = dec(*args)
        state.cache = cache
        self._note_moe_drop(drop)
        return ids, lp

    # -- paged-KV device helpers ------------------------------------------
    def make_dense_scratch(self, stages: int):
        """A dense, stage-sharded decode cache for the paged prefill path.
        Its contents are disposable: prefill writes whole lanes, pack_pages
        copies the admitted lanes' pages out, nothing else reads it."""
        world = self.world(stages)
        cache = M.init_cache(self.cfg, world.dcfg, self.shapes.num_micro,
                             self.shapes.mb_global, self.shapes.cache_len)
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = NamedSharding(world.mesh, P("model"))
        return jax.tree.map(lambda a: jax.device_put(a, sh), cache)

    def pack_pages(self, state: EngineState, scratch, table, mask):
        """Scatter prompt pages from the dense prefill scratch into the
        block pool.  ``table``/``mask``: [m, B, J] — a page is copied iff
        masked and mapped; everything else is steered at the trash block.
        Duplicate targets (prefix-shared pages admitted together) carry
        bit-identical bytes, so scatter order cannot matter."""
        with self.world(state.stages).mesh:
            state.cache = _pack_pages(
                state.cache, scratch["k"], scratch["v"],
                jnp.asarray(table, jnp.int32), jnp.asarray(mask, bool))
        return state.cache

    def copy_block(self, state: EngineState, src: int, dst: int):
        """Copy-on-write fork: duplicate one physical block across every
        stage-slot pool."""
        with self.world(state.stages).mesh:
            state.cache = _copy_block(state.cache, jnp.int32(src),
                                      jnp.int32(dst))
        return state.cache

    def _note_moe_drop(self, drop):
        """Normalize a serve call's summed MoE drop signal to a mean
        fraction.  Stays a device scalar — the server pays the host sync
        only when it reads the telemetry."""
        from repro.configs.base import BLOCK_MOE
        n_moe = sum(1 for t in self.cfg.block_pattern() if t == BLOCK_MOE)
        if n_moe == 0:
            self.last_moe_drop = None
            return
        self.last_moe_drop = drop / float(n_moe * self.shapes.num_micro)

    # -- measured per-stage timers ----------------------------------------
    def in_step_stage_times(self, state: EngineState):
        """Per-stage busy seconds per step from the live pipelined step
        (DESIGN.md §15) — no extra execution: reads and resets the current
        world's ``StageTimer`` accumulation since the last call.  Returns
        None when in-step timing is off or no full window has accumulated
        yet (e.g. right after a resize onto a fresh world)."""
        w = self.world(state.stages)
        if w.timer is None:
            return None
        return w.timer.snapshot(ticks_per_step=self.ticks(state.stages))

    def measure_stage_times(self, state: EngineState, batch):
        """Measured per-stage forward wall times (seconds, [S]).

        Runs each stage's ``stage_forward`` in isolation over the first
        microbatch, timing on the host with ``block_until_ready`` — the
        profiler's "measured" fidelity tier.  The probe executes with
        ``slot_exec="bounded_loop"`` regardless of the world's executor:
        it must measure the stage's *live* work (the active slots), which
        is the quantity the straggler detector compares against the
        balancer's expected per-stage loads — masked-scan padding cost is
        uniform across stages and carries no load signal.  One probe fn
        serves every stage (slot buffers are uniformly [L_max, ...]-
        shaped), so this compiles once per world; it is still a full host
        sync per stage, which is why the trainer gates it on controller
        cadence.
        """
        import numpy as np

        w = self.world(state.stages)
        if w.stage_probe is None:
            cfg, dyncfg = self.cfg, self.dyncfg
            dcfg = dataclasses.replace(w.dcfg, slot_exec="bounded_loop")

            def probe(stage_params, shared, tags, dyn_s, carry, depth_base):
                pos = jnp.arange(carry["x"].shape[1])
                out, _, _, _ = M.stage_forward(
                    cfg, dcfg, dyncfg, "train", stage_params, shared, tags,
                    dyn_s, carry, None, pos, depth_base)
                return out

            w.stage_probe = jax.jit(probe)
        dt = jnp.bfloat16 if w.dcfg.param_dtype == "bfloat16" \
            else jnp.float32
        carry = M.embed(state.params, self.cfg, batch["tokens"][0])
        carry["x"] = carry["x"].astype(dt)
        if "enc" in carry:
            carry["enc"] = carry["enc"].astype(dt)
        if self.dyncfg.uses_early_exit:
            carry["exited"] = jnp.zeros(carry["x"].shape[:2], jnp.float32)
        starts = np.concatenate([[0], np.cumsum(state.lps)[:-1]])
        times = np.zeros(state.stages)
        shared = state.params["shared"]
        for warm in (True, False):      # first pass compiles + warms caches
            for s in range(state.stages):
                sp = jax.tree.map(lambda a: a[s], state.params["stages"])
                dyn_s = jax.tree.map(lambda a: a[s], state.dyn)
                tags_s = state.assignment["tags"][s]
                t0 = time.perf_counter()
                out = w.stage_probe(sp, shared, tags_s, dyn_s, carry,
                                    jnp.int32(starts[s]))
                jax.block_until_ready(out)
                if not warm:
                    times[s] = time.perf_counter() - t0
                    carry = out      # flow the carry stage-to-stage
        return times

    # -- live resize -------------------------------------------------------
    def resize(self, state: EngineState, new_stages: int,
               new_lps: Optional[Sequence[int]] = None,
               workers: Optional[Sequence[int]] = None) -> EngineState:
        """Reshape all stage-keyed state to ``new_stages`` and place it onto
        that world's submesh — no checkpoint, no restart, no host round-trip.
        A serving cache rides the same re-split plan (its [S, L_max] leading
        dims are gathered exactly like params), so in-flight KV state
        survives the resize bit-identically.  Falls back to a uniform split
        when ``new_lps`` violates the target world's slot capacity."""
        from repro.checkpoint.elastic import (_resplit_stage_tree,
                                              elastic_restore)
        world = self.world(new_stages, workers)
        if new_lps is not None and (
                len(new_lps) != new_stages
                or max(new_lps) > world.dcfg.slots_for(self.cfg)):
            new_lps = None
        params, opt_state, dyn, assignment, lps = elastic_restore(
            self.cfg, self.dcfg_for(state.stages), world.dcfg,
            state.params, state.opt_state, state.dyn, state.lps, new_lps)
        cache = state.cache
        if cache is not None:
            cache = _resplit_stage_tree(cache, state.lps, lps,
                                        world.dcfg.slots_for(self.cfg))
        params, opt_state, dyn, assignment, cache = self._place(
            world, params, opt_state, dyn, assignment, cache)
        self.epoch += 1
        return EngineState(params, opt_state, dyn, assignment, lps,
                           new_stages, cache)

    def shrink(self, state: EngineState, target_stages: int,
               new_lps: Optional[Sequence[int]] = None,
               step: int = -1) -> EngineState:
        """Live consolidation: rebuild on fewer workers, release the tail of
        the stage→worker map back to the job manager."""
        assert target_stages < state.stages
        t0 = time.perf_counter()
        new_state = self.resize(state, target_stages, new_lps)
        released = self.stage_workers[target_stages:]
        self.stage_workers = self.stage_workers[:target_stages]
        self._jm_release(released)
        self.resizes.append(ResizeEvent(
            step=step, kind="shrink", from_stages=state.stages,
            to_stages=target_stages, workers=list(released),
            seconds=time.perf_counter() - t0,
            ticks_before=self.ticks(state.stages),
            ticks_after=self.ticks(target_stages)))
        self.last_shrink_step = step
        return new_state

    def evict(self, state: EngineState, workers: Sequence[int],
              step: int = -1) -> EngineState:
        """Failure path: rebuild the pipeline WITHOUT ``workers`` (dead —
        reported to the job manager as failed, not released; they are not
        grantable until the manager revives them).  Unlike ``shrink`` the
        lost workers may sit anywhere in the stage→worker map."""
        lost = [w for w in workers if w in self.stage_workers]
        if not lost:
            return state
        target = len(self.stage_workers) - len(lost)
        assert target >= 1, "cannot evict every worker"
        t0 = time.perf_counter()
        survivors = [w for w in self.stage_workers if w not in set(lost)]
        # the new world runs on the SURVIVORS' devices (the dead workers'
        # hardware is gone) — not on a positional device prefix
        new_state = self.resize(state, target, workers=survivors)
        self.stage_workers = survivors
        for w in lost:
            self._jm_fail(w)
        self.resizes.append(ResizeEvent(
            step=step, kind="evict", from_stages=state.stages,
            to_stages=target, workers=list(lost),
            seconds=time.perf_counter() - t0,
            ticks_before=self.ticks(state.stages),
            ticks_after=self.ticks(target)))
        self.last_shrink_step = step
        return new_state

    def grow(self, state: EngineState, n_workers: int,
             step: int = -1, steal: bool = False) -> EngineState:
        """Re-expansion: request workers back from the pool and rebuild the
        pipeline over the larger device subset.  Grows by however many the
        pool actually grants (possibly zero).  An unreachable manager
        degrades to "no grant, training continues"; a granted id with no
        free device column behind it is handed back.

        ``steal=True`` escalates the ask through the cluster scheduler's
        steal verb (DESIGN.md §14): free capacity is granted immediately
        and the shortfall preempts a lower-priority tenant — only
        meaningful on a tenant-registered multi-tenant manager; falls back
        to a plain request otherwise."""
        t0 = time.perf_counter()
        self._flush_pending_jm()
        ask = (self.jm.steal if steal and hasattr(self.jm, "steal")
               else self.jm.request)
        try:
            granted = ask(n_workers)
            if not granted and self._pending_jm and self._flush_pending_jm():
                # the request got through, so the manager is back — but its
                # pool hadn't heard our deferred releases yet (the breaker
                # blocked the flush, the request was the probe that closed
                # it).  Bookkeeping is settled now; ask once more.
                granted = ask(n_workers)
        except JobManagerUnavailable:
            self.degraded_events.append(
                f"grow denied at step {step}: manager unreachable")
            return state
        granted, rejected = self._bind_new_workers(granted)
        if rejected:
            self.degraded_events.append(
                f"grant rejected (no free device column): {rejected}")
            self._jm_release(rejected)
        if not granted:
            return state
        target = state.stages + len(granted)
        new_state = self.resize(state, target,
                                workers=self.stage_workers + granted)
        self.stage_workers = self.stage_workers + granted
        self.resizes.append(ResizeEvent(
            step=step, kind="grow", from_stages=state.stages,
            to_stages=target, workers=list(granted),
            seconds=time.perf_counter() - t0,
            ticks_before=self.ticks(state.stages),
            ticks_after=self.ticks(target)))
        return new_state
