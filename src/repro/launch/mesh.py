"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.  Single pod: 16×16 = 256 chips (data × model); multi-pod:
2×16×16 = 512 chips (pod × data × model).  ``model`` is the pipeline axis;
``data`` (and ``pod``) carry DP/FSDP; see DESIGN.md §4.
"""
from __future__ import annotations

import jax


def _auto_mesh(shape, axes):
    """jax.make_mesh with Auto axis types, tolerant of jax versions where
    ``axis_types`` (jax.sharding.AxisType, >= 0.5) does not exist yet —
    Auto is the implicit default there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _auto_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU integration runs / tests."""
    return _auto_mesh((data, model), ("data", "model"))


def make_submesh(data: int, model: int, devices=None):
    """Mesh over an *explicit device subset* — the elastic engine's shrink
    path rebuilds the pipeline on the first ``data*model`` devices of the
    given (or process-global) device list, so released devices hold no
    state and can be handed back to the job manager.

    Uses jax.sharding.Mesh directly (jax.make_mesh offers no device subset
    on every supported jax version); Auto axis types are the default there.
    """
    import numpy as np
    devs = list(devices) if devices is not None else list(jax.devices())
    need = data * model
    if len(devs) < need:
        raise ValueError(
            f"submesh needs {need} devices (data={data} x model={model}), "
            f"have {len(devs)}")
    arr = np.array(devs[:need]).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def data_axes(mesh) -> tuple:
    """The DP axes of a mesh (everything except the pipeline axis)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_degree(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
