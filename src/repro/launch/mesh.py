"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.  Single pod: 16×16 = 256 chips (data × model); multi-pod:
2×16×16 = 512 chips (pod × data × model).  ``model`` is the pipeline axis;
``data`` (and ``pod``) carry DP/FSDP; see DESIGN.md §4.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU integration runs / tests."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def data_axes(mesh) -> tuple:
    """The DP axes of a mesh (everything except the pipeline axis)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_degree(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
