"""Live expert re-layout for expert-parallel MoE (LAER-style move).

The controller watches the per-expert routed-token vector that
``stats["expert_load"]`` folds into every :class:`StatsSnapshot`.  When the
measured hot/cold skew (``max(load) / mean(load)``) crosses a watermark it
emits an :class:`ExpertRelayoutPlan`: a new placement of *logical* experts
over *physical* kernel groups that interleaves hot and cold experts so no
physical neighbourhood concentrates the heavy groups.

Two invariants keep this bit-exact and restart-free:

  * **Params and optimizer state never move.**  The optimizer's global-norm
    clip sums in expert order, so physically permuting the expert axis would
    perturb every update.  Placement lives only in the ``dyn["expert_map"]``
    leaf ([S, L_max, E] float32) consumed by the grouped Pallas kernel —
    per-token math is row-wise, so any placement computes the same y
    bitwise.
  * **The move is the migration gather.**  A placement change is expressed
    as a :class:`migration.MigrationPlan` over a single-stage [1, E] grid
    and applied with the same ``apply_plan`` machinery that moves layers
    between stages — per-expert controller state rides it exactly like
    weights ride a rebalance.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import numpy as np

from repro.core import migration as mig


@dataclasses.dataclass(frozen=True)
class ExpertLayout:
    """Placement of logical experts over physical kernel groups.

    ``placement[e]`` is the physical group computing logical expert ``e``;
    ``capacity_weights[e]`` records the normalized load share that produced
    this placement (1.0 = exactly mean load) — a signal for capacity-aware
    follow-ups, not a kernel input."""
    placement: Tuple[int, ...]
    capacity_weights: Tuple[float, ...]

    @classmethod
    def identity(cls, num_experts: int) -> "ExpertLayout":
        return cls(placement=tuple(range(num_experts)),
                   capacity_weights=(1.0,) * num_experts)

    def __post_init__(self):
        E = len(self.placement)
        assert sorted(self.placement) == list(range(E)), self.placement
        assert len(self.capacity_weights) == E

    @property
    def num_experts(self) -> int:
        return len(self.placement)

    @property
    def inverse(self) -> Tuple[int, ...]:
        """``inverse[p]`` = logical expert computed by physical group p."""
        inv = [0] * len(self.placement)
        for e, p in enumerate(self.placement):
            inv[p] = e
        return tuple(inv)

    def as_array(self) -> np.ndarray:
        return np.asarray(self.placement, np.float32)


@dataclasses.dataclass(frozen=True)
class ExpertRelayoutPlan:
    """One decided placement change, carried by a DecisionPlan to the next
    safe point."""
    old: ExpertLayout
    new: ExpertLayout
    skew: float               # max/mean load ratio that triggered it
    total_tokens: int         # routed tokens in the window
    iteration: int            # trainer step / scheduler tick of the decision

    @property
    def moved_experts(self) -> int:
        return int(sum(a != b for a, b in
                       zip(self.old.placement, self.new.placement)))


def measure_skew(load) -> Tuple[float, int]:
    """(max/mean ratio, total routed tokens) of a per-expert load vector."""
    load = np.asarray(load, np.float64)
    total = float(load.sum())
    if total <= 0:
        return 1.0, 0
    return float(load.max() / (total / load.size)), int(round(total))


def build_relayout(load, current: ExpertLayout, *, watermark: float,
                   min_tokens: int, iteration: int
                   ) -> Optional[ExpertRelayoutPlan]:
    """Decide a re-layout from a measured per-expert load vector.

    Returns None when the window is too small (< min_tokens routed), the
    skew is under the watermark, or the interleaved placement equals the
    current one (nothing to move)."""
    load = np.asarray(load, np.float64)
    skew, total = measure_skew(load)
    if total < min_tokens or skew <= watermark:
        return None
    # LAER interleave: rank experts hot->cold, then zip the ranking from
    # both ends so physical neighbours pair a hot expert with a cold one —
    # under expert-parallel sharding no device neighbourhood concentrates
    # the heavy groups.  argsort on (-load, e) is deterministic under ties.
    E = load.size
    ranked = np.lexsort((np.arange(E), -load))
    order = np.empty(E, np.int64)
    order[0::2] = ranked[: (E + 1) // 2]
    order[1::2] = ranked[(E + 1) // 2:][::-1]
    placement = [0] * E
    for phys, e in enumerate(order):
        placement[int(e)] = phys
    mean = total / E
    new = ExpertLayout(placement=tuple(placement),
                       capacity_weights=tuple(float(x / mean) for x in load))
    if new.placement == current.placement:
        return None
    return ExpertRelayoutPlan(old=current, new=new, skew=skew,
                              total_tokens=total, iteration=iteration)


def as_migration_plan(old: ExpertLayout, new: ExpertLayout
                      ) -> mig.MigrationPlan:
    """Express a placement change as a migration gather over a [1, E] grid.

    Destination physical slot p must hold the state of whatever logical
    expert ``new`` places there, currently sitting at ``old.placement`` of
    that expert — a pure permutation, so every slot is valid."""
    E = old.num_experts
    assert new.num_experts == E
    old_pl = np.asarray(old.placement, np.int64)
    src_slot = old_pl[np.asarray(new.inverse, np.int64)]
    return mig.MigrationPlan(
        src_stage=np.zeros((1, E), np.int32),
        src_slot=src_slot.reshape(1, E).astype(np.int32),
        valid=np.ones((1, E), bool),
        moved_layers=int(np.sum(src_slot != np.arange(E))))


def apply_expert_plan(tree: Any, plan: mig.MigrationPlan) -> Any:
    """Gather per-expert [E, ...] leaves to a new placement by lifting them
    to [1, E, ...] and running the standard migration gather."""
    import jax

    lifted = jax.tree.map(lambda a: a[None], tree)
    moved = mig.apply_plan(lifted, plan)
    return jax.tree.map(lambda a: a[0], moved)
