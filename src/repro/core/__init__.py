"""DynMo core: the paper's primary contribution — profiling, the two
provably-converging load balancers, layer migration, workload re-packing, the
discrete-event pipeline simulator, and the autonomous controller."""
from repro.core.balancer import (BalanceResult, balance, diffusion_balance,
                                 imbalance, partition_balance, stage_loads)
from repro.core.controller import (ControllerConfig, ControllerEvent,
                                   DynMoController, ResizePlan)
from repro.core.migration import MigrationPlan, apply_plan, build_plan, migrate
from repro.core.repack import (RepackPlan, repack, repack_adjacent,
                               repack_first_fit)
from repro.core.simulator import (SimResult, TrainSimConfig, TrainSimResult,
                                  simulate_pipeline, simulate_training,
                                  stage_times_from_layers)

__all__ = [
    "BalanceResult", "balance", "diffusion_balance", "imbalance",
    "partition_balance", "stage_loads", "ControllerConfig", "ControllerEvent",
    "DynMoController", "ResizePlan", "MigrationPlan", "apply_plan",
    "build_plan", "migrate",
    "RepackPlan", "repack", "repack_adjacent", "repack_first_fit", "SimResult",
    "TrainSimConfig", "TrainSimResult", "simulate_pipeline",
    "simulate_training", "stage_times_from_layers",
]
