"""DynMo load balancers (paper §3.3).

Both balancers map a per-layer cost vector onto S contiguous stages,
minimising the bottleneck (max stage cost) — the imbalance ΔL of Eq. (2) is
monotone in the bottleneck, so bottleneck-minimisation ⇔ maximum imbalance
reduction (Lemmas 1 & 2).

``Partition``  — centralized: binary search on the bottleneck value with a
                 greedy feasibility probe (DeepSpeed partition_balanced
                 style), by parameter count or by measured layer time.
``Diffusion``  — decentralized iterative: neighbor-to-neighbor single-layer
                 transfers from overloaded to underloaded stages; Lyapunov
                 potential (sum of pairwise load gaps) strictly decreases;
                 round bound per Lemma 2.

Both respect per-stage slot capacity (L_max) and optional per-stage memory
capacity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class BalanceResult:
    layers_per_stage: List[int]
    bottleneck: float
    imbalance: float            # ΔL of Eq. (2)
    rounds: int = 0             # diffusion iterations (0 for partition)

    @property
    def boundaries(self) -> List[int]:
        out, acc = [], 0
        for n in self.layers_per_stage:
            acc += n
            out.append(acc)
        return out


def imbalance(loads: Sequence[float]) -> float:
    """ΔL^(k) of Eq. (2): (Lmax - Lmin) / mean."""
    loads = np.asarray(loads, dtype=np.float64)
    m = loads.mean()
    if m <= 0:
        return 0.0
    return float((loads.max() - loads.min()) / m)


def stage_loads(costs: Sequence[float], layers_per_stage: Sequence[int]
                ) -> np.ndarray:
    loads, i = [], 0
    for n in layers_per_stage:
        loads.append(float(np.sum(costs[i:i + n])))
        i += n
    return np.asarray(loads)


def _feasible(costs: np.ndarray, S: int, cap: float, max_slots: int,
              mem: Optional[np.ndarray], mem_cap: float) -> Optional[List[int]]:
    """Greedy probe: can we split into ≤ S contiguous stages with stage cost
    ≤ cap, ≤ max_slots layers and ≤ mem_cap memory each?"""
    out, cur_c, cur_n, cur_m, used = [], 0.0, 0, 0.0, 1
    for j, c in enumerate(costs):
        mj = float(mem[j]) if mem is not None else 0.0
        over = (cur_c + c > cap or cur_n + 1 > max_slots
                or (mem is not None and cur_m + mj > mem_cap))
        if over and cur_n > 0:
            out.append(cur_n)
            used += 1
            cur_c, cur_n, cur_m = 0.0, 0, 0.0
            if used > S:
                return None
        if c > cap or (mem is not None and mj > mem_cap):
            return None                      # single layer violates cap
        cur_c += c
        cur_n += 1
        cur_m += mj
    out.append(cur_n)
    if len(out) > S:
        return None
    # pad empty stages at the end (allowed: re-packing uses them)
    out += [0] * (S - len(out))
    return out


def partition_balance(costs: Sequence[float], num_stages: int,
                      max_slots: int = 10 ** 9,
                      mem: Optional[Sequence[float]] = None,
                      mem_cap: float = float("inf"),
                      iters: int = 48) -> BalanceResult:
    """Centralized balancer: minimal-bottleneck contiguous partition via
    binary search on the bottleneck + greedy feasibility probe.

    Optimal to within float tolerance: the returned bottleneck is ≤ any
    feasible contiguous partition's bottleneck (tested property).
    """
    costs = np.asarray(costs, dtype=np.float64)
    assert len(costs) >= 1
    mem_arr = None if mem is None else np.asarray(mem, dtype=np.float64)
    lo = float(costs.max())
    hi = float(costs.sum())
    best = None
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        probe = _feasible(costs, num_stages, mid, max_slots, mem_arr, mem_cap)
        if probe is not None:
            best, hi = probe, mid
        else:
            lo = mid
    if best is None:
        best = _feasible(costs, num_stages, hi, max_slots, mem_arr, mem_cap)
    if best is None:
        raise ValueError("infeasible: capacity/memory constraints too tight")
    best = _rebalance_empty(costs, best, max_slots)
    loads = stage_loads(costs, best)
    return BalanceResult(best, float(loads.max()), imbalance(loads))


def _rebalance_empty(costs: np.ndarray, lps: List[int],
                     max_slots: int) -> List[int]:
    """Greedy probing can leave trailing empty stages.  An empty stage is a
    harmless relay (that is exactly how re-packed shadow stages work), but
    when there are enough layers we cosmetically spread one layer into each
    empty stage: decrementing a donor and incrementing the empty stage keeps
    the split contiguous (all spans in between shift by one)."""
    lps = list(lps)
    S = len(lps)
    if sum(lps) < S:
        return lps
    for s in range(S):
        if lps[s] == 0:
            cand = [d for d in range(S) if lps[d] > 1]
            if not cand:
                break
            d = min(cand, key=lambda dd: (abs(dd - s), -lps[dd]))
            lps[d] -= 1
            lps[s] += 1
    return lps


def diffusion_balance(costs: Sequence[float], num_stages: int,
                      max_slots: int = 10 ** 9,
                      mem: Optional[Sequence[float]] = None,
                      mem_cap: float = float("inf"),
                      gamma: float = 1e-3,
                      max_rounds: Optional[int] = None,
                      init: Optional[Sequence[int]] = None) -> BalanceResult:
    """Decentralized diffusion balancer: odd/even alternating neighbor
    exchanges of boundary layers, accepted only if they strictly reduce the
    pair's local potential |L_i − L_{i+1}| (Lyapunov descent ⇒ convergence;
    round bound per Lemma 2)."""
    costs = np.asarray(costs, dtype=np.float64)
    S = num_stages
    mem_arr = None if mem is None else np.asarray(mem, dtype=np.float64)
    if init is None:
        base = len(costs) // S
        rem = len(costs) % S
        lps = [min(max_slots, base + (1 if s < rem else 0)) for s in range(S)]
        # fix any total mismatch from capacity clamping
        deficit = len(costs) - sum(lps)
        s = 0
        while deficit > 0:
            if lps[s] < max_slots:
                lps[s] += 1
                deficit -= 1
            s = (s + 1) % S
    else:
        lps = list(init)

    Sn = float(costs.sum())
    if max_rounds is None:
        # Lemma 2 bound: O(min{N^2 log(SN/γ) log N, S N log N / γ})
        n = max(2, S)
        b1 = n * n * math.log(max(Sn * n / max(gamma, 1e-9), 2.0)) \
            * math.log(n)
        b2 = Sn * n * math.log(n) / max(gamma, 1e-9)
        max_rounds = int(min(max(64, b1), max(64, b2))) + 1
        max_rounds = min(max_rounds, 10000)

    def bounds_ok(lps_, s):
        if lps_[s] > max_slots or lps_[s] < 0:
            return False
        if mem_arr is not None:
            starts = np.concatenate([[0], np.cumsum(lps_)])
            m = float(mem_arr[starts[s]:starts[s + 1]].sum())
            if m > mem_cap:
                return False
        return True

    def pair_best_cut(span_lo: int, span_hi: int, cur_left: int,
                      prefer_small_left: bool):
        """Optimal 2-partition of the contiguous span [lo, hi): the cut that
        minimises max(left, right) load, tie-broken by smaller gap, then by
        the percolation direction (equal-quality cuts drift load toward the
        lighter side of the ring).  Pure pair-local information.

        Vectorized prefix-sum scan (the controller runs this for every
        neighbor pair every round — O(n) per pair instead of a Python
        loop): the stable lexsort reproduces the sequential scan's
        earliest-cut tie-break."""
        seg = costs[span_lo:span_hi]
        n = len(seg)
        left = np.concatenate([[0.0], np.cumsum(seg)])      # [n + 1]
        right = left[-1] - left
        cuts = np.arange(n + 1)
        ok = (cuts <= max_slots) & ((n - cuts) <= max_slots)
        if not ok.any():
            return cur_left
        key1 = np.where(ok, np.maximum(left, right), np.inf)
        key2 = np.abs(left - right)
        key3 = -cuts if not prefer_small_left else cuts      # = -tie_dir
        return int(np.lexsort((key3, key2, key1))[0])

    def window_pass(lps, width: int, offset: int) -> Tuple[List[int], bool]:
        """Re-partition each window of `width` consecutive stages optimally
        over its own contiguous span (only neighbor-local information);
        accept on strict window-bottleneck reduction."""
        starts = np.concatenate([[0], np.cumsum(lps)]).astype(int)
        moved = False
        i = offset
        while i + width <= S:
            lo, hi = starts[i], starts[i + width]
            if hi > lo:
                span = costs[lo:hi]
                old_max = max(float(span[starts[i + t] - lo:
                                         starts[i + t + 1] - lo].sum())
                              for t in range(width))
                res = partition_balance(span, width, max_slots=max_slots)
                if res.bottleneck < old_max - 1e-12:
                    trial = list(lps)
                    for t in range(width):
                        trial[i + t] = res.layers_per_stage[t]
                    ok = all(bounds_ok(trial, i + t) for t in range(width))
                    if ok:
                        lps = trial
                        starts = np.concatenate(
                            [[0], np.cumsum(lps)]).astype(int)
                        moved = True
            i += width
        return lps, moved

    rounds = 0
    for r in range(max_rounds):
        rounds = r + 1
        moved = False
        # pairwise exchange (odd/even alternation)
        loads_ring = stage_loads(costs, lps)
        for parity in (0, 1):
            starts = np.concatenate([[0], np.cumsum(lps)]).astype(int)
            for i in range(parity, S - 1, 2):
                j = i + 1
                lo, hi = starts[i], starts[j + 1]
                cur_left = lps[i]
                left_mean = float(loads_ring[:j].mean())
                right_mean = float(loads_ring[j:].mean())
                cut = pair_best_cut(lo, hi, cur_left,
                                    prefer_small_left=left_mean > right_mean)
                if cut == cur_left:
                    continue
                trial = list(lps)
                trial[i] = cut
                trial[j] = (hi - lo) - cut
                if not (bounds_ok(trial, i) and bounds_ok(trial, j)):
                    continue
                old_max = max(float(costs[lo:lo + cur_left].sum()),
                              float(costs[lo + cur_left:hi].sum()))
                new_max = max(float(costs[lo:lo + cut].sum()),
                              float(costs[lo + cut:hi].sum()))
                if new_max < old_max - 1e-12:
                    lps = trial
                    starts = np.concatenate(
                        [[0], np.cumsum(lps)]).astype(int)
                    moved = True
                elif abs(new_max - old_max) < 1e-12 and r < 2 * S:
                    # tie percolation: the direction-aware tie-break above
                    # already chose the drift toward the lighter ring side;
                    # accept so heavy plateaus drain toward idle stages.
                    # (bounded to 2S rounds — prevents endless tie walks)
                    lps = trial
                    starts = np.concatenate(
                        [[0], np.cumsum(lps)]).astype(int)
                    moved = True
        if not moved:
            # plateau: escalate to 3-stage neighborhoods (patterns like
            # [3,1 | 3,3] need coordinated shifts pairs cannot express)
            for off in (0, 1, 2):
                lps, m3 = window_pass(lps, 3, off)
                moved = moved or m3
        if not moved:
            break
    loads = stage_loads(costs, lps)
    return BalanceResult(list(map(int, lps)), float(loads.max()),
                         imbalance(loads), rounds)


def balance(method: str, costs: Sequence[float], num_stages: int,
            **kw) -> BalanceResult:
    if method == "partition":
        kw.pop("init", None)
        kw.pop("gamma", None)
        return partition_balance(costs, num_stages, **kw)
    if method == "diffusion":
        return diffusion_balance(costs, num_stages, **kw)
    if method == "uniform":      # Megatron-LM static baseline
        base = len(costs) // num_stages
        rem = len(costs) % num_stages
        lps = [base + (1 if s < rem else 0) for s in range(num_stages)]
        loads = stage_loads(costs, lps)
        return BalanceResult(lps, float(loads.max()), imbalance(loads))
    raise ValueError(method)
