"""Analytic per-layer cost model.

Single source of truth for: the simulator's per-layer times, the balancers'
"by-param"/"by-time" cost vectors at dry-run scale, and the roofline's
MODEL_FLOPS cross-check.  All dynamism schemes modulate per-layer cost
through a ``LayerDynState`` so the *same* model drives Fig. 1/3/4
reproductions.

Hardware constants default to TPU v5e (the roofline target); the paper's
H100 numbers are available for reproducing the paper's absolute throughput
ratios.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import (
    BLOCK_DEC, BLOCK_DENSE, BLOCK_ENC, BLOCK_HYBRID_ATTN, BLOCK_MAMBA,
    BLOCK_MLSTM, BLOCK_MOE, BLOCK_SLSTM, ModelConfig,
)

# TPU v5e
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
# H100 SXM (for paper-scale reproduction ratios)
H100_PEAK_FLOPS = 989e12 / 2   # bf16 dense ~ 989/2 without sparsity
H100_HBM_BW = 3.35e12
NVLINK_BW = 450e9


@dataclasses.dataclass
class LayerDynState:
    """Per-layer dynamism multipliers at one training moment."""
    retained: float = 1.0       # pruning: fraction of FFN blocks kept
    frozen: bool = False        # freezing: backward dW skipped
    attn_density: float = 1.0   # sparse attention: fraction of attn blocks
    token_frac: float = 1.0     # early-exit / MoD: fraction of live tokens
    expert_hot: float = 1.0     # MoE: hottest-expert load multiplier vs mean


def layer_flops(cfg: ModelConfig, block_type: int, tokens: int,
                seq: int, dyn: Optional[LayerDynState] = None,
                backward: bool = False) -> float:
    """FLOPs for one block over ``tokens`` tokens at context ``seq``."""
    dyn = dyn or LayerDynState()
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    t = tokens * dyn.token_frac
    f = 0.0
    if block_type in (BLOCK_DENSE, BLOCK_MOE, BLOCK_ENC, BLOCK_DEC,
                      BLOCK_HYBRID_ATTN):
        # qkvo projections
        proj = 2 * t * d * (nq * hd + 2 * nkv * hd + nq * hd)
        ctx = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
        att = 2 * t * ctx * nq * hd * 2 * dyn.attn_density
        f += proj + att
        if block_type == BLOCK_DEC:
            f += proj + 2 * t * cfg.encoder_seq * nq * hd * 2   # cross attn
    if block_type == BLOCK_DENSE:
        f += 2 * t * 3 * d * cfg.d_ff * dyn.retained
    elif block_type == BLOCK_MOE:
        cap = 1.25
        f += 2 * t * cfg.experts_per_token * cap * 3 * d * cfg.d_ff \
            * dyn.retained * dyn.expert_hot
        f += 2 * t * d * cfg.num_experts                        # router
    elif block_type in (BLOCK_ENC, BLOCK_DEC):
        f += 2 * t * 2 * d * cfg.d_ff * dyn.retained
    elif block_type in (BLOCK_MAMBA, BLOCK_HYBRID_ATTN):
        d_in = 2 * d
        st = cfg.ssm_state
        nh = max(1, d_in // 64)
        f_m = 2 * t * d * (2 * d_in + 2 * st + nh)              # in_proj
        f_m += 2 * t * d_in * d                                 # out_proj
        f_m += t * d_in * st * 6                                # ssd scan
        f += f_m
    elif block_type == BLOCK_MLSTM:
        d_in = 2 * d
        nh = max(1, cfg.num_heads)
        dh = d_in // nh
        f += 2 * t * d * 2 * d_in + 2 * t * d_in * d            # up/down
        f += 2 * t * 3 * d_in * dh * dyn.retained               # qkv blockdiag
        chunk = min(seq, 256)
        f += 2 * t * chunk * nh * dh * 2                        # chunk attn
    elif block_type == BLOCK_SLSTM:
        f += 2 * t * d * 4 * d + 2 * t * d * d
        f += 2 * t * d * (8 * d // 3) * dyn.retained
    if backward:
        # dx for all; dW skipped when frozen
        f *= 1.0 if not dyn else (1.0 if dyn.frozen else 2.0)
    return f


def layer_bytes(cfg: ModelConfig, block_type: int, tokens: int,
                seq: int, dyn: Optional[LayerDynState] = None,
                dtype_bytes: int = 2) -> float:
    """HBM traffic estimate: weights once + activations in/out."""
    dyn = dyn or LayerDynState()
    w = cfg.params_per_block(block_type) * dtype_bytes * max(
        0.25, dyn.retained)
    act = 3 * tokens * cfg.d_model * dtype_bytes
    if block_type in (BLOCK_DENSE, BLOCK_MOE, BLOCK_ENC, BLOCK_DEC,
                      BLOCK_HYBRID_ATTN):
        ctx = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
        kv = 2 * tokens * cfg.num_kv_heads * cfg.resolved_head_dim \
            * dtype_bytes
        act += kv + 2 * ctx * cfg.num_kv_heads * cfg.resolved_head_dim \
            * dtype_bytes * dyn.attn_density
    return w + act


def layer_time(cfg: ModelConfig, block_type: int, tokens: int, seq: int,
               dyn: Optional[LayerDynState] = None, backward: bool = True,
               peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
               overhead: float = 2e-6) -> float:
    """Roofline time: max(compute, memory) + launch overhead; fwd+bwd.

    Frozen layers run FORWARD ONLY: layer freezing advances as a front from
    layer 0 (Egeria — early layers converge first), so no activation grads
    flow into the frozen prefix at all; both dW and dx are skipped there
    (matching the paper's 'drop frozen layers from back propagation')."""
    dyn = dyn or LayerDynState()
    f_fwd = layer_flops(cfg, block_type, tokens, seq, dyn)
    t_fwd = max(f_fwd / peak_flops,
                layer_bytes(cfg, block_type, tokens, seq, dyn) / hbm_bw)
    t = t_fwd + overhead
    if backward and not dyn.frozen:
        t += t_fwd * 2.0 + overhead
    return t


def model_flops(cfg: ModelConfig, tokens: int, train: bool = True) -> float:
    """6·N·D convention (2·N·D forward, 4·N·D backward); MoE uses active
    params."""
    n = cfg.active_param_count()
    return (6.0 if train else 2.0) * n * tokens


# resident bytes per param byte: weights + grads + 2 Adam moments + working
# set; single source for every repack memory estimate (profiler, controller,
# trainer budget) — change it HERE, not at a call site
MEM_STATE_FACTOR = 5.0


def stage_memory_budget(cfg: ModelConfig, tokens: int, seq: int,
                        bytes_per_param: float, num_stages: int,
                        cap_factor: float = 1.0) -> float:
    """Per-worker memory budget: ``cap_factor`` × the UNPRUNED per-stage
    footprint (params + optimizer state) under a uniform split — the repack
    trigger the trainer hands the controller."""
    pb = cost_vector(cfg, tokens, seq, None, by="param") \
        * float(bytes_per_param)
    return float(cap_factor) * float(pb.sum()) * MEM_STATE_FACTOR \
        / max(1, num_stages)


def cost_vector(cfg: ModelConfig, tokens: int, seq: int,
                dyn_states: Optional[Sequence[LayerDynState]] = None,
                by: str = "time") -> np.ndarray:
    """Per-layer cost vector for the balancers.

    ``by='time'``  — analytic layer times (profiled execution time stand-in)
    ``by='param'`` — parameter counts (DeepSpeed-style)
    """
    pattern = cfg.block_pattern()
    if dyn_states is None:
        dyn_states = [LayerDynState() for _ in pattern]
    out = []
    for bt, ds in zip(pattern, dyn_states):
        if by == "param":
            out.append(cfg.params_per_block(bt) * max(0.05, ds.retained))
        else:
            out.append(layer_time(cfg, bt, tokens, seq, ds))
    return np.asarray(out, dtype=np.float64)
