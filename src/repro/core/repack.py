"""Workload re-packing (paper §3.4, Algorithm 2): first-fit consolidation of
pipeline stages onto fewer workers subject to memory capacity, so idle
workers can be released back to the job manager (elasticity).

A packed-away stage becomes a *shadow* stage: its layers migrate to the
destination worker and the source keeps zero slots (pure ppermute relay) —
or, across a checkpoint restart, the mesh is rebuilt without it
(checkpoint-coordinated path, §3.4.2).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class RepackPlan:
    transfers: List[Tuple[int, int, int]]   # (src_stage, dst_stage, layer_idx)
    active_workers: List[int]               # 0/1 per stage after packing
    mem_usage: List[float]                  # per-stage memory after packing
    layers_per_stage: List[int]             # new layer counts

    @property
    def num_active(self) -> int:
        return int(sum(self.active_workers))


def repack_first_fit(mem_usage: Sequence[float], num_layers: Sequence[int],
                     max_mem: float, target_num_workers: int = 1,
                     max_layers: int = 10 ** 9) -> RepackPlan:
    """Algorithm 2 (faithful): iterate worker pairs (src, dst>src); if their
    combined memory fits one worker's budget and we are still above the
    target count, migrate all of src's layers to dst and deactivate src.
    ``max_layers`` bounds a worker's slot capacity (L_max)."""
    mem = list(map(float, mem_usage))
    nl = list(map(int, num_layers))
    n = len(mem)
    active = [1] * n
    transfers: List[Tuple[int, int, int]] = []
    for src in range(n):
        if not active[src]:
            continue
        for dst in range(src + 1, n):
            if not active[dst]:
                continue
            if (mem[src] + mem[dst] < max_mem
                    and sum(active) > target_num_workers
                    and nl[src] > 0
                    and nl[src] + nl[dst] <= max_layers):
                active[src] = 0
                for lyr in range(nl[src]):
                    transfers.append((src, dst, lyr))
                mem[dst] += mem[src]
                mem[src] = 0.0
                nl[dst] += nl[src]
                nl[src] = 0
                break
    return RepackPlan(transfers, active, mem, nl)


def repack_adjacent(mem_usage: Sequence[float], num_layers: Sequence[int],
                    max_mem: float, target_num_workers: int = 1,
                    max_layers: int = 10 ** 9) -> RepackPlan:
    """Pipeline-order-preserving variant (beyond-paper): only merge adjacent
    stages so the contiguous layer order is kept and migrations are single-hop
    ppermutes.  First-fit over adjacent pairs, repeated to fixpoint.
    ``max_layers`` bounds a worker's slot capacity (L_max)."""
    mem = list(map(float, mem_usage))
    nl = list(map(int, num_layers))
    n = len(mem)
    active = [1] * n
    transfers: List[Tuple[int, int, int]] = []
    changed = True
    while changed and sum(active) > target_num_workers:
        changed = False
        i = 0
        order = [s for s in range(n) if active[s]]
        for a, b in zip(order, order[1:]):
            if sum(active) <= target_num_workers:
                break
            if (mem[a] + mem[b] < max_mem and nl[a] > 0
                    and nl[a] + nl[b] <= max_layers):
                active[a] = 0
                for lyr in range(nl[a]):
                    transfers.append((a, b, lyr))
                mem[b] += mem[a]
                mem[a] = 0.0
                nl[b] += nl[a]
                nl[a] = 0
                changed = True
                break
    return RepackPlan(transfers, active, mem, nl)


REPACK_POLICIES = {
    "first_fit": repack_first_fit,   # Algorithm 2 as written
    "adjacent": repack_adjacent,     # order-preserving variant
}


def repack(policy: str, mem_usage: Sequence[float],
           num_layers: Sequence[int], max_mem: float,
           target_num_workers: int = 1,
           max_layers: int = 10 ** 9) -> RepackPlan:
    """Policy-dispatched consolidation; the controller selects via
    ``ControllerConfig.repack_policy``."""
    try:
        fn = REPACK_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown repack policy {policy!r}; have {sorted(REPACK_POLICIES)}")
    return fn(mem_usage, num_layers, max_mem, target_num_workers, max_layers)
