"""Discrete-event pipeline simulator.

Reproduces the paper's evaluation (Figs. 1, 3, 4) without GPUs: per-layer
fwd/bwd times come from the calibrated cost model (or measured profiles),
dynamism trajectories evolve them over iterations, and the simulator computes
step makespans, per-stage idleness (bubble ratio), and end-to-end throughput
for static (Megatron-uniform / DeepSpeed-param) vs DynMo (Partition /
Diffusion × by-param / by-time) balancing, including DynMo's own overhead
(profiling + algorithm + migration) and optional re-packing.

Schedules: GPipe and non-interleaved 1F1B.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import balancer as bal
from repro.core import repack as rp
from repro.core.cost_model import ICI_BW


@dataclasses.dataclass
class SimResult:
    makespan: float
    bubble_ratio: float          # idle fraction across stages
    stage_busy: np.ndarray
    throughput: float = 0.0      # tokens/sec (filled by callers)


def simulate_pipeline(fwd: Sequence[float], bwd: Sequence[float],
                      num_micro: int, comm: float = 0.0,
                      schedule: str = "1f1b") -> SimResult:
    """Event-driven makespan of one step on S stages with per-stage op times.

    Dependencies: F[s,k] ← F[s-1,k]+comm; B[s,k] ← B[s+1,k]+comm and F[s,k];
    ops on one stage execute in the schedule's per-stage order.
    """
    S, m = len(fwd), num_micro
    order: List[List[Tuple[str, int]]] = []
    for s in range(S):
        ops: List[Tuple[str, int]] = []
        if schedule == "gpipe":
            ops += [("F", k) for k in range(m)]
            ops += [("B", k) for k in range(m)]
        else:  # 1f1b (non-interleaved)
            w = min(m, S - s)
            ops += [("F", k) for k in range(w)]
            nf, nb = w, 0
            while nf < m or nb < m:
                if nb < m:
                    ops.append(("B", nb))
                    nb += 1
                if nf < m:
                    ops.append(("F", nf))
                    nf += 1
        order.append(ops)

    end: Dict[Tuple[str, int, int], float] = {}
    ptr = [0] * S
    stage_free = [0.0] * S
    busy = np.zeros(S)
    remaining = sum(len(o) for o in order)
    while remaining:
        progressed = False
        for s in range(S):
            if ptr[s] >= len(order[s]):
                continue
            kind, k = order[s][ptr[s]]
            if kind == "F":
                dep = 0.0 if s == 0 else end.get(("F", s - 1, k))
                if dep is None:
                    continue
                start = max(stage_free[s], dep + (comm if s else 0.0))
                dur = fwd[s]
            else:
                dep_b = 0.0 if s == S - 1 else end.get(("B", s + 1, k))
                dep_f = end.get(("F", s, k))
                if dep_b is None or dep_f is None:
                    continue
                start = max(stage_free[s],
                            dep_b + (comm if s < S - 1 else 0.0), dep_f)
                dur = bwd[s]
            end[(kind, s, k)] = start + dur
            stage_free[s] = start + dur
            busy[s] += dur
            ptr[s] += 1
            remaining -= 1
            progressed = True
        if not progressed:
            raise RuntimeError("schedule deadlock (bug)")
    makespan = max(stage_free)
    denom = max(1e-12, S * makespan)
    bubble = 1.0 - float(busy.sum()) / denom
    return SimResult(makespan, bubble, busy)


def stage_times_from_layers(layer_fwd: np.ndarray, layer_bwd: np.ndarray,
                            layers_per_stage: Sequence[int]
                            ) -> Tuple[np.ndarray, np.ndarray]:
    f, b, i = [], [], 0
    for n in layers_per_stage:
        f.append(float(layer_fwd[i:i + n].sum()))
        b.append(float(layer_bwd[i:i + n].sum()))
        i += n
    return np.asarray(f), np.asarray(b)


@dataclasses.dataclass
class TrainSimConfig:
    num_stages: int
    num_micro: int
    tokens_per_iter: int
    iters: int = 10000
    sample_every: int = 50            # evaluate the makespan this often
    rebalance_every: int = 0          # 0 = static
    balancer: str = "uniform"         # uniform | dsparam | partition | diffusion
    cost_by: str = "time"             # time | param
    schedule: str = "1f1b"
    comm: float = 0.0
    max_slots: int = 10 ** 9
    repack: bool = False
    repack_mem_cap: float = float("inf")
    layer_mem: Optional[np.ndarray] = None
    migration_bw: float = ICI_BW
    profile_overhead_frac: float = 1.0   # one profiling iteration's cost


@dataclasses.dataclass
class TrainSimResult:
    total_time: float
    throughput: float
    avg_bubble: float
    avg_active_workers: float
    overhead_frac: float
    overhead_breakdown: Dict[str, float]
    bubble_history: List[Tuple[int, float]]
    imbalance_history: List[Tuple[int, float]]


def simulate_training(layer_time_fn: Callable[[int], Tuple[np.ndarray,
                                                           np.ndarray]],
                      layer_param_bytes: np.ndarray,
                      sim: TrainSimConfig) -> TrainSimResult:
    """End-to-end training simulation.

    ``layer_time_fn(k)`` returns (fwd_times, bwd_times) per *layer* at
    iteration k (the dynamism trajectory).  Balancers see the by-time or
    by-param cost vector (profiled at the last profile iteration, like the
    real system — rebalance acts on slightly stale data, faithfully).
    """
    S = sim.num_stages
    L = len(layer_param_bytes)
    lps = bal.balance("uniform", np.ones(L), S,
                      max_slots=sim.max_slots).layers_per_stage
    if sim.balancer == "dsparam" and sim.rebalance_every == 0:
        lps = bal.partition_balance(layer_param_bytes, S,
                                    max_slots=sim.max_slots).layers_per_stage
    total, tokens = 0.0, 0.0
    t_overhead = {"profile": 0.0, "algorithm": 0.0, "migration": 0.0}
    bubbles, imbs = [], []
    busy_w = 0.0
    active_workers = S
    aw_acc, n_samples = 0.0, 0
    reb_round = max(sim.sample_every,
                    (sim.rebalance_every // max(1, sim.sample_every))
                    * sim.sample_every) if sim.rebalance_every else 0
    for k in range(0, sim.iters, sim.sample_every):
        f_l, b_l = layer_time_fn(k)
        # rebalance?
        if reb_round and k and k % reb_round == 0:
            costs = (f_l + b_l) if sim.cost_by == "time" \
                else layer_param_bytes
            method = {"partition": "partition", "diffusion": "diffusion",
                      "dsparam": "partition",
                      "uniform": "uniform"}[sim.balancer]
            t0 = _time.perf_counter()
            res = bal.balance(method, costs, S, max_slots=sim.max_slots,
                              init=lps if method == "diffusion" else None)
            t_alg = _time.perf_counter() - t0
            new_lps = res.layers_per_stage
            moved = _moved_bytes(lps, new_lps, layer_param_bytes)
            t_overhead["algorithm"] += t_alg
            t_overhead["migration"] += moved / sim.migration_bw
            step_now = simulate_pipeline(
                *stage_times_from_layers(f_l, b_l, lps), sim.num_micro,
                sim.comm, sim.schedule).makespan
            t_overhead["profile"] += step_now * sim.profile_overhead_frac
            lps = new_lps
            if sim.repack and sim.layer_mem is not None:
                mem_stage = bal.stage_loads(sim.layer_mem, lps)
                plan = rp.repack_adjacent(mem_stage, lps,
                                          sim.repack_mem_cap)
                t_overhead["migration"] += _moved_bytes(
                    lps, plan.layers_per_stage, layer_param_bytes) \
                    / sim.migration_bw
                lps = plan.layers_per_stage
                active_workers = plan.num_active
        fwd_s, bwd_s = stage_times_from_layers(f_l, b_l, lps)
        r = simulate_pipeline(fwd_s, bwd_s, sim.num_micro, sim.comm,
                              sim.schedule)
        total += r.makespan * sim.sample_every
        tokens += sim.tokens_per_iter * sim.sample_every
        busy_w += r.bubble_ratio * sim.sample_every
        aw_acc += active_workers
        n_samples += 1
        bubbles.append((k, r.bubble_ratio))
        imbs.append((k, bal.imbalance(fwd_s + bwd_s)))
    oh = sum(t_overhead.values())
    total += oh
    return TrainSimResult(
        total_time=total, throughput=tokens / total,
        avg_bubble=busy_w / max(1, sim.iters),
        avg_active_workers=aw_acc / max(1, n_samples),
        overhead_frac=oh / max(1e-12, total),
        overhead_breakdown=t_overhead,
        bubble_history=bubbles, imbalance_history=imbs)


def _moved_bytes(old_lps: Sequence[int], new_lps: Sequence[int],
                 layer_bytes: np.ndarray) -> float:
    """Bytes migrated between stages when the contiguous split changes:
    layers whose stage changed, weighted ×4 (weights + grads + 2 opt
    moments), matching the paper's migration of full layer state."""
    def owner(lps):
        out = []
        for s, n in enumerate(lps):
            out += [s] * n
        return np.asarray(out)
    o1, o2 = owner(old_lps), owner(new_lps)
    n = min(len(o1), len(o2))
    moved = o1[:n] != o2[:n]
    return float((layer_bytes[:n] * moved).sum() * 4.0)
