"""DynMo profiler (paper §3.1 step 3): after each dynamism event, one
iteration measures per-layer execution time and per-worker memory.

Sources, in decreasing fidelity:
  * measured   — wall-clock timing of per-stage execution on the host
                 backend (integration runs / single-node);
  * stats      — the pipeline's per-slot stats outputs (expert loads, ff
                 retention, attention density, token fractions) folded
                 through the analytic cost model;
  * analytic   — pure cost model from the dynamism state (dry-run scale).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import BLOCK_PAD, ModelConfig
from repro.core.cost_model import (LayerDynState, MEM_STATE_FACTOR,
                                   cost_vector)


@dataclasses.dataclass
class LayerProfile:
    """Per-global-layer profile in execution order."""
    time_per_layer: np.ndarray      # seconds (fwd+bwd)
    param_bytes: np.ndarray         # bytes
    mem_per_stage: np.ndarray       # bytes resident per stage
    dyn_states: List[LayerDynState]
    # MoE routing signals, aggregated over every MoE slot in the window:
    # per-expert routed-token counts [E] (None for non-MoE archs) and the
    # mean capacity-drop fraction — the controller's expert re-layout and
    # overflow telemetry read these.
    expert_load: Optional[np.ndarray] = None
    moe_drop_frac: float = 0.0


def profile_from_stats(cfg: ModelConfig, stats: Dict[str, np.ndarray],
                       tags: np.ndarray, num_micro: int, tokens: int,
                       seq: int, dyn_ff: Optional[np.ndarray] = None,
                       frozen: Optional[np.ndarray] = None,
                       bytes_per_param: float = 2.0) -> LayerProfile:
    """Fold the pipeline's per-slot stats [S, L_max, ...] into per-layer
    DynStates + cost-model times, in global layer order.

    ``bytes_per_param`` must match the trainer's param dtype
    (``DistConfig.bytes_per_param``) — repack memory budgets are computed
    from these byte vectors."""
    S, L_max = tags.shape
    states: List[LayerDynState] = []
    order: List[int] = []
    expert = stats.get("expert_load")
    dropped = stats.get("moe_dropped")
    dens = stats.get("attn_density")
    ffa = stats.get("ff_active")
    expert_total: Optional[np.ndarray] = None
    drop_sum, drop_n = 0.0, 0
    for s in range(S):
        for l in range(L_max):
            if tags[s, l] == BLOCK_PAD:
                continue
            ds = LayerDynState()
            if ffa is not None and np.ndim(ffa) >= 2:
                v = float(ffa[s, l]) / max(1, num_micro)
                ds.retained = float(np.clip(v, 0.02, 1.0))
            if dens is not None and np.ndim(dens) >= 2:
                v = float(dens[s, l]) / max(1, num_micro)
                ds.attn_density = float(np.clip(v, 0.02, 1.0))
            if expert is not None and cfg.num_experts:
                e = np.asarray(expert[s, l], dtype=np.float64)
                mean = e.mean() if e.mean() > 0 else 1.0
                ds.expert_hot = float(np.clip(e.max() / mean, 1.0, 4.0))
                if e.sum() > 0:   # an MoE slot that actually routed
                    expert_total = (e if expert_total is None
                                    else expert_total + e)
                    if dropped is not None and np.ndim(dropped) >= 2:
                        drop_sum += float(dropped[s, l]) / max(1, num_micro)
                        drop_n += 1
            if frozen is not None:
                ds.frozen = bool(frozen[s, l] > 0)
            states.append(ds)
            order.append(tags[s, l])
    times = cost_vector(cfg, tokens, seq, states, by="time")
    params = cost_vector(cfg, tokens, seq, states,
                         by="param") * float(bytes_per_param)
    mem = np.zeros(S)
    i = 0
    for s in range(S):
        n = int(np.sum(tags[s] != BLOCK_PAD))
        mem[s] = params[i:i + n].sum() * MEM_STATE_FACTOR
        i += n
    return LayerProfile(times, params, mem, states,
                        expert_load=expert_total,
                        moe_drop_frac=drop_sum / drop_n if drop_n else 0.0)


def measure_stage_times(step_fn: Callable[[], None], repeats: int = 3
                        ) -> float:
    """Wall-clock one pipeline step (host backend); used to calibrate the
    cost model's overhead constant on real integration runs."""
    step_fn()                        # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        step_fn()
    return (time.perf_counter() - t0) / repeats
