"""Layer migration between pipeline stages (paper §4.1, TPU-native).

A rebalance produces a new contiguous layers-per-stage split.  Because stage
state lives in statically-shaped slot buffers ``[S, L_max, ...]`` sharded
over the ``model`` axis, migration is a *gather along the stage axis* with a
host-computed (dst ← src) index map — XLA lowers it to collective-permute /
all-to-all between the affected stages.  **No recompilation**: the new
assignment arrays are ordinary inputs.

The same plan moves weights, optimizer moments, dynamism state, and (when
serving) the KV cache — everything keyed on [S, L_max, ...] leading dims.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BLOCK_PAD


@dataclasses.dataclass
class MigrationPlan:
    src_stage: np.ndarray     # int32 [S, L_max]
    src_slot: np.ndarray      # int32 [S, L_max]
    valid: np.ndarray         # bool  [S, L_max] (False = dst slot is PAD)
    moved_layers: int         # how many layers change stage
    moved_bytes_per_layer_hint: int = 0

    def as_jnp(self):
        return (jnp.asarray(self.src_stage), jnp.asarray(self.src_slot),
                jnp.asarray(self.valid))


def _locate(lps) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized locate: global layer g -> (stage[g], slot[g]) under a
    contiguous split."""
    lps = np.asarray(lps, np.int64)
    stages = np.repeat(np.arange(len(lps)), lps)
    starts = np.concatenate([[0], np.cumsum(lps)[:-1]])
    slots = np.arange(int(lps.sum())) - starts[stages]
    return stages, slots


def build_plan(old_lps: Sequence[int], new_lps: Sequence[int],
               L_max: int) -> MigrationPlan:
    """Map each destination slot to its source slot under contiguous splits.

    Global layer g lives at (stage, slot) = locate(lps, g); plan[dst] = src.
    Pure numpy prefix-sum construction — the controller rebuilds a plan
    every rebalance (each iteration for MoE/MoD, §3.3.1), so this is on the
    decision-latency critical path."""
    total_old, total_new = sum(old_lps), sum(new_lps)
    assert total_old == total_new, (total_old, total_new)
    S = len(new_lps)
    assert max(new_lps) <= L_max, "destination split exceeds slot capacity"

    src_st, src_sl = _locate(old_lps)
    dst_st, dst_sl = _locate(new_lps)
    src_stage = np.zeros((S, L_max), np.int32)
    src_slot = np.zeros((S, L_max), np.int32)
    valid = np.zeros((S, L_max), bool)
    src_stage[dst_st, dst_sl] = src_st
    src_slot[dst_st, dst_sl] = src_sl
    valid[dst_st, dst_sl] = True
    moved = int(np.sum(src_st != dst_st))
    return MigrationPlan(src_stage, src_slot, valid, moved)


def apply_plan(tree: Any, plan: MigrationPlan) -> Any:
    """Gather [S, L_max, ...] arrays to the new layout.  Invalid (PAD)
    destination slots keep zeros (their tags mark them inactive)."""
    ss, sl, valid = plan.as_jnp()

    def gather(a):
        out = a[ss, sl]                      # [S, L_max, ...]
        mask = valid.reshape(valid.shape + (1,) * (out.ndim - 2))
        return jnp.where(mask, out, jnp.zeros_like(out))

    return jax.tree.map(gather, tree)


def _apply_plan_to_opt(opt_state: Any, plan: MigrationPlan) -> Any:
    """Optimizer state mirrors the param tree; only its ``stages`` subtrees
    are stage-keyed — everything else (step count, embed/head moments) stays
    put."""
    def walk(node):
        if isinstance(node, dict):
            return {k: (apply_plan(v, plan) if k == "stages" else walk(v))
                    for k, v in node.items()}
        return node
    return walk(opt_state)


def migrate(params_stages: Dict[str, jax.Array], opt_stages: Any,
            dyn: Dict[str, jax.Array], old_lps: Sequence[int],
            new_lps: Sequence[int], tags_pattern: Sequence[int],
            L_max: int, cache: Any = None):
    """One-call migration of all stage-keyed state + fresh assignment arrays.

    Returns (params_stages, opt_stages, dyn, assignment, cache, plan)."""
    from repro.models.model import make_assignment  # avoid cycle
    plan = build_plan(old_lps, new_lps, L_max)
    new_params = apply_plan(params_stages, plan)
    new_opt = (_apply_plan_to_opt(opt_stages, plan)
               if opt_stages is not None else None)
    new_dyn = apply_plan(dyn, plan)
    new_cache = apply_plan(cache, plan) if cache is not None else None
    # assignment arrays rebuilt host-side from the pattern + new split
    S = len(new_lps)
    tags = np.full((S, L_max), BLOCK_PAD, np.int32)
    dst_st, dst_sl = _locate(new_lps)
    tags[dst_st, dst_sl] = np.asarray(tags_pattern, np.int32)
    lps = np.asarray(new_lps, np.int64)
    assignment = {
        "tags": jnp.asarray(tags),
        "num_active": jnp.asarray(lps, jnp.int32),
        "depth_base": jnp.asarray(
            np.concatenate([[0], np.cumsum(lps)[:-1]]), jnp.int32),
    }
    return new_params, new_opt, new_dyn, assignment, new_cache, plan
