"""DynMo controller — the autonomous loop of Fig. 2:

  (2) dynamism alters the model → (3) profile → (4) balance (+ optionally
  re-pack) → (5) migrate & continue.

The controller is transparent to the training loop: it consumes the per-slot
stats that every train_step already emits, decides on a host-side plan, and
applies one jitted migration.  Invoked every ``rebalance_every`` iterations
(per-iteration for MoE/MoD, thousands for pruning — paper §3.3.1); rebalance
is black-box w.r.t. the dynamism scheme.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import DistConfig, ModelConfig
from repro.core import balancer as bal
from repro.core import migration as mig
from repro.core import repack as rp
from repro.core.profiler import LayerProfile, profile_from_stats
from repro.dynamics.config import DynamicsConfig


@dataclasses.dataclass
class ControllerConfig:
    method: str = "diffusion"        # partition | diffusion
    cost_by: str = "time"            # time | param
    rebalance_every: int = 1
    imbalance_threshold: float = 0.05  # skip rebalance below this ΔL
    repack: bool = False
    repack_max_mem: float = float("inf")
    repack_target: int = 1
    mem_cap: float = float("inf")


@dataclasses.dataclass
class ControllerEvent:
    iteration: int
    imbalance_before: float
    imbalance_after: float
    moved_layers: int
    active_workers: int
    decision_s: float
    rebalanced: bool


class DynMoController:
    """Stateful controller owning the current assignment."""

    def __init__(self, cfg: ModelConfig, dcfg: DistConfig,
                 dyncfg: DynamicsConfig, ccfg: ControllerConfig,
                 layers_per_stage: Optional[Sequence[int]] = None):
        self.cfg, self.dcfg, self.dyncfg, self.ccfg = cfg, dcfg, dyncfg, ccfg
        from repro.models.model import uniform_boundaries
        self.lps: List[int] = list(
            layers_per_stage
            or uniform_boundaries(cfg.total_blocks(), dcfg.num_stages))
        self.pattern = cfg.block_pattern()
        self.events: List[ControllerEvent] = []
        self.active_workers = dcfg.num_stages

    # -- decision ----------------------------------------------------------
    def decide(self, profile: LayerProfile, iteration: int
               ) -> Tuple[Optional[List[int]], ControllerEvent]:
        t0 = time.perf_counter()
        costs = (profile.time_per_layer if self.ccfg.cost_by == "time"
                 else profile.param_bytes)
        loads = bal.stage_loads(costs, self.lps)
        imb_before = bal.imbalance(loads)
        new_lps: Optional[List[int]] = None
        imb_after = imb_before
        if imb_before > self.ccfg.imbalance_threshold:
            res = bal.balance(
                self.ccfg.method, costs, self.dcfg.num_stages,
                max_slots=self.dcfg.slots_for(self.cfg),
                mem=profile.param_bytes * 5.0, mem_cap=self.ccfg.mem_cap,
                init=self.lps if self.ccfg.method == "diffusion" else None)
            if res.imbalance < imb_before - 1e-9:
                new_lps = res.layers_per_stage
                imb_after = res.imbalance
        if new_lps is not None and self.ccfg.repack:
            mem_stage = bal.stage_loads(profile.param_bytes * 5.0, new_lps)
            plan = rp.repack_adjacent(mem_stage, new_lps,
                                      self.ccfg.repack_max_mem,
                                      self.ccfg.repack_target,
                                      max_layers=self.dcfg.slots_for(
                                          self.cfg))
            new_lps = plan.layers_per_stage
            self.active_workers = plan.num_active
        moved = 0
        if new_lps is not None:
            moved = mig.build_plan(self.lps, new_lps,
                                   self.dcfg.slots_for(self.cfg)).moved_layers
        ev = ControllerEvent(
            iteration=iteration, imbalance_before=imb_before,
            imbalance_after=imb_after, moved_layers=moved,
            active_workers=self.active_workers,
            decision_s=time.perf_counter() - t0,
            rebalanced=new_lps is not None)
        self.events.append(ev)
        return new_lps, ev

    # -- application -------------------------------------------------------
    def apply(self, new_lps: Sequence[int], params: Dict[str, Any],
              opt_state: Any, dyn: Dict[str, Any], cache: Any = None):
        """Migrate stage-keyed state to the new split; returns updated
        (params, opt_state, dyn, assignment, cache)."""
        stages, nopt, ndyn, assignment, ncache, plan = mig.migrate(
            params["stages"], opt_state, dyn, self.lps, new_lps,
            self.pattern, self.dcfg.slots_for(self.cfg), cache)
        self.lps = list(new_lps)
        params = dict(params)
        params["stages"] = stages
        return params, nopt, ndyn, assignment, ncache

    def step(self, iteration: int, stats: Dict[str, np.ndarray],
             tags: np.ndarray, num_micro: int, tokens: int, seq: int,
             params, opt_state, dyn, cache=None, frozen=None):
        """Full controller step: profile → decide → (maybe) migrate."""
        if iteration % max(1, self.ccfg.rebalance_every):
            return params, opt_state, dyn, None, cache, None
        profile = profile_from_stats(self.cfg, stats, tags, num_micro,
                                     tokens, seq, frozen=frozen)
        new_lps, ev = self.decide(profile, iteration)
        if new_lps is None:
            return params, opt_state, dyn, None, cache, ev
        params, opt_state, dyn, assignment, cache = self.apply(
            new_lps, params, opt_state, dyn, cache)
        return params, opt_state, dyn, assignment, cache, ev
