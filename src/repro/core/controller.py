"""DynMo controller — the autonomous loop of Fig. 2:

  (2) dynamism alters the model → (3) profile → (4) balance (+ optionally
  re-pack) → (5) migrate & continue.

The controller is transparent to the training loop: it consumes the per-slot
stats that every train_step already emits, decides on a host-side plan, and
applies one jitted migration.  Invoked every ``rebalance_every`` iterations
(per-iteration for MoE/MoD, thousands for pruning — paper §3.3.1); rebalance
is black-box w.r.t. the dynamism scheme.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import DistConfig, ModelConfig
from repro.core import balancer as bal
from repro.core import expert_layout as el
from repro.core import migration as mig
from repro.core import repack as rp
from repro.core.cost_model import MEM_STATE_FACTOR
from repro.core.profiler import LayerProfile, profile_from_stats
from repro.dynamics.config import DynamicsConfig
from repro.runtime.fault_tolerance import StragglerDetector


@dataclasses.dataclass
class ControllerConfig:
    method: str = "diffusion"        # partition | diffusion
    cost_by: str = "time"            # time | param
    rebalance_every: int = 1
    imbalance_threshold: float = 0.05  # skip rebalance below this ΔL
    repack: bool = False
    repack_policy: str = "adjacent"  # adjacent | first_fit
    # per-worker repack budget in ABSOLUTE bytes (the trainer converts its
    # capacity-factor CLI knob into this; one name end-to-end: CLI
    # --repack-mem-cap → run_training(repack_mem_cap) → this field)
    repack_mem_cap: float = float("inf")
    repack_target: int = 1
    mem_cap: float = float("inf")
    # live expert re-layout (MoE archs with the grouped pallas kernel)
    expert_relayout: bool = False
    expert_watermark: float = 2.0     # max/mean routed-load trigger
    expert_min_tokens: int = 16       # ignore windows below this total


@dataclasses.dataclass
class ControllerEvent:
    iteration: int
    imbalance_before: float
    imbalance_after: float
    moved_layers: int
    active_workers: int
    decision_s: float
    rebalanced: bool
    # MoE telemetry (defaults keep non-MoE call sites untouched)
    expert_skew: float = 0.0          # measured max/mean routed load
    expert_dropped: float = 0.0       # capacity-overflow drop fraction
    relayout: bool = False            # a re-layout plan was emitted


@dataclasses.dataclass
class ResizePlan:
    """A repack decision the elastic runtime can act on *live*: rebuild the
    pipeline onto ``target_stages`` workers and release the rest back to the
    job manager (paper §3.4, Alg. 2).  ``layers_per_stage`` is the compacted
    per-surviving-stage layer count in pipeline order; the engine may re-split
    uniformly if a count exceeds the shrunk world's slot capacity.

    ``released_stages`` names the logical stages the packing emptied (for
    logging/tests); the actually released WORKER ids are decided by the
    engine, which re-splits contiguously onto the stage→worker map's prefix
    and releases the tail — see ``ResizeEvent.workers`` for the ids handed
    to the pool.  ``mem_per_stage`` is the memory of the CONTIGUOUS groups
    the engine will execute (checked against the budget at decision time)."""
    iteration: int
    target_stages: int
    layers_per_stage: List[int]     # compact (no zero shadow stages)
    released_stages: List[int]      # stage indices deactivated by the plan
    policy: str
    mem_per_stage: List[float]      # memory of the executed contiguous split


class DynMoController:
    """Stateful controller owning the current assignment."""

    def __init__(self, cfg: ModelConfig, dcfg: DistConfig,
                 dyncfg: DynamicsConfig, ccfg: ControllerConfig,
                 layers_per_stage: Optional[Sequence[int]] = None,
                 straggler: Optional[StragglerDetector] = None):
        self.cfg, self.dcfg, self.dyncfg, self.ccfg = cfg, dcfg, dyncfg, ccfg
        self.straggler = straggler
        from repro.models.model import uniform_boundaries
        self.lps: List[int] = list(
            layers_per_stage
            or uniform_boundaries(cfg.total_blocks(), dcfg.num_stages))
        self.pattern = cfg.block_pattern()
        self.events: List[ControllerEvent] = []
        self.active_workers = dcfg.num_stages
        self.pending_resize: Optional[ResizePlan] = None
        # expert placement: the controller owns the LOGICAL layout; the
        # runtime mirrors it into dyn["expert_map"] at safe points.  The
        # layout is only committed when a plan is actually applied
        # (commit_relayout) so fenced-out plans never desync the two.
        self.expert_layout = (el.ExpertLayout.identity(cfg.num_experts)
                              if cfg.num_experts else None)
        self.pending_relayout: Optional[el.ExpertRelayoutPlan] = None
        self.relayouts: List[el.ExpertRelayoutPlan] = []

    # -- elastic runtime hooks --------------------------------------------
    def cadence(self, iteration: int) -> bool:
        """Whether the controller acts this iteration.  The training loop
        gates its device→host stats sync on this (paper §3.3.1: decision
        latency off the critical path)."""
        return iteration % max(1, self.ccfg.rebalance_every) == 0

    def take_resize(self) -> Optional[ResizePlan]:
        """Consume the pending repack decision (engine shrink trigger)."""
        plan, self.pending_resize = self.pending_resize, None
        return plan

    def take_expert_relayout(self) -> "Optional[el.ExpertRelayoutPlan]":
        """Consume the pending expert re-layout (safe-point apply)."""
        plan, self.pending_relayout = self.pending_relayout, None
        return plan

    def commit_relayout(self, plan: "el.ExpertRelayoutPlan"):
        """Record that a re-layout plan was actually applied to the model's
        expert_map — only now does the controller's notion of the layout
        advance (plans fenced out at a safe point never desync it)."""
        self.expert_layout = plan.new
        self.relayouts.append(plan)
        return self

    def rebind(self, dcfg: DistConfig, layers_per_stage: Sequence[int]):
        """Re-anchor the controller after the engine rebuilt the execution
        world (shrink/grow): new stage count, new split.  The expert layout
        survives — placement is per-expert, not per-stage, and the
        expert_map dyn leaf rides the resize like every other leaf."""
        self.dcfg = dcfg
        self.lps = list(layers_per_stage)
        self.active_workers = dcfg.num_stages
        self.pending_resize = None
        self.pending_relayout = None
        if self.straggler is not None:
            # per-stage EMAs are meaningless across a resize
            self.straggler.reset(dcfg.num_stages)

    # -- decision ----------------------------------------------------------
    def decide(self, profile: LayerProfile, iteration: int
               ) -> Tuple[Optional[List[int]], ControllerEvent]:
        t0 = time.perf_counter()
        self.pending_resize = None      # stale unconsumed plans don't linger
        self.pending_relayout = None
        expert_skew = 0.0
        if profile.expert_load is not None and self.expert_layout is not None:
            expert_skew, _ = el.measure_skew(profile.expert_load)
            if self.ccfg.expert_relayout:
                self.pending_relayout = el.build_relayout(
                    profile.expert_load, self.expert_layout,
                    watermark=self.ccfg.expert_watermark,
                    min_tokens=self.ccfg.expert_min_tokens,
                    iteration=iteration)
        costs = (profile.time_per_layer if self.ccfg.cost_by == "time"
                 else profile.param_bytes)
        if (self.straggler is not None and self.ccfg.cost_by == "time"
                and self.straggler.initialized
                and len(self.straggler.times) == len(self.lps)):
            # a persistent straggler appears to DynMo exactly like load
            # imbalance (paper §1): fold the measured-vs-modelled per-stage
            # slowdown into each of the stage's layers and let the ordinary
            # rebalance move layers off the slow worker
            expected = np.asarray(bal.stage_loads(costs, self.lps))
            slow = self.straggler.relative_slowdown(expected)
            costs = np.asarray(costs, dtype=np.float64) \
                * np.repeat(slow, self.lps)
        loads = bal.stage_loads(costs, self.lps)
        imb_before = bal.imbalance(loads)
        new_lps: Optional[List[int]] = None
        imb_after = imb_before
        if imb_before > self.ccfg.imbalance_threshold:
            res = bal.balance(
                self.ccfg.method, costs, self.dcfg.num_stages,
                max_slots=self.dcfg.slots_for(self.cfg),
                mem=profile.param_bytes * MEM_STATE_FACTOR,
                mem_cap=self.ccfg.mem_cap,
                init=self.lps if self.ccfg.method == "diffusion" else None)
            if res.imbalance < imb_before - 1e-9:
                new_lps = res.layers_per_stage
                imb_after = res.imbalance
        if self.ccfg.repack:
            # evaluated every cadence, not only after a rebalance: uniform
            # dynamism (e.g. global pruning) keeps the split balanced while
            # memory still shrinks — consolidation must fire regardless.
            cand = list(new_lps) if new_lps is not None else list(self.lps)
            mem_layers = profile.param_bytes * MEM_STATE_FACTOR
            mem_stage = bal.stage_loads(mem_layers, cand)
            # max_layers: counts bounded by the CURRENT world's slot
            # capacity, which every smaller world's capacity dominates
            # (slots_for grows as S shrinks) — the engine never has to
            # silently discard the plan's split as over-capacity
            plan = rp.repack(self.ccfg.repack_policy, mem_stage, cand,
                             self.ccfg.repack_mem_cap,
                             self.ccfg.repack_target,
                             max_layers=self.dcfg.slots_for(self.cfg))
            if plan.num_active < len(cand):
                compact = [plan.layers_per_stage[s] for s in range(len(cand))
                           if plan.active_workers[s]]
                # the engine executes the counts as a CONTIGUOUS split, which
                # for first_fit can group different layers than the packing
                # did — re-check the actual placement against the budget (a
                # group no heavier than today's worst stage is never a
                # regression even above the cap)
                contiguous_mem = bal.stage_loads(mem_layers, compact)
                limit = max(self.ccfg.repack_mem_cap, max(mem_stage))
                # repack-aware balancing: the packing only decided WHO
                # survives; the split the shrunk world actually executes is
                # re-balanced on the time cost vector (under the same
                # memory budget and the target world's slot capacity), so
                # the post-shrink pipeline starts load-balanced instead of
                # inheriting the merged groups' skew
                compact = self._balance_resize_split(
                    costs, mem_layers, compact, plan.num_active, limit)
                contiguous_mem = bal.stage_loads(mem_layers, compact)
                if all(m < limit for m in contiguous_mem):
                    self.pending_resize = ResizePlan(
                        iteration=iteration,
                        target_stages=plan.num_active,
                        layers_per_stage=compact,
                        released_stages=[s for s in range(len(cand))
                                         if not plan.active_workers[s]],
                        policy=self.ccfg.repack_policy,
                        mem_per_stage=[float(m) for m in contiguous_mem])
                    # the resize supersedes in-mesh migration: the engine's
                    # re-split moves every layer anyway, so applying a
                    # migration first would be double device data movement
                    # (and the event honestly reports that no in-mesh
                    # rebalance happened)
                    new_lps = None
                    imb_after = imb_before
        moved = 0
        if new_lps is not None:
            moved = mig.build_plan(self.lps, new_lps,
                                   self.dcfg.slots_for(self.cfg)).moved_layers
        # active_workers reports the CURRENT world — a pending ResizePlan is
        # only a decision until the engine executes it and calls rebind()
        ev = ControllerEvent(
            iteration=iteration, imbalance_before=imb_before,
            imbalance_after=imb_after, moved_layers=moved,
            active_workers=self.active_workers,
            decision_s=time.perf_counter() - t0,
            rebalanced=new_lps is not None,
            expert_skew=expert_skew,
            expert_dropped=profile.moe_drop_frac,
            relayout=self.pending_relayout is not None)
        self.events.append(ev)
        return new_lps, ev

    def _balance_resize_split(self, costs, mem_layers, compact,
                              target_stages: int, mem_cap: float
                              ) -> List[int]:
        """Fold the balancer's time cost vector into a resize's target
        split (ROADMAP "repack-aware balancing").  ``compact`` — the repack
        policy's merged per-survivor counts — is the fallback when the
        balanced split is infeasible (zero-layer stage, over budget) or no
        better; otherwise the balancer's minimal-bottleneck contiguous
        partition over the *surviving* worker count wins."""
        import dataclasses as _dc
        target_dcfg = _dc.replace(self.dcfg, num_stages=target_stages)
        try:
            res = bal.balance(
                self.ccfg.method, costs, target_stages,
                max_slots=target_dcfg.slots_for(self.cfg),
                mem=mem_layers, mem_cap=mem_cap,
                init=compact if self.ccfg.method == "diffusion" else None)
        except Exception:
            return compact
        balanced = list(res.layers_per_stage)
        if (len(balanced) != target_stages or min(balanced) < 1
                or sum(balanced) != sum(compact)):
            return compact
        balanced_fits = all(m < mem_cap for m in
                            bal.stage_loads(mem_layers, balanced))
        compact_fits = all(m < mem_cap for m in
                           bal.stage_loads(mem_layers, compact))
        if balanced_fits and not compact_fits:
            # the packing's counts regroup over budget when executed
            # contiguously (first_fit can do this) — a memory-feasible
            # balanced split rescues the consolidation even if its time
            # bottleneck is no better
            return balanced
        if (max(bal.stage_loads(costs, balanced))
                > max(bal.stage_loads(costs, compact)) - 1e-12):
            return compact
        return balanced

    # -- application -------------------------------------------------------
    def apply(self, new_lps: Sequence[int], params: Dict[str, Any],
              opt_state: Any, dyn: Dict[str, Any], cache: Any = None):
        """Migrate stage-keyed state to the new split; returns updated
        (params, opt_state, dyn, assignment, cache)."""
        stages, nopt, ndyn, assignment, ncache, plan = mig.migrate(
            params["stages"], opt_state, dyn, self.lps, new_lps,
            self.pattern, self.dcfg.slots_for(self.cfg), cache)
        self.lps = list(new_lps)
        params = dict(params)
        params["stages"] = stages
        return params, nopt, ndyn, assignment, ncache

    def step(self, iteration: int, stats: Dict[str, np.ndarray],
             tags: np.ndarray, num_micro: int, tokens: int, seq: int,
             params, opt_state, dyn, cache=None, frozen=None):
        """Full controller step: profile → decide → (maybe) migrate."""
        if not self.cadence(iteration):
            return params, opt_state, dyn, None, cache, None
        profile = profile_from_stats(self.cfg, stats, tags, num_micro,
                                     tokens, seq, frozen=frozen,
                                     bytes_per_param=self.dcfg
                                     .bytes_per_param)
        new_lps, ev = self.decide(profile, iteration)
        if new_lps is None:
            return params, opt_state, dyn, None, cache, ev
        params, opt_state, dyn, assignment, cache = self.apply(
            new_lps, params, opt_state, dyn, cache)
        return params, opt_state, dyn, assignment, cache, ev
