"""GPT configs matching the paper's own evaluation models (§5: seq 2048,
hidden 1024, 32 heads; depth varied). Used by the reproduction benchmarks."""
from repro.configs.base import ModelConfig, register


def _gpt(layers: int) -> ModelConfig:
    return ModelConfig(
        name=f"gpt-paper-{layers}l",
        family="dense",
        num_layers=layers,
        d_model=1024,
        num_heads=32,
        num_kv_heads=32,
        d_ff=4096,
        vocab_size=50257,
        head_dim=32,
        max_seq_len=2048,
        rope_theta=1e4,
        source="paper §5 (GPT-2 style)",
    )


GPT_PAPER_24L = register(_gpt(24))
GPT_PAPER_32L = register(_gpt(32))
GPT_PAPER_40L = register(_gpt(40))
GPT_PAPER_48L = register(_gpt(48))
