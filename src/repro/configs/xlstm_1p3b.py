"""xLSTM 1.3B — mLSTM + sLSTM blocks (7:1 pattern). [arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig, register

# sLSTM at positions spaced every 8th block (7:1 mLSTM:sLSTM), per paper recipe.
_SLSTM_POSITIONS = tuple(range(3, 48, 8))

XLSTM_1P3B = register(ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                   # xLSTM blocks have no separate FFN (gated proj inside)
    vocab_size=50304,
    head_dim=512,
    slstm_positions=_SLSTM_POSITIONS,
    source="arXiv:2405.04517; unverified",
))
