"""Command R+ 104B — dense GQA, no-bias, 256k vocab.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig, register

COMMAND_R_PLUS = register(ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    attn_bias=False,
    rope_theta=75e4,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
))
