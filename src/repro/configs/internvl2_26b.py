"""InternVL2 26B — InternLM2 LM backbone; InternViT frontend is a STUB
(input_specs provides precomputed patch embeddings). [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig, register

INTERNVL2_26B = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    num_patches=256,          # stubbed ViT output tokens per image
    rope_theta=1e6,
    source="arXiv:2404.16821; hf",
))
