"""Whisper large-v3 — encoder-decoder; conv frontend STUBBED (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig, register

WHISPER_LARGE_V3 = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,            # decoder layers
    num_encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    encoder_seq=1500,         # 30 s audio -> 1500 frames after conv stub
    max_seq_len=32768,        # honoured mechanically for assigned shapes
    source="arXiv:2212.04356; unverified",
))
