"""SmolLM 360M — llama-arch small with GQA kv=5.
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ModelConfig, register

SMOLLM_360M = register(ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    rope_theta=1e4,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
))
