"""Zamba2 1.2B — Mamba2 backbone with shared attention blocks.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, register

ZAMBA2_1P2B = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    d_conv=4,
    shared_attn_period=6,     # every 6th block invokes the shared attn block
    source="arXiv:2411.15242; hf",
))
