from repro.configs.base import (
    BLOCK_DEC, BLOCK_DENSE, BLOCK_ENC, BLOCK_HYBRID_ATTN, BLOCK_MAMBA,
    BLOCK_MLSTM, BLOCK_MOE, BLOCK_PAD, BLOCK_SLSTM, BLOCK_TYPE_NAMES,
    SHAPES, DistConfig, ModelConfig, ShapeConfig, get_config, list_configs,
    reduced_config, register,
)

__all__ = [
    "BLOCK_DEC", "BLOCK_DENSE", "BLOCK_ENC", "BLOCK_HYBRID_ATTN",
    "BLOCK_MAMBA", "BLOCK_MLSTM", "BLOCK_MOE", "BLOCK_PAD", "BLOCK_SLSTM",
    "BLOCK_TYPE_NAMES", "SHAPES", "DistConfig", "ModelConfig", "ShapeConfig",
    "get_config", "list_configs", "reduced_config", "register",
]
