"""Config dataclasses + registry for every assigned architecture.

A single ``ModelConfig`` describes any arch in the pool; family-specific
fields are optional.  ``ShapeConfig`` describes one input-shape cell,
``DistConfig`` the parallelism layout.  Configs are pure data — no jax
imports here, so importing a config never touches device state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Block types inside the padded-slot pipeline.  Each slot carries a type tag;
# the stage executor lax.switch-es on it.  Integer values are stable (they
# appear in checkpoints and migration plans).
# ---------------------------------------------------------------------------
BLOCK_PAD = 0          # inactive slot
BLOCK_DENSE = 1        # attention + dense MLP
BLOCK_MOE = 2          # attention + MoE FFN
BLOCK_MAMBA = 3        # Mamba2 SSM block
BLOCK_HYBRID_ATTN = 4  # Mamba block + shared-attention invocation (Zamba2)
BLOCK_MLSTM = 5        # xLSTM mLSTM block
BLOCK_SLSTM = 6        # xLSTM sLSTM block
BLOCK_ENC = 7          # encoder self-attn block (Whisper)
BLOCK_DEC = 8          # decoder self+cross-attn block (Whisper)

BLOCK_TYPE_NAMES = {
    BLOCK_PAD: "pad", BLOCK_DENSE: "dense", BLOCK_MOE: "moe",
    BLOCK_MAMBA: "mamba", BLOCK_HYBRID_ATTN: "hybrid_attn",
    BLOCK_MLSTM: "mlstm", BLOCK_SLSTM: "slstm",
    BLOCK_ENC: "enc", BLOCK_DEC: "dec",
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25   # GShard-style; tokens over capacity
                                        # are dropped (residual passthrough)
    # attention flavor
    sliding_window: int = 0          # 0 = full attention
    attn_bias: bool = False
    # SSM / hybrid
    ssm_state: int = 0
    d_conv: int = 4
    shared_attn_period: int = 0      # Zamba2: every k-th block invokes shared attn
    # xLSTM: fraction/positions of sLSTM blocks
    slstm_positions: Tuple[int, ...] = ()
    # enc-dec (Whisper)
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # frames after conv frontend (stub input)
    # VLM
    num_patches: int = 0             # vision prefix tokens (stub input)
    # misc
    max_seq_len: int = 1 << 20
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def is_subquadratic(self) -> bool:
        """Whether long-context (500k) shapes are runnable per the task spec:
        SSM/hybrid/linear-attn run; sliding-window attention counts too."""
        return self.family in ("hybrid", "ssm") or self.sliding_window > 0

    # -- parameter counting ------------------------------------------------
    def params_per_block(self, block_type: int) -> int:
        d, h = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * (nq * h) + 2 * d * (nkv * h) + (nq * h) * d
        dense_ffn = 3 * d * self.d_ff                  # SwiGLU: wi, wg, wo
        norms = 2 * d
        if block_type == BLOCK_DENSE:
            return attn + dense_ffn + norms
        if block_type == BLOCK_MOE:
            router = d * self.num_experts
            return attn + self.num_experts * dense_ffn + router + norms
        if block_type in (BLOCK_MAMBA, BLOCK_HYBRID_ATTN):
            d_in = 2 * d                               # expand factor 2
            nheads = max(1, d_in // 64)
            mamba = (d * (2 * d_in + 2 * self.ssm_state * (d_in // 64 if False else 1))
                     )  # refined below
            # canonical Mamba2 param count: in_proj d->(2*d_in + 2*n_groups*state + nheads)
            n_groups = 1
            in_proj = d * (2 * d_in + 2 * n_groups * self.ssm_state + nheads)
            conv = self.d_conv * (d_in + 2 * n_groups * self.ssm_state)
            out_proj = d_in * d
            extra = 3 * nheads                          # A, D, dt_bias
            base = in_proj + conv + out_proj + extra + norms
            if block_type == BLOCK_HYBRID_ATTN:
                return base                             # shared attn counted once globally
            return base
        if block_type == BLOCK_MLSTM:
            d_in = 2 * d
            proj = d * 2 * d_in + d_in * d              # up (gated) + down
            qkv = 3 * d_in * (d_in // max(1, nq))       # block-diagonal per head
            gates = 2 * d_in + d_in
            return proj + qkv + gates + norms
        if block_type == BLOCK_SLSTM:
            # 4 gates, recurrent + input weights at model dim + ffn
            return 8 * d * d + 2 * d * int(d * 4 / 3) + norms
        if block_type == BLOCK_ENC:
            return attn + 2 * d * self.d_ff + d * self.d_ff + norms
        if block_type == BLOCK_DEC:
            cross = attn
            return 2 * attn + 2 * d * self.d_ff + d * self.d_ff + 3 * d
        return 0

    def block_pattern(self) -> List[int]:
        """Global layer sequence of block type tags (length = total blocks)."""
        if self.is_encdec:
            return ([BLOCK_ENC] * self.num_encoder_layers
                    + [BLOCK_DEC] * self.num_layers)
        if self.family == "moe":
            return [BLOCK_MOE] * self.num_layers
        if self.family == "hybrid":
            out = []
            for i in range(self.num_layers):
                if self.shared_attn_period and (i % self.shared_attn_period
                                                == self.shared_attn_period // 2):
                    out.append(BLOCK_HYBRID_ATTN)
                else:
                    out.append(BLOCK_MAMBA)
            return out
        if self.family == "ssm":
            return [BLOCK_SLSTM if i in self.slstm_positions else BLOCK_MLSTM
                    for i in range(self.num_layers)]
        return [BLOCK_DENSE] * self.num_layers

    def total_blocks(self) -> int:
        return len(self.block_pattern())

    def param_count(self) -> int:
        body = sum(self.params_per_block(t) for t in self.block_pattern())
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        shared = 0
        if self.family == "hybrid" and self.shared_attn_period:
            d, h = self.d_model, self.resolved_head_dim
            shared = (d * self.num_heads * h + 2 * d * self.num_kv_heads * h
                      + self.num_heads * h * d + 2 * d)
        final_norm = self.d_model
        return body + emb + head + shared + final_norm

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only experts_per_token experts)."""
        if self.family != "moe":
            return self.param_count()
        total = self.param_count()
        dense_ffn = 3 * self.d_model * self.d_ff
        inactive = (self.num_experts - self.experts_per_token) * dense_ffn
        return total - inactive * self.num_layers


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# bytes per element for every param dtype the trainer supports; repack memory
# budgets and profiler byte vectors must use the *configured* dtype, not a
# hard-coded bf16 assumption (the CLI trainer runs float32)
DTYPE_BYTES: Dict[str, int] = {
    "float32": 4, "bfloat16": 2, "float16": 2, "float64": 8,
}


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Parallelism layout knobs."""
    num_stages: int = 16           # model-axis size (pipeline)
    num_micro: int = 32            # microbatches per step
    slot_slack: int = 1            # extra layer slots per stage beyond ceil(L/S)
    fsdp: bool = True              # shard weights over data axis (ZeRO-3)
    expert_parallel: bool = True   # MoE experts over data axis
    remat: str = "block"           # none | block | full
    slot_exec: str = "masked_scan" # masked_scan | bounded_loop
    unroll_ticks: bool = False     # unroll schedule loop (exact cost analysis)
    unroll_slots: bool = False
    param_dtype: str = "bfloat16"
    kernel_impl: str = "scan"      # reference | scan | pallas — attention +
                                   # SwiGLU inner impl: "reference" is the
                                   # O(s^2) oracle, "scan" the pure-JAX flash
                                   # scan, "pallas" the block-skipping TPU
                                   # kernels (interpret mode off-TPU); see
                                   # DESIGN.md §kernel dispatch
    optimizer: str = "adamw"       # adamw | adafactor
    grad_compression: str = "none" # none | topk | int8
    collective_matmul: bool = False
    seq_shard: bool = False        # shard long sequences over data axis
    pin_carry_sharding: bool = True  # with_sharding_constraint on the
                                     # pipeline carry at tick boundaries —
                                     # stops XLA auto-sharding's involuntary
                                     # full-rematerialization fallback

    @property
    def num_slots(self) -> int:
        raise NotImplementedError("use slots_for(model_cfg)")

    @property
    def bytes_per_param(self) -> int:
        return DTYPE_BYTES.get(self.param_dtype, 2)

    def slots_for(self, mc: ModelConfig) -> int:
        return math.ceil(mc.total_blocks() / self.num_stages) + self.slot_slack


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "mixtral_8x7b", "mixtral_8x22b", "llama3_405b", "command_r_plus_104b",
    "smollm_360m", "deepseek_coder_33b", "internvl2_26b", "zamba2_1p2b",
    "xlstm_1p3b", "whisper_large_v3", "gpt_paper",
]


def _load_all() -> None:
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def reduced_config(mc: ModelConfig, num_layers: int = 4, d_model: int = 64,
                   num_heads: int = 4, num_kv_heads: int = 2, d_ff: int = 128,
                   vocab_size: int = 256) -> ModelConfig:
    """Shrink an arch config to smoke-test size, preserving its family shape."""
    kv = min(num_kv_heads, num_heads)
    repl = dict(
        name=mc.name + "-reduced", num_layers=num_layers, d_model=d_model,
        num_heads=num_heads, num_kv_heads=kv, d_ff=d_ff,
        vocab_size=vocab_size, head_dim=d_model // num_heads,
        max_seq_len=4096,
    )
    if mc.num_experts:
        repl["num_experts"] = min(4, mc.num_experts)
        repl["experts_per_token"] = min(2, mc.experts_per_token)
        # drop-free capacity so incremental decode == full re-forward in
        # smoke tests (capacity dropping makes them legitimately differ)
        repl["moe_capacity_factor"] = 4.0
    if mc.sliding_window:
        repl["sliding_window"] = 32
    if mc.ssm_state:
        repl["ssm_state"] = 16
    if mc.shared_attn_period:
        repl["shared_attn_period"] = 2
    if mc.slstm_positions:
        repl["slstm_positions"] = tuple(
            p for p in (1,) if p < num_layers)
    if mc.num_encoder_layers:
        repl["num_encoder_layers"] = max(2, num_layers // 2)
        repl["encoder_seq"] = 16
    if mc.num_patches:
        repl["num_patches"] = 8
    return dataclasses.replace(mc, **repl)
