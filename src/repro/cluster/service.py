"""Asynchronous DynMo decision service (paper §3.3.1).

The profile→decide loop must stay off the training critical path so that
per-iteration cadence (MoE/MoD) pays zero step latency.  ``ControlPlane``
runs ``DynMoController.decide`` on a background thread behind a
double-buffered stats mailbox:

  * the training thread *publishes* the host-synced ``[S, L_max]`` stats
    snapshot on controller cadence — an O(1) pointer swap, never a wait on
    the decision;
  * the worker thread folds the snapshot through the profiler, runs the
    balancer/repack decision, and posts the plan into a latest-wins outbox;
  * the training thread *polls* the outbox at its next safe point (between
    steps) and applies the plan there.

Epoch fencing: every engine resize (shrink/grow/evict) advances the world
epoch.  A plan decided against a stale world — wrong stage count or layer
split after a resize — is rejected by epoch at ``poll`` (or skipped before
deciding, when the plane can see the live epoch via ``epoch_fn``); it is
never applied.

In ``async_mode=False`` the same ``_decide`` body runs synchronously on the
publishing thread, so the inline and asynchronous paths produce bit-identical
decisions from the same snapshot by construction (parity-tested).
``drain()`` makes the asynchronous mode deterministic for tests and loss
parity runs: it blocks until the worker has emptied the mailbox.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.controller import (ControllerEvent, DynMoController,
                                   ResizePlan)
from repro.core.expert_layout import ExpertRelayoutPlan
from repro.core.profiler import profile_from_stats


@dataclasses.dataclass
class StatsSnapshot:
    """Host-side view of one profiling iteration, tagged with the engine
    epoch it was observed in.  Everything the worker thread needs to run
    profile→decide without touching live training state."""
    iteration: int
    epoch: int
    stats: Dict[str, np.ndarray]        # folded [S, L_max, ...] (host)
    tags: np.ndarray                    # [S, L_max] slot→global-layer map
    num_micro: int
    tokens: int
    seq: int
    frozen: Optional[np.ndarray] = None
    stage_times: Optional[np.ndarray] = None   # measured per-stage seconds
    #   (feeds the controller's StragglerDetector when one is attached)


@dataclasses.dataclass
class DecisionPlan:
    """One controller decision, fenced by the epoch of the world it was
    decided against.  Either ``new_lps`` (in-mesh migration) or ``resize``
    (live shrink) is set — the controller never emits both.
    ``expert_relayout`` is orthogonal (it moves no stage state, only the
    expert_map dyn leaf) and may accompany either."""
    epoch: int
    iteration: int
    new_lps: Optional[List[int]]
    resize: Optional[ResizePlan]
    event: ControllerEvent
    decide_s: float                     # worker-side profile+decide seconds
    expert_relayout: Optional[ExpertRelayoutPlan] = None


class ControlPlane:
    """Runs the controller's decisions off the training thread.

    The training thread talks to the controller ONLY through this object:
    ``publish`` / ``poll`` for decisions, ``apply`` / ``rebind`` /
    ``with_ctrl`` for safe-point state mutation — all controller access is
    serialized on one lock, so a decide in flight never observes a
    half-applied migration.
    """

    def __init__(self, ctrl: DynMoController, *, async_mode: bool = True,
                 epoch_fn: Optional[Callable[[], int]] = None,
                 name: str = "dynmo-control-plane"):
        self.ctrl = ctrl
        self.async_mode = async_mode
        self.epoch_fn = epoch_fn
        self._ctrl_lock = threading.Lock()   # decide vs apply/rebind
        self._cv = threading.Condition()     # guards inbox/outbox/busy/stop
        self._inbox: Optional[StatsSnapshot] = None
        self._outbox: Optional[DecisionPlan] = None
        self._busy = False
        self._stop = False
        self._error: Optional[BaseException] = None
        # counters (telemetry + tests)
        self.published = 0
        self.decided = 0
        self.dropped = 0            # snapshots overwritten before consumption
        self.stale_rejected = 0     # plans fenced off by epoch
        self._thread: Optional[threading.Thread] = None
        if async_mode:
            self._thread = threading.Thread(target=self._loop, name=name,
                                            daemon=True)
            self._thread.start()

    # -- training-thread API ----------------------------------------------
    def publish(self, snap: StatsSnapshot) -> None:
        """Hand a stats snapshot to the decision worker.  Never blocks on
        the decision; an unconsumed older snapshot is overwritten
        (latest-wins — the controller always decides on the freshest
        profile, paper §3.3.1)."""
        self.published += 1
        if not self.async_mode:
            plan = self._decide(snap)
            with self._cv:
                self._outbox = plan
            return
        with self._cv:
            if self._inbox is not None:
                self.dropped += 1
            self._inbox = snap
            self._cv.notify_all()

    def poll(self, epoch: int) -> Optional[DecisionPlan]:
        """Fetch the newest finished plan, or None.  ``epoch`` is the
        caller's CURRENT world epoch: a plan decided against an older world
        is rejected here and never reaches the training state."""
        self._reraise()
        with self._cv:
            plan, self._outbox = self._outbox, None
        if plan is None:
            return None
        if plan.epoch != epoch:
            self.stale_rejected += 1
            return None
        return plan

    def inject_resize(self, epoch: int, target_stages: int, *,
                      policy: str = "preempt") -> DecisionPlan:
        """Put an externally-originated shrink into the outbox (DESIGN.md
        §14): a cluster-scheduler preemption arrives through the SAME
        epoch-fenced mailbox as controller decisions, so the training loop
        applies it at its next safe point with zero new machinery — and a
        plan fenced off by a concurrent resize is simply re-injected at the
        next directive poll (the scheduler's directives are level-
        triggered), never lost.  Latest-wins like any other plan."""
        plan = DecisionPlan(
            epoch=epoch, iteration=-1, new_lps=None,
            resize=ResizePlan(iteration=-1, target_stages=target_stages,
                              layers_per_stage=None, released_stages=[],
                              policy=policy, mem_per_stage=[]),
            event=None, decide_s=0.0)
        with self._cv:
            self._outbox = plan
        return plan

    def drain(self, timeout: float = 60.0) -> None:
        """Block until the worker has consumed the inbox and finished any
        in-flight decision.  Deterministic mode: publish → drain → poll is
        step-for-step identical to the inline path (used by the parity
        tests and ``run_training(async_drain=True)``)."""
        if not self.async_mode:
            return
        deadline = time.monotonic() + timeout
        with self._cv:
            while self._inbox is not None or self._busy:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("control-plane drain timed out")
                self._cv.wait(min(0.05, remaining))
        self._reraise()

    def _reraise(self) -> None:
        """Surface a worker-thread failure on the training thread — an
        async run must crash as loudly as the inline path would, not
        silently stop making decisions."""
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                "control-plane decision worker failed") from err

    # -- safe-point state mutation ----------------------------------------
    def apply(self, plan: DecisionPlan, params, opt_state, dyn, cache=None):
        """Apply a rebalance plan's migration at a safe point (training
        thread).  Serialized against in-flight decides."""
        with self._ctrl_lock:
            return self.ctrl.apply(plan.new_lps, params, opt_state, dyn,
                                   cache)

    def rebind(self, dcfg, layers_per_stage) -> None:
        """Re-anchor the controller after an engine resize (new world)."""
        with self._ctrl_lock:
            self.ctrl.rebind(dcfg, layers_per_stage)

    def with_ctrl(self, fn: Callable[[DynMoController], Any]) -> Any:
        """Run ``fn(ctrl)`` under the controller lock — for any other
        mutation the training loop needs (e.g. disabling repack after a
        grow)."""
        with self._ctrl_lock:
            return fn(self.ctrl)

    # -- decision body (shared by inline and worker paths) -----------------
    def _decide(self, snap: StatsSnapshot) -> Optional[DecisionPlan]:
        if self.epoch_fn is not None and self.epoch_fn() != snap.epoch:
            # the world already changed under this snapshot: don't waste a
            # decide on it (and don't pollute controller state/events)
            self.stale_rejected += 1
            return None
        t0 = time.perf_counter()
        from repro.obs.trace import current_tracer
        tr = current_tracer()
        sp = (tr.span("controlplane.decide", cat="controller",
                      iteration=snap.iteration, epoch=snap.epoch)
              if tr is not None else None)
        with self._ctrl_lock:
            ctrl = self.ctrl
            if (snap.stage_times is not None
                    and ctrl.straggler is not None):
                ctrl.straggler.update(snap.stage_times)
            profile = profile_from_stats(
                ctrl.cfg, snap.stats, snap.tags, snap.num_micro,
                snap.tokens, snap.seq, frozen=snap.frozen,
                bytes_per_param=ctrl.dcfg.bytes_per_param)
            new_lps, ev = ctrl.decide(profile, snap.iteration)
            resize = ctrl.take_resize()
            relayout = ctrl.take_expert_relayout()
        self.decided += 1
        if sp is not None:
            sp.end(rebalanced=bool(ev is not None and ev.rebalanced),
                   resize=resize is not None)
        return DecisionPlan(epoch=snap.epoch, iteration=snap.iteration,
                            new_lps=new_lps, resize=resize, event=ev,
                            decide_s=time.perf_counter() - t0,
                            expert_relayout=relayout)

    # -- worker thread -----------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cv:
                while self._inbox is None and not self._stop:
                    self._cv.wait(0.2)
                if self._stop:
                    return
                snap, self._inbox = self._inbox, None
                self._busy = True
            plan = None
            try:
                plan = self._decide(snap)
            except BaseException as e:   # noqa: BLE001 — handed to trainer
                self._error = e
            finally:
                with self._cv:
                    if plan is not None:
                        self._outbox = plan
                    self._busy = False
                    self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
