"""HTTP job-manager transport (DESIGN.md §14).

The file transport (``cluster.rpc``) stays the crash-tested test double;
this module is the k8s-operator-shaped real thing: one
``ClusterScheduler`` served over plain HTTP (stdlib ``http.server`` +
``urllib`` — no dependencies), so N Sessions in N *processes* — or N
machines — contend over one pool.  Wire protocol: ``POST /rpc`` with a
JSON body ``{"op": ..., "seq": ..., "client": ..., ...}``; the response
is the scheduler's response dict.  ``GET /healthz`` answers liveness.

Exactly-once semantics carry over from the file transport, reshaped for
many clients: the idempotency key is ``(client, seq)`` instead of the
bare sequence number (two tenants both on seq 1 must not collide).  The
server journals every executed response before replying; a client retry
re-sends the SAME ``(client, seq)`` and is answered from the journal, so
ops never execute twice even when the response was lost in flight.  All
scheduler access is serialized under one lock — arbitration stays
deterministic no matter how requests interleave on the wire.

The client (``HttpJobManager``) mirrors ``FileJobManager``: same retry/
backoff/circuit-breaker skeleton, same ``JobManagerClient`` surface plus
the ``TenantVerbsMixin`` verbs.  ``shutdown_on_close`` defaults to False
— tenants of a shared manager deregister on close; only the process that
spawned the manager tears it down.
"""
from __future__ import annotations

import argparse
import http.server
import json
import os
import random
import socketserver
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request
from typing import Dict, List, Optional, Sequence, Tuple

from repro.cluster.rpc import (CircuitBreaker, JobManagerUnavailable,
                               TenantVerbsMixin, _atomic_write_json,
                               _read_json)
from repro.cluster.scheduler import ClusterScheduler
from repro.runtime.fault_tolerance import WorkerPool


class HttpJobManager(TenantVerbsMixin):
    """HTTP-backed ``JobManagerClient``: the pool lives behind a URL."""

    def __init__(self, url: str, timeout_s: float = 30.0, *,
                 retries: int = 3, backoff_s: float = 0.05,
                 jitter_seed: int = 0, breaker_after: int = 2,
                 breaker_probe_every: int = 4,
                 shutdown_on_close: bool = False,
                 client_id: Optional[str] = None):
        self.url = url.rstrip("/")
        self.timeout_s = timeout_s       # TOTAL budget, split over retries
        self.retries = max(1, retries)
        self.backoff_s = backoff_s
        self._jitter = random.Random(jitter_seed)
        self.breaker = CircuitBreaker(breaker_after, breaker_probe_every)
        self.shutdown_on_close = shutdown_on_close
        # the (client, seq) pair is the idempotency key; the pid makes the
        # namespace unique per process even before register_tenant names us
        self.client_id = client_id or f"pid{os.getpid()}"
        self.tenant = None
        self._seq = 0
        self._active: Optional[int] = None
        self.log: List[str] = []         # client-side mirror of transitions
        self.rpc_stats: Dict[str, int] = {"calls": 0, "retries": 0,
                                          "timeouts": 0}

    # -- transport ---------------------------------------------------------
    def _roundtrip(self, obj: dict, deadline: float) -> dict:
        body = json.dumps(obj).encode()
        req = urllib.request.Request(
            self.url + "/rpc", data=body,
            headers={"Content-Type": "application/json"}, method="POST")
        budget = max(0.05, deadline - time.monotonic())
        with urllib.request.urlopen(req, timeout=budget) as resp:
            return json.loads(resp.read().decode())

    def _call(self, op: str, **payload) -> dict:
        if not self.breaker.allow():
            raise JobManagerUnavailable(
                f"job manager circuit open ({self.breaker.failures} "
                f"consecutive failures): {op} skipped")
        self._seq += 1
        seq = self._seq
        self.rpc_stats["calls"] += 1
        obj = {"op": op, "seq": seq, "client": self.client_id, **payload}
        # ship the caller's span context (client_id + seq ride along) so
        # the scheduler can attribute the op and forward a steal's context
        # to its preemption victim (DESIGN.md §15)
        from repro.obs.trace import current_tracer
        tr = current_tracer()
        if tr is not None:
            obj["trace"] = tr.rpc_ctx(op, transport="http",
                                      client=self.client_id, seq=seq)
        per_attempt = self.timeout_s / self.retries
        last_err: Optional[Exception] = None
        for attempt in range(self.retries):
            # retries re-send the SAME (client, seq): the server dedups on
            # it, so a retried-but-actually-executed op is answered from
            # its journal, never run twice
            try:
                out = self._roundtrip(obj,
                                      time.monotonic() + per_attempt)
            except (urllib.error.URLError, OSError, TimeoutError,
                    ConnectionError) as e:
                last_err = e
                self.rpc_stats["timeouts"] += 1
                if attempt + 1 < self.retries:
                    self.rpc_stats["retries"] += 1
                    time.sleep(self.backoff_s * (2 ** attempt)
                               * (1.0 + self._jitter.random()))
                continue
            self.breaker.success()
            if "active" in out:
                self._active = int(out["active"])
            if out.get("error"):
                raise RuntimeError(
                    f"job manager rejected {op}: {out['error']}")
            return out
        self.breaker.failure()
        raise JobManagerUnavailable(
            f"job manager did not answer {op} (seq {seq}) within "
            f"{self.timeout_s}s across {self.retries} attempts — is the "
            f"server at {self.url!r} up? ({last_err!r})")

    # -- JobManagerClient --------------------------------------------------
    def release(self, workers: Sequence[int]) -> List[int]:
        out = self._call("release", workers=[int(w) for w in workers],
                         **self._tenant_kw())
        released = [int(w) for w in out["released"]]
        self.log.extend(f"release:{w}" for w in released)
        return released

    def request(self, n: int) -> List[int]:
        out = self._call("request", n=int(n), **self._tenant_kw())
        granted = [int(w) for w in out["granted"]]
        self.log.extend(f"grant:{w}" for w in granted)
        return granted

    def fail(self, worker: int) -> None:
        self._call("fail", worker=int(worker), **self._tenant_kw())
        self.log.append(f"fail:{worker}")

    @property
    def num_active(self) -> int:
        if self._active is None:
            try:
                self._call("status")
            except JobManagerUnavailable:
                return -1
        return int(self._active)

    def close(self) -> None:
        prev = self.timeout_s
        self.timeout_s = min(prev, 2.0)
        try:
            if self.tenant:
                self.deregister()        # grants flow back to the pool
            if self.shutdown_on_close:
                self._call("shutdown")
        except (TimeoutError, OSError, RuntimeError):
            pass                         # server already gone — fine
        finally:
            self.timeout_s = prev


class _SchedulerHTTPServer(socketserver.ThreadingMixIn,
                           http.server.HTTPServer):
    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, addr, handler, sched: ClusterScheduler,
                 state_path: Optional[str]):
        super().__init__(addr, handler)
        self.sched = sched
        self.state_path = state_path
        self.lock = threading.Lock()     # serializes ALL scheduler access
        self.answered: Dict[str, dict] = {}
        self.last_traffic = time.monotonic()
        self.shutting_down = False


class _Handler(http.server.BaseHTTPRequestHandler):
    server: _SchedulerHTTPServer

    def log_message(self, fmt, *args):   # quiet; the journal is the log
        pass

    def _reply(self, code: int, obj: dict) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):
        if self.path == "/healthz":
            with self.server.lock:
                self._reply(200, {"ok": True,
                                  "active": self.server.sched.pool
                                  .num_active})
        elif self.path == "/metrics":
            # Prometheus text exposition derived from the SAME events list
            # the `metrics` RPC verb returns — scraped counters can never
            # disagree with the events stream (DESIGN.md §15)
            from repro.obs.metrics import scheduler_to_prometheus
            with self.server.lock:
                body = scheduler_to_prometheus(self.server.sched).encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
        else:
            self._reply(404, {"error": "not found"})

    def do_POST(self):
        if self.path != "/rpc":
            self._reply(404, {"error": "not found"})
            return
        try:
            n = int(self.headers.get("Content-Length", "0"))
            req = json.loads(self.rfile.read(n).decode())
        except (ValueError, json.JSONDecodeError):
            self._reply(400, {"error": "bad request body"})
            return
        key = f"{req.get('client', '?')}:{req.get('seq', '?')}"
        srv = self.server
        with srv.lock:
            srv.last_traffic = time.monotonic()
            if key in srv.answered:
                # client retry after response loss: re-serve the journaled
                # answer — the op is NOT re-executed
                self._reply(200, srv.answered[key])
                return
            out = srv.sched.handle(req)
            # journal BEFORE replying (same exactly-once contract as the
            # file transport): a crash between journal and reply makes the
            # retry hit the journal, not the scheduler
            srv.answered[key] = out
            if srv.state_path:
                sd = srv.sched.state_dict()
                _atomic_write_json(srv.state_path,
                                   {"pool": sd["pool"],
                                    "tenants": sd["tenants"],
                                    "answered": srv.answered})
            if req.get("op") == "shutdown":
                srv.shutting_down = True
        self._reply(200, out)
        if srv.shutting_down:
            threading.Thread(target=srv.shutdown, daemon=True).start()


def serve_http_manager(workers: int, *, spares: int = 0,
                       host: str = "127.0.0.1", port: int = 0,
                       state_path: Optional[str] = None,
                       addr_file: Optional[str] = None,
                       idle_timeout_s: Optional[float] = None
                       ) -> WorkerPool:
    """Serve one ``ClusterScheduler`` over HTTP until a ``shutdown`` op
    (or ``idle_timeout_s`` with no traffic).  Binds ``port`` (0 = pick a
    free one) and, when ``addr_file`` is given, atomically publishes
    ``{"url": ...}`` there so a spawning parent can discover the address.
    Returns the final pool for inspection when called in-process."""
    sched: Optional[ClusterScheduler] = None
    if state_path and os.path.exists(state_path):
        try:
            js = _read_json(state_path)
            sched = ClusterScheduler.from_state(
                {"pool": js["pool"], "tenants": js.get("tenants", [])})
        except (json.JSONDecodeError, OSError, KeyError):
            sched = None
    if sched is None:
        sched = ClusterScheduler(WorkerPool(workers, spares=spares))
    srv = _SchedulerHTTPServer((host, port), _Handler, sched, state_path)
    if state_path and os.path.exists(state_path):
        try:
            srv.answered = dict(_read_json(state_path).get("answered", {}))
        except (json.JSONDecodeError, OSError):
            pass
    url = f"http://{srv.server_address[0]}:{srv.server_address[1]}"
    if addr_file:
        _atomic_write_json(addr_file, {"url": url})
    stop_watchdog = threading.Event()
    if idle_timeout_s is not None:
        def _watchdog():
            while not stop_watchdog.wait(min(idle_timeout_s, 0.5)):
                with srv.lock:
                    idle = time.monotonic() - srv.last_traffic
                if idle > idle_timeout_s:
                    srv.shutdown()
                    return
        threading.Thread(target=_watchdog, daemon=True).start()
    try:
        srv.serve_forever(poll_interval=0.05)
    finally:
        stop_watchdog.set()
        srv.server_close()
    return sched.pool


def spawn_http_manager(run_dir: str, workers: int, *, spares: int = 0,
                       idle_timeout_s: float = 300.0,
                       startup_timeout_s: float = 20.0
                       ) -> Tuple[subprocess.Popen, str]:
    """Start the HTTP job manager as a separate process and return
    ``(proc, url)`` once it is accepting connections.  The idle timeout is
    a safety net so an orphaned server never outlives its job by much."""
    os.makedirs(run_dir, exist_ok=True)
    addr_file = os.path.join(run_dir, "addr.json")
    if os.path.exists(addr_file):
        os.unlink(addr_file)
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    proc = subprocess.Popen(
        [sys.executable, "-c",
         "from repro.cluster.http_rpc import main; main()",
         "--workers", str(workers), "--spares", str(spares),
         "--port", "0", "--addr-file", addr_file,
         "--state", os.path.join(run_dir, "state.json"),
         "--idle-timeout", str(idle_timeout_s)],
        env={**os.environ,
             "PYTHONPATH": os.pathsep.join(
                 p for p in [os.environ.get("PYTHONPATH"), src_root]
                 if p)})
    deadline = time.monotonic() + startup_timeout_s
    while not os.path.exists(addr_file):
        if proc.poll() is not None:
            raise RuntimeError(
                f"http job manager died on startup (rc={proc.returncode})")
        if time.monotonic() > deadline:
            proc.kill()
            raise TimeoutError("http job manager never published its "
                               f"address to {addr_file!r}")
        time.sleep(0.02)
    url = _read_json(addr_file)["url"]
    return proc, url


def main() -> None:
    ap = argparse.ArgumentParser(description="HTTP job manager")
    ap.add_argument("--workers", type=int, required=True)
    ap.add_argument("--spares", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--addr-file", default=None)
    ap.add_argument("--state", default=None,
                    help="journal path for exactly-once crash recovery")
    ap.add_argument("--idle-timeout", type=float, default=None)
    args = ap.parse_args()
    pool = serve_http_manager(args.workers, spares=args.spares,
                              host=args.host, port=args.port,
                              state_path=args.state,
                              addr_file=args.addr_file,
                              idle_timeout_s=args.idle_timeout)
    print(f"job manager done: active={pool.num_active} "
          f"released={sorted(pool.released)} dead={sorted(pool.dead)}")


if __name__ == "__main__":
    main()
