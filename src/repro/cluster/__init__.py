"""DynMo cluster control plane: the layer between the training loop and
the job manager.

  service    — ControlPlane: off-thread profile→decide with a double-buffered
               stats mailbox and epoch-fenced plan application (§3.3.1)
  autoscaler — signal-driven shrink/grow policy (heartbeats + throughput
               watermark with hysteresis) replacing CLI-driven growth
  rpc        — JobManagerClient boundary: in-process WorkerPool wrapper and
               a file-backed stub shaped like a k8s-operator/Ray endpoint
"""
from repro.cluster.autoscaler import (Autoscaler, AutoscalerConfig,
                                      ScaleDecision)
from repro.cluster.rpc import (FileJobManager, InProcessJobManager,
                               JobManagerClient, serve_file_manager,
                               spawn_file_manager)
from repro.cluster.service import ControlPlane, DecisionPlan, StatsSnapshot

__all__ = [
    "Autoscaler", "AutoscalerConfig", "ScaleDecision",
    "ControlPlane", "DecisionPlan", "StatsSnapshot",
    "JobManagerClient", "InProcessJobManager", "FileJobManager",
    "serve_file_manager", "spawn_file_manager",
]
