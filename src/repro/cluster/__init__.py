"""DynMo cluster control plane: the layer between the training loop and
the job manager.

  service    — ControlPlane: off-thread profile→decide with a double-buffered
               stats mailbox and epoch-fenced plan application (§3.3.1)
  autoscaler — signal-driven shrink/grow policy (heartbeats + throughput
               watermark with hysteresis) replacing CLI-driven growth
  rpc        — JobManagerClient boundary: in-process WorkerPool wrapper and
               a file-backed stub shaped like a k8s-operator/Ray endpoint
  scheduler  — ClusterScheduler: multi-tenant arbitration (priorities,
               steal/yield, safe-point preemption) above one WorkerPool
  http_rpc   — HTTP transport for the scheduler (stdlib http.server),
               so N Sessions in N processes contend over one manager
"""
from repro.cluster.autoscaler import (Autoscaler, AutoscalerConfig,
                                      ScaleDecision)
from repro.cluster.http_rpc import (HttpJobManager, serve_http_manager,
                                    spawn_http_manager)
from repro.cluster.rpc import (FileJobManager, InProcessJobManager,
                               JobManagerClient, TenantVerbsMixin,
                               serve_file_manager, spawn_file_manager)
from repro.cluster.scheduler import (ClusterScheduler,
                                     SchedulerInvariantError, Tenant)
from repro.cluster.service import ControlPlane, DecisionPlan, StatsSnapshot

__all__ = [
    "Autoscaler", "AutoscalerConfig", "ScaleDecision",
    "ControlPlane", "DecisionPlan", "StatsSnapshot",
    "JobManagerClient", "InProcessJobManager", "FileJobManager",
    "TenantVerbsMixin", "serve_file_manager", "spawn_file_manager",
    "ClusterScheduler", "SchedulerInvariantError", "Tenant",
    "HttpJobManager", "serve_http_manager", "spawn_http_manager",
]
