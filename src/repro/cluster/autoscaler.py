"""Signal-driven autoscaling policy (replaces CLI ``--grow-back``).

Drives ``ElasticEngine.shrink`` / ``grow`` / ``evict`` from live signals
instead of a hard-coded step count:

  * **Heartbeats** — a newly failed *active* worker must be evicted
    immediately (correctness, bypasses hysteresis); a recovered worker
    (revived after failure, e.g. a released machine handed back by the job
    manager) triggers re-growth.
  * **Throughput watermark** — per-worker token throughput over a recent
    step-time window, compared against the best per-worker throughput seen
    so far.  Sustained idleness (current < ``low_watermark`` × best) means
    the pipeline no longer feeds its workers and suggests a shrink;
    recovery headroom uses the symmetric ``high_watermark``.

Hysteresis so decisions don't flap: a watermark signal must persist for
``patience`` consecutive observations, and any resize starts a ``cooldown``
window during which only failure evictions fire.  ``note_resize`` resets
the window — post-resize step times are a different distribution.

The policy is deliberately engine-agnostic: ``observe`` returns a
``ScaleDecision`` and the training loop chooses how to execute it, so the
same policy drives the in-process engine and (later) a multi-process job.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import List, Optional, Sequence, Set

from repro.runtime.fault_tolerance import HeartbeatMonitor


@dataclasses.dataclass
class AutoscalerConfig:
    min_stages: int = 1
    max_stages: int = 64
    window: int = 4              # step-time observations per throughput est.
    low_watermark: float = 0.6   # per-worker throughput fraction → shrink
    high_watermark: float = 0.9  # recovery threshold before growing again
    patience: int = 3            # consecutive signals before acting
    cooldown: int = 8            # steps after a resize with no scaling
    watermark: bool = True       # False: heartbeat signals only (wall-clock
    #   throughput is noise on shared CI machines — keep scaling
    #   deterministic there)
    # ---- serving load signals (``observe_load``) -------------------------
    queue_high: int = 8          # pending requests → grow pressure
    occupancy_low: float = 0.35  # live-lane fraction; with an empty queue,
    #   sustained occupancy below this consolidates the serving pipeline
    latency_slo_s: float = 0.0   # p95 inter-token latency SLO → grow
    #   pressure (the server feeds the p95 over its recent token window,
    #   not a raw tick wall).  0 disables: the latency signal is
    #   wall-clock and therefore breaks run-to-run determinism — leave
    #   off when comparing traces
    page_high: float = 0.92      # paged-KV pool occupancy → grow pressure:
    #   a nearly-full block pool is the memory analogue of a deep queue
    #   (admission gates on free *pages*, so pool pressure backs requests
    #   up even while lanes sit free).  Only fed in paged serving mode


@dataclasses.dataclass
class ScaleDecision:
    step: int
    action: str                  # "none" | "shrink" | "grow" | "evict"
    workers: int                 # how many workers the action concerns
    reason: str
    ids: List[int] = dataclasses.field(default_factory=list)
    # concrete worker ids, when the signal names them (evict: the dead
    # workers; grow: the recovered ones) — empty for watermark decisions
    urgent: bool = False
    # hard pressure (SLO breach / deep queue): on a multi-tenant manager a
    # grow may escalate to a cluster-scheduler *steal* (DESIGN.md §14)


_NONE = "none"


class Autoscaler:
    """Stateful policy: feed it one observation per step, act on what it
    returns.  ``monitor`` is optional — without it only the throughput
    watermark is active."""

    def __init__(self, cfg: AutoscalerConfig,
                 monitor: Optional[HeartbeatMonitor] = None):
        self.cfg = cfg
        self.monitor = monitor
        self._times: collections.deque = collections.deque(
            maxlen=max(1, cfg.window))
        self._known_failed: Set[int] = set()
        self._pending_recovered: Set[int] = set()
        self._pending_evict: Set[int] = set()
        self._bad_shrink_sizes: Set[int] = set()
        self._best_per_worker = 0.0
        self._best_total = 0.0
        self._low_streak = 0
        self._slow_streak = 0
        self._pressure_streak = 0
        self._drain_streak = 0
        self._last_resize_step: Optional[int] = None
        self._last_grow_attempt: Optional[int] = None
        self.decisions: List[ScaleDecision] = []

    # -- persistence (Session safepoints, DESIGN.md §12) -------------------
    def state_dict(self) -> dict:
        """Hysteresis state for crash-safe resume: a resumed run must make
        the same decisions the uninterrupted run would have (cooldown
        anchors, streaks, and best-throughput baselines all carry over).
        ``decisions`` stays out — it is report telemetry, not policy state."""
        return {
            "times": list(self._times),
            "known_failed": sorted(self._known_failed),
            "pending_recovered": sorted(self._pending_recovered),
            "pending_evict": sorted(self._pending_evict),
            "bad_shrink_sizes": sorted(self._bad_shrink_sizes),
            "best_per_worker": self._best_per_worker,
            "best_total": self._best_total,
            "low_streak": self._low_streak,
            "slow_streak": self._slow_streak,
            "pressure_streak": self._pressure_streak,
            "drain_streak": self._drain_streak,
            "last_resize_step": self._last_resize_step,
            "last_grow_attempt": self._last_grow_attempt,
        }

    def load_state(self, sd: dict) -> None:
        self._times.clear()
        self._times.extend(float(t) for t in sd["times"])
        self._known_failed = set(sd["known_failed"])
        self._pending_recovered = set(sd["pending_recovered"])
        self._pending_evict = set(sd["pending_evict"])
        self._bad_shrink_sizes = set(sd["bad_shrink_sizes"])
        self._best_per_worker = float(sd["best_per_worker"])
        self._best_total = float(sd["best_total"])
        self._low_streak = int(sd["low_streak"])
        self._slow_streak = int(sd["slow_streak"])
        self._pressure_streak = int(sd["pressure_streak"])
        self._drain_streak = int(sd["drain_streak"])
        self._last_resize_step = sd["last_resize_step"]
        self._last_grow_attempt = sd["last_grow_attempt"]

    # -- lifecycle hooks ---------------------------------------------------
    def note_resize(self, step: int, stages: int) -> None:
        """The world changed (any cause): reset the throughput window and
        start the cooldown clock."""
        del stages
        self._times.clear()
        self._low_streak = 0
        self._slow_streak = 0
        self._pressure_streak = 0
        self._drain_streak = 0
        self._last_resize_step = step

    def _in_cooldown(self, step: int) -> bool:
        return (self._last_resize_step is not None
                and step - self._last_resize_step < self.cfg.cooldown)

    # -- one observation per step -----------------------------------------
    def observe(self, step: int, step_time_s: float, stages: int,
                active_workers: Sequence[int], tokens: int) -> ScaleDecision:
        decision = ScaleDecision(step, _NONE, 0, "")

        # 1) heartbeat signals (these bypass the watermark hysteresis: a
        # dead worker is a correctness problem and a recovered one is an
        # explicit grant from the job-manager side, not a noisy measurement)
        if self.monitor is not None:
            failed = self.monitor.failed_workers()
            active = set(active_workers)
            newly_failed = (failed - self._known_failed) & active
            # remember recoveries until acted on — the revive transition is
            # transient but the capacity it frees is not (a grow blocked by
            # max_stages today must still fire after a later evict).  Only
            # becoming ACTIVE clears one: a revived-but-not-yet-granted
            # worker is not beaten, so it may time out back into ``failed``
            # while waiting — that must not drop the recovery
            self._pending_recovered |= self._known_failed - failed
            self._pending_recovered -= active
            # dead ACTIVE workers stay due for eviction until they actually
            # leave the pipeline (min_stages may cap how many go at once)
            # or recover on their own
            self._pending_evict = (self._pending_evict | newly_failed) \
                & failed & active
            self._known_failed = set(failed)
            if self._pending_evict:
                n = min(len(self._pending_evict),
                        stages - self.cfg.min_stages)
                if n > 0:
                    ids = sorted(self._pending_evict)[:n]
                    decision = ScaleDecision(
                        step, "evict", n,
                        f"heartbeat lost: workers {ids}", ids=ids)
            # NOT elif on the evict SET: when min_stages caps eviction to
            # zero, the recovery grow below is exactly what creates the
            # capacity to evict the dead worker — blocking it would stall
            # the autoscaler with a corpse in the pipeline
            if decision.action == _NONE and self._pending_recovered:
                n = min(len(self._pending_recovered),
                        self.cfg.max_stages - stages)
                # ids are NOT consumed here: the grant may fail (e.g. the
                # worker is dead on the manager side), so they stay pending
                # until they actually turn up active (cleaned above) — with
                # retries spaced by the cooldown so a never-grantable
                # worker doesn't spam grow attempts every step
                if n > 0 and (self._last_grow_attempt is None
                              or step - self._last_grow_attempt
                              >= self.cfg.cooldown):
                    self._last_grow_attempt = step
                    ids = sorted(self._pending_recovered)[:n]
                    decision = ScaleDecision(
                        step, "grow", n,
                        f"heartbeat recovered: {ids}", ids=ids)
        if decision.action != _NONE:
            self.decisions.append(decision)
            return decision

        # 2) throughput/idleness watermark with hysteresis
        if not self.cfg.watermark:
            return decision
        self._times.append(float(step_time_s))
        if (len(self._times) == self._times.maxlen
                and not self._in_cooldown(step)):
            mean_t = sum(self._times) / len(self._times)
            total = tokens / max(1e-9, mean_t)
            per_worker = total / stages
            self._best_per_worker = max(self._best_per_worker, per_worker)
            self._best_total = max(self._best_total, total)
            idle = per_worker < self.cfg.low_watermark * self._best_per_worker
            slow = total < self.cfg.high_watermark * self._best_total
            self._low_streak = self._low_streak + 1 if idle else 0
            self._slow_streak = self._slow_streak + 1 if slow else 0
            if (self._low_streak >= self.cfg.patience
                    and stages > self.cfg.min_stages
                    and stages - 1 not in self._bad_shrink_sizes):
                # (a size whose shrink previously regressed total
                # throughput enough to trigger the grow watermark is
                # remembered and never re-tried — the two watermarks would
                # otherwise oppose each other into a steady resize cycle
                # in compute-bound regimes)
                self._low_streak = 0
                decision = ScaleDecision(
                    step, "shrink", 1,
                    f"per-worker throughput {per_worker:.0f} tok/s below "
                    f"{self.cfg.low_watermark:.0%} of best "
                    f"{self._best_per_worker:.0f}")
            elif (self._slow_streak >= self.cfg.patience
                    and stages < self.cfg.max_stages):
                # end-to-end throughput regressed (e.g. the model grew back,
                # or a worker was evicted): try to reclaim capacity — the
                # grow is a no-op if the job manager grants nothing
                self._slow_streak = 0
                self._bad_shrink_sizes.add(stages)
                decision = ScaleDecision(
                    step, "grow", 1,
                    f"throughput {total:.0f} tok/s below "
                    f"{self.cfg.high_watermark:.0%} of best "
                    f"{self._best_total:.0f}")
        if decision.action != _NONE:
            self.decisions.append(decision)
        return decision

    # -- serving load signals (one observation per scheduler tick) ---------
    def observe_load(self, step: int, stages: int, *, queue_depth: int,
                     occupancy: float, latency_s: float = 0.0,
                     page_occupancy: Optional[float] = None
                     ) -> ScaleDecision:
        """Queue-depth / latency / occupancy watermarks for the serving
        tier, sharing the training watermarks' hysteresis (``patience``
        consecutive signals, ``cooldown`` after any resize).

        *Grow* on sustained admission pressure: the queue backs up past
        ``queue_high`` (requests wait because every KV lane is taken), or
        p95 per-token latency breaches the SLO when one is configured.
        *Shrink* on sustained drain: queue empty and live-lane occupancy
        below ``occupancy_low`` — early exits / short generations have
        vacated most lanes, so fewer workers serve the same tokens with a
        shorter pipeline fill.  Signals are logical (queue/occupancy), so
        scaling is deterministic per trace unless the latency SLO is on.

        ``page_occupancy`` (paged serving only, else None) adds page
        *pressure*: a block pool past ``page_high`` gates admissions just
        like exhausted lanes do, and also vetoes the drain shrink — lanes
        may look idle while the pool is pinned by long prompts.
        """
        decision = ScaleDecision(step, _NONE, 0, "")
        if self._in_cooldown(step):
            return decision
        paged_hot = (page_occupancy is not None
                     and page_occupancy >= self.cfg.page_high)
        pressured = queue_depth >= self.cfg.queue_high or paged_hot or (
            self.cfg.latency_slo_s > 0
            and latency_s > self.cfg.latency_slo_s)
        draining = (queue_depth == 0 and occupancy <= self.cfg.occupancy_low
                    and not paged_hot)
        self._pressure_streak = self._pressure_streak + 1 if pressured else 0
        self._drain_streak = self._drain_streak + 1 if draining else 0
        if (self._pressure_streak >= self.cfg.patience
                and stages < self.cfg.max_stages):
            self._pressure_streak = 0
            # urgent = SLO actually breached, or the queue runs at twice
            # the grow watermark — worth preempting a lower-priority
            # tenant for, not just waiting on free capacity
            urgent = (self.cfg.latency_slo_s > 0
                      and latency_s > self.cfg.latency_slo_s) or (
                          queue_depth >= 2 * self.cfg.queue_high)
            pages = (f" pages={page_occupancy:.0%}"
                     if page_occupancy is not None else "")
            decision = ScaleDecision(
                step, "grow", 1,
                f"load: queue={queue_depth} latency={latency_s * 1e3:.0f}ms "
                f"at occupancy {occupancy:.0%}{pages}", urgent=urgent)
        elif (self._drain_streak >= self.cfg.patience
                and stages > self.cfg.min_stages):
            self._drain_streak = 0
            decision = ScaleDecision(
                step, "shrink", 1,
                f"drain: queue empty, occupancy {occupancy:.0%} below "
                f"{self.cfg.occupancy_low:.0%}")
        if decision.action != _NONE:
            self.decisions.append(decision)
        return decision
