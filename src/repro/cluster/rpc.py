"""Job-manager RPC boundary (paper §3.4.2).

DynMo's elasticity assumes a job manager that can *take released workers
back* (and grant them again later).  ``JobManagerClient`` is the protocol
the elastic engine talks to; two implementations:

  * ``InProcessJobManager`` — wraps the in-process ``WorkerPool`` (the
    seed's behavior, zero overhead, same logs);
  * ``FileJobManager`` — a file-backed stub shaped like a k8s-operator /
    Ray autoscaler endpoint: each call serializes one request file into a
    shared directory and blocks for the matching response, written by a
    *separate process* running ``serve_file_manager`` (CLI:
    ``python -m repro.cluster.rpc --dir D --workers N``).  Release/grant
    genuinely crosses a process boundary, which is what the multi-node
    story needs tested; swapping the file transport for HTTP/gRPC changes
    only this module.

Wire protocol: ``req-<seq>.json`` → ``resp-<seq>.json``, JSON objects,
atomically published via write-to-temp + ``os.replace`` so a reader never
observes a partial file.  Ops: ``status | release | request | fail |
shutdown``.  Every response carries the manager's view of the pool
(``active`` count) so the client can mirror it without extra round trips.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import List, Optional, Protocol, Sequence, runtime_checkable

from repro.runtime.fault_tolerance import WorkerPool


@runtime_checkable
class JobManagerClient(Protocol):
    """What the elastic engine needs from a job manager."""

    def release(self, workers: Sequence[int]) -> List[int]:
        """Hand workers back to the manager; returns those actually taken."""
        ...

    def request(self, n: int) -> List[int]:
        """Ask for up to ``n`` workers; returns the granted ids."""
        ...

    def fail(self, worker: int) -> None:
        """Report a dead worker (not released — gone)."""
        ...

    @property
    def num_active(self) -> int: ...

    def close(self) -> None: ...


class InProcessJobManager:
    """The seed's job manager: a ``WorkerPool`` in this process.  The
    engine's existing subscribe hooks and logs keep working unchanged."""

    def __init__(self, pool: WorkerPool):
        self.pool = pool

    def release(self, workers: Sequence[int]) -> List[int]:
        before = set(self.pool.released)
        self.pool.release(list(workers))
        return sorted(set(self.pool.released) - before)

    def request(self, n: int) -> List[int]:
        return self.pool.request(n)

    def fail(self, worker: int) -> None:
        self.pool.fail(worker)

    @property
    def num_active(self) -> int:
        return self.pool.num_active

    @property
    def log(self) -> List[str]:
        return self.pool.log

    def close(self) -> None:
        pass


def _atomic_write_json(path: str, obj) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def _read_json(path: str):
    with open(path) as f:
        return json.load(f)


class FileJobManager:
    """File-backed ``JobManagerClient``; the pool lives in the server
    process.  Calls are synchronous RPCs with a poll-for-response loop —
    release/grant are rare (resize-time only), so latency is irrelevant and
    the transport stays trivially debuggable (``ls`` the directory)."""

    def __init__(self, root: str, timeout_s: float = 30.0,
                 poll_s: float = 0.01):
        self.root = root
        self.timeout_s = timeout_s
        self.poll_s = poll_s
        # start past any leftover req/resp files (a reused directory):
        # colliding with a previous run's sequence numbers would read its
        # stale responses as answers to our requests
        self._seq = 0
        for name in os.listdir(root):
            if ((name.startswith("req-") or name.startswith("resp-"))
                    and name.endswith(".json")):
                try:
                    self._seq = max(self._seq,
                                    int(name.split("-", 1)[1][:-len(".json")]))
                except ValueError:
                    pass
        self._active: Optional[int] = None
        self.log: List[str] = []        # client-side mirror of transitions

    def _call(self, op: str, **payload) -> dict:
        self._seq += 1
        seq = self._seq
        req = os.path.join(self.root, f"req-{seq:06d}.json")
        resp = os.path.join(self.root, f"resp-{seq:06d}.json")
        _atomic_write_json(req, {"op": op, **payload})
        deadline = time.monotonic() + self.timeout_s
        while not os.path.exists(resp):
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"job manager did not answer {op} (req {seq}) within "
                    f"{self.timeout_s}s — is the server process running on "
                    f"{self.root!r}?")
            time.sleep(self.poll_s)
        out = _read_json(resp)
        if "active" in out:
            self._active = int(out["active"])
        if out.get("error"):
            raise RuntimeError(f"job manager rejected {op}: {out['error']}")
        return out

    # -- JobManagerClient --------------------------------------------------
    def release(self, workers: Sequence[int]) -> List[int]:
        out = self._call("release", workers=[int(w) for w in workers])
        released = [int(w) for w in out["released"]]
        self.log.extend(f"release:{w}" for w in released)
        return released

    def request(self, n: int) -> List[int]:
        out = self._call("request", n=int(n))
        granted = [int(w) for w in out["granted"]]
        self.log.extend(f"grant:{w}" for w in granted)
        return granted

    def fail(self, worker: int) -> None:
        self._call("fail", worker=int(worker))
        self.log.append(f"fail:{worker}")

    @property
    def num_active(self) -> int:
        if self._active is None:
            self._call("status")
        return int(self._active)

    def close(self) -> None:
        # best-effort: a dead server must not stall shutdown for the full
        # RPC timeout, so the farewell uses its own short deadline
        prev = self.timeout_s
        self.timeout_s = min(prev, 2.0)
        try:
            self._call("shutdown")
        except (TimeoutError, OSError):
            pass                         # server already gone — fine
        finally:
            self.timeout_s = prev


def serve_file_manager(root: str, workers: int, poll_s: float = 0.01,
                       idle_timeout_s: Optional[float] = None) -> WorkerPool:
    """Serve one ``WorkerPool`` over the file protocol until a ``shutdown``
    request (or ``idle_timeout_s`` with no traffic).  Runs in its own
    process in tests/CI; returns the final pool for inspection when called
    in-process."""
    pool = WorkerPool(workers)
    done: set = set()
    last_traffic = time.monotonic()
    while True:
        names = sorted(n for n in os.listdir(root)
                       if n.startswith("req-") and n.endswith(".json"))
        for name in names:
            seq = name[len("req-"):-len(".json")]
            if seq in done:
                continue
            if os.path.exists(os.path.join(root, f"resp-{seq}.json")):
                done.add(seq)            # answered by a previous server
                continue                 # process — never replay its ops
            try:
                req = _read_json(os.path.join(root, name))
            except (json.JSONDecodeError, OSError):
                continue                 # writer mid-flight; next scan
            done.add(seq)
            last_traffic = time.monotonic()
            op = req.get("op")
            out: dict = {"op": op}
            if op == "release":
                out["released"] = [
                    int(w) for w in req["workers"] if w in pool.active]
                pool.release(req["workers"])
            elif op == "request":
                out["granted"] = pool.request(int(req["n"]))
            elif op == "fail":
                pool.fail(int(req["worker"]))
            elif op in ("status", "shutdown"):
                pass
            else:
                out["error"] = f"unknown op {op!r}"
            out["active"] = pool.num_active
            _atomic_write_json(os.path.join(root, f"resp-{seq}.json"), out)
            if op == "shutdown":
                return pool
        if (idle_timeout_s is not None
                and time.monotonic() - last_traffic > idle_timeout_s):
            return pool
        time.sleep(poll_s)


def spawn_file_manager(root: str, workers: int,
                       idle_timeout_s: float = 300.0) -> subprocess.Popen:
    """Start the file job manager as a separate process (the RPC actually
    crosses a process boundary).  The idle timeout is a safety net so an
    orphaned server never outlives its job by much."""
    return subprocess.Popen(
        [sys.executable, "-c",
         "from repro.cluster.rpc import main; main()", "--dir", root,
         "--workers", str(workers), "--idle-timeout",
         str(idle_timeout_s)],
        env={**os.environ,
             "PYTHONPATH": os.pathsep.join(
                 p for p in [os.environ.get("PYTHONPATH"),
                             os.path.dirname(os.path.dirname(
                                 os.path.dirname(
                                     os.path.abspath(__file__))))]
                 if p)})


def main() -> None:
    ap = argparse.ArgumentParser(description="file-backed job manager")
    ap.add_argument("--dir", required=True)
    ap.add_argument("--workers", type=int, required=True)
    ap.add_argument("--poll", type=float, default=0.01)
    ap.add_argument("--idle-timeout", type=float, default=None)
    args = ap.parse_args()
    pool = serve_file_manager(args.dir, args.workers, poll_s=args.poll,
                              idle_timeout_s=args.idle_timeout)
    print(f"job manager done: active={pool.num_active} "
          f"released={sorted(pool.released)} dead={sorted(pool.dead)}")


if __name__ == "__main__":
    main()
