"""Job-manager RPC boundary (paper §3.4.2).

DynMo's elasticity assumes a job manager that can *take released workers
back* (and grant them again later).  ``JobManagerClient`` is the protocol
the elastic engine talks to; two implementations:

  * ``InProcessJobManager`` — wraps the in-process ``WorkerPool`` (the
    seed's behavior, zero overhead, same logs);
  * ``FileJobManager`` — a file-backed stub shaped like a k8s-operator /
    Ray autoscaler endpoint: each call serializes one request file into a
    shared directory and blocks for the matching response, written by a
    *separate process* running ``serve_file_manager`` (CLI:
    ``python -m repro.cluster.rpc --dir D --workers N``).  Release/grant
    genuinely crosses a process boundary, which is what the multi-node
    story needs tested; swapping the file transport for HTTP/gRPC changes
    only this module.

Wire protocol: ``req-<seq>.json`` → ``resp-<seq>.json``, JSON objects,
atomically published via write-to-temp + ``os.replace`` so a reader never
observes a partial file.  Ops: ``status | release | request | fail |
shutdown``.  Every response carries the manager's view of the pool
(``active`` count) so the client can mirror it without extra round trips.

Failure model (DESIGN.md §12): the sequence number IS the idempotency key.
The client retries a timed-out call by re-publishing the SAME ``req-<seq>``
with exponential backoff + seeded jitter; the server journals every
executed response (plus the pool state it produced) into ``state.json``
*before* publishing it, so a retry — or a freshly respawned server after a
``kill -9`` — re-serves the stored response instead of re-executing the
op.  When the whole retry budget burns, ``JobManagerUnavailable`` (a
``TimeoutError``) surfaces and a client-side circuit breaker opens: calls
fail fast (training continues without scaling decisions) with a periodic
probe so a revived manager is rediscovered.
"""
from __future__ import annotations

import argparse
import json
import os
import random
import subprocess
import sys
import time
from typing import Dict, List, Optional, Protocol, Sequence, \
    runtime_checkable

from repro.runtime.fault_tolerance import WorkerPool


class JobManagerUnavailable(TimeoutError):
    """The manager did not answer within the retry budget (or the circuit
    breaker is open).  Subclasses ``TimeoutError`` so callers that handled
    the raw timeout keep working; the elastic engine catches it and
    degrades — no scaling decision, training continues."""


class CircuitBreaker:
    """Count-based breaker (deterministic — no wall-clock cool-off): after
    ``trip_after`` consecutive call failures the circuit opens and calls
    fail fast; every ``probe_every``-th blocked call is let through as a
    probe, and one success closes the circuit again."""

    def __init__(self, trip_after: int = 2, probe_every: int = 4):
        self.trip_after = max(1, trip_after)
        self.probe_every = max(1, probe_every)
        self.failures = 0
        self.trips = 0
        self.fast_fails = 0
        self._blocked_since_probe = 0

    @property
    def open(self) -> bool:
        return self.failures >= self.trip_after

    def allow(self) -> bool:
        if not self.open:
            return True
        self._blocked_since_probe += 1
        if self._blocked_since_probe >= self.probe_every:
            self._blocked_since_probe = 0
            return True                   # probe
        self.fast_fails += 1
        return False

    def success(self) -> None:
        self.failures = 0
        self._blocked_since_probe = 0

    def failure(self) -> None:
        self.failures += 1
        if self.failures == self.trip_after:
            self.trips += 1

    def state_dict(self) -> dict:
        return {"failures": self.failures, "trips": self.trips,
                "fast_fails": self.fast_fails}


@runtime_checkable
class JobManagerClient(Protocol):
    """What the elastic engine needs from a job manager."""

    def release(self, workers: Sequence[int]) -> List[int]:
        """Hand workers back to the manager; returns those actually taken."""
        ...

    def request(self, n: int) -> List[int]:
        """Ask for up to ``n`` workers; returns the granted ids."""
        ...

    def fail(self, worker: int) -> None:
        """Report a dead worker (not released — gone)."""
        ...

    @property
    def num_active(self) -> int: ...

    def close(self) -> None: ...


class TenantVerbsMixin:
    """Multi-tenant verbs shared by the file and HTTP clients (DESIGN.md
    §14).  Once ``register_tenant`` has run, the plain ``release``/
    ``request`` verbs become tenant-scoped automatically (the payload
    carries the tenant id), so the elastic engine's existing release/grant
    hooks participate in scheduler arbitration without knowing it."""

    tenant: Optional[str] = None

    def _call(self, op: str, **payload) -> dict:  # provided by the client
        raise NotImplementedError

    def _tenant_kw(self) -> dict:
        return {"tenant": self.tenant} if self.tenant else {}

    def register_tenant(self, tenant_id: str, *, priority: int = 0,
                        kind: str = "train", workers: int = 0,
                        max_workers: Optional[int] = None,
                        min_workers: int = 1) -> List[int]:
        """Join the cluster; returns the initial grant.  Idempotent — a
        retried registration sees the tenant's current grant."""
        out = self._call("register", tenant=tenant_id,
                         priority=int(priority), kind=kind,
                         workers=int(workers),
                         max_workers=max_workers,
                         min_workers=int(min_workers))
        self.tenant = tenant_id
        return [int(w) for w in out["granted"]]

    def steal(self, n: int) -> List[int]:
        """Demand ``n`` workers NOW: whatever free capacity allows is
        granted immediately; the shortfall becomes a preemption directive
        against lower-priority tenants, and the victims' workers arrive
        reserved-for-us (collect with a later ``request``)."""
        out = self._call("steal", n=int(n), **self._tenant_kw())
        granted = [int(w) for w in out["granted"]]
        if hasattr(self, "log"):
            self.log.extend(f"grant:{w}" for w in granted)
        return granted

    def yield_workers(self, workers: Sequence[int]) -> List[int]:
        """Voluntarily hand workers back (load dropped) — a tenant-scoped
        release; freed workers settle pending steals first, then become
        offers to tenants below their ceiling."""
        out = self._call("yield", workers=[int(w) for w in workers],
                         **self._tenant_kw())
        released = [int(w) for w in out["released"]]
        if hasattr(self, "log"):
            self.log.extend(f"release:{w}" for w in released)
        return released

    def poll_cluster(self) -> Dict[str, int]:
        """Directive mailbox: ``{"preempt": k, "offer": m}`` — this tenant
        must release ``k`` workers at its next safe point / could absorb
        ``m`` free ones.  Level-triggered: re-delivered until acted on.
        ``cause`` (when present) is the thief's span context — the victim
        parents its preemption events on it so the cross-process
        steal→preempt→shrink chain correlates (DESIGN.md §15)."""
        out = self._call("poll", **self._tenant_kw())
        return {"preempt": int(out.get("preempt", 0)),
                "offer": int(out.get("offer", 0)),
                "cause": out.get("cause")}

    def cluster_metrics(self) -> dict:
        """Scheduler event timeline + per-tenant grants (bench telemetry)."""
        return self._call("metrics")

    def deregister(self) -> List[int]:
        """Leave the cluster, releasing everything this tenant holds."""
        if not self.tenant:
            return []
        out = self._call("deregister", tenant=self.tenant)
        self.tenant = None
        return [int(w) for w in out.get("released", [])]


class InProcessJobManager:
    """The seed's job manager: a ``WorkerPool`` in this process.  The
    engine's existing subscribe hooks and logs keep working unchanged."""

    def __init__(self, pool: WorkerPool):
        self.pool = pool

    def release(self, workers: Sequence[int]) -> List[int]:
        before = set(self.pool.released)
        self.pool.release(list(workers))
        return sorted(set(self.pool.released) - before)

    def request(self, n: int) -> List[int]:
        return self.pool.request(n)

    def fail(self, worker: int) -> None:
        self.pool.fail(worker)

    @property
    def num_active(self) -> int:
        return self.pool.num_active

    @property
    def log(self) -> List[str]:
        return self.pool.log

    def close(self) -> None:
        pass


def _atomic_write_json(path: str, obj) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def _read_json(path: str):
    with open(path) as f:
        return json.load(f)


class FileJobManager(TenantVerbsMixin):
    """File-backed ``JobManagerClient``; the pool lives in the server
    process.  Calls are synchronous RPCs with a poll-for-response loop —
    release/grant are rare (resize-time only), so latency is irrelevant and
    the transport stays trivially debuggable (``ls`` the directory)."""

    def __init__(self, root: str, timeout_s: float = 30.0,
                 poll_s: float = 0.01, *, retries: int = 3,
                 backoff_s: float = 0.05, jitter_seed: int = 0,
                 breaker_after: int = 2, breaker_probe_every: int = 4,
                 shutdown_on_close: bool = True):
        self.root = root
        self.tenant = None
        self.shutdown_on_close = shutdown_on_close
        self.timeout_s = timeout_s       # TOTAL budget, split over retries
        self.poll_s = poll_s
        self.retries = max(1, retries)
        self.backoff_s = backoff_s
        self._jitter = random.Random(jitter_seed)
        self.breaker = CircuitBreaker(breaker_after, breaker_probe_every)
        # start past any leftover req/resp files (a reused directory):
        # colliding with a previous run's sequence numbers would read its
        # stale responses as answers to our requests
        self._seq = 0
        for name in os.listdir(root):
            if ((name.startswith("req-") or name.startswith("resp-"))
                    and name.endswith(".json")):
                try:
                    self._seq = max(self._seq,
                                    int(name.split("-", 1)[1][:-len(".json")]))
                except ValueError:
                    pass
        self._active: Optional[int] = None
        self.log: List[str] = []        # client-side mirror of transitions
        self.rpc_stats: Dict[str, int] = {"calls": 0, "retries": 0,
                                          "timeouts": 0}

    # -- transport hooks (ChaosFileJobManager overrides these) -------------
    def _send(self, req_path: str, obj: dict, attempt: int) -> None:
        _atomic_write_json(req_path, obj)

    def _await(self, resp_path: str, deadline: float, attempt: int) -> dict:
        while not os.path.exists(resp_path):
            if time.monotonic() > deadline:
                raise TimeoutError(resp_path)
            time.sleep(self.poll_s)
        return _read_json(resp_path)

    def _call(self, op: str, **payload) -> dict:
        if not self.breaker.allow():
            raise JobManagerUnavailable(
                f"job manager circuit open ({self.breaker.failures} "
                f"consecutive failures): {op} skipped")
        self._seq += 1
        seq = self._seq
        self.rpc_stats["calls"] += 1
        req = os.path.join(self.root, f"req-{seq:06d}.json")
        resp = os.path.join(self.root, f"resp-{seq:06d}.json")
        obj = {"op": op, "seq": seq, **payload}
        # ship the caller's span context so the scheduler can attribute
        # this op (and forward a steal's context to its preemption victim)
        from repro.obs.trace import current_tracer
        tr = current_tracer()
        if tr is not None:
            obj["trace"] = tr.rpc_ctx(op, transport="file", seq=seq)
        per_attempt = self.timeout_s / self.retries
        for attempt in range(self.retries):
            # retries re-publish the SAME sequence number: the server
            # dedups on it, so a retried-but-actually-executed op is
            # answered from its journal, never run twice
            self._send(req, obj, attempt)
            try:
                out = self._await(resp,
                                  time.monotonic() + per_attempt, attempt)
            except TimeoutError:
                self.rpc_stats["timeouts"] += 1
                if attempt + 1 < self.retries:
                    self.rpc_stats["retries"] += 1
                    # exponential backoff with seeded jitter: deterministic
                    # per client, still decorrelated across clients
                    time.sleep(self.backoff_s * (2 ** attempt)
                               * (1.0 + self._jitter.random()))
                continue
            self.breaker.success()
            if "active" in out:
                self._active = int(out["active"])
            if out.get("error"):
                raise RuntimeError(
                    f"job manager rejected {op}: {out['error']}")
            return out
        # withdraw the request before giving up: a server that comes back
        # later must not execute an op whose caller already moved on (a
        # stale ``request`` would leak its grant).  Best-effort — if the
        # server is mid-execution the journal dedup still applies.
        try:
            os.unlink(req)
        except OSError:
            pass
        self.breaker.failure()
        raise JobManagerUnavailable(
            f"job manager did not answer {op} (req {seq}) within "
            f"{self.timeout_s}s across {self.retries} attempts — is the "
            f"server process running on {self.root!r}?")

    # -- JobManagerClient --------------------------------------------------
    def release(self, workers: Sequence[int]) -> List[int]:
        out = self._call("release", workers=[int(w) for w in workers],
                         **self._tenant_kw())
        released = [int(w) for w in out["released"]]
        self.log.extend(f"release:{w}" for w in released)
        return released

    def request(self, n: int) -> List[int]:
        out = self._call("request", n=int(n), **self._tenant_kw())
        granted = [int(w) for w in out["granted"]]
        self.log.extend(f"grant:{w}" for w in granted)
        return granted

    def fail(self, worker: int) -> None:
        self._call("fail", worker=int(worker), **self._tenant_kw())
        self.log.append(f"fail:{worker}")

    @property
    def num_active(self) -> int:
        """Last-known active count; -1 when the manager has never answered
        and is currently unreachable (telemetry must not raise in degraded
        mode — scaling decisions use the RPC ops, not this)."""
        if self._active is None:
            try:
                self._call("status")
            except JobManagerUnavailable:
                return -1
        return int(self._active)

    def close(self) -> None:
        # best-effort: a dead server must not stall shutdown for the full
        # RPC timeout, so the farewell uses its own short deadline
        prev = self.timeout_s
        self.timeout_s = min(prev, 2.0)
        try:
            if self.tenant:
                self.deregister()        # grants flow back to the pool
            if self.shutdown_on_close:
                # only the Session that owns the manager process tears it
                # down; tenants of a shared manager just deregister
                self._call("shutdown")
        except (TimeoutError, OSError, RuntimeError):
            pass                         # server already gone — fine
        finally:
            self.timeout_s = prev


def serve_file_manager(root: str, workers: int, poll_s: float = 0.01,
                       idle_timeout_s: Optional[float] = None,
                       spares: int = 0) -> WorkerPool:
    """Serve one ``WorkerPool`` over the file protocol until a ``shutdown``
    request (or ``idle_timeout_s`` with no traffic).  Runs in its own
    process in tests/CI; returns the final pool for inspection when called
    in-process.

    Crash-safety: before publishing any response the server journals
    ``{pool state, answered responses}`` into ``state.json`` (atomic
    replace).  A respawned server on the same directory restores the pool
    exactly where the dead one left it and re-serves journaled responses
    for retried sequence numbers — ops are executed at most once even
    across a ``kill -9`` (DESIGN.md §12)."""
    from repro.cluster.scheduler import ClusterScheduler

    state_path = os.path.join(root, "state.json")
    answered: Dict[str, dict] = {}
    sched: Optional[ClusterScheduler] = None
    if os.path.exists(state_path):
        try:
            js = _read_json(state_path)
            # journal keeps the PR-6 "pool" key (old journals restore with
            # zero tenants) plus the tenant ledger alongside
            sched = ClusterScheduler.from_state(
                {"pool": js["pool"], "tenants": js.get("tenants", [])})
            answered = dict(js["answered"])
        except (json.JSONDecodeError, OSError, KeyError):
            sched = None                 # torn/old journal: start fresh
    if sched is None:
        sched = ClusterScheduler(WorkerPool(workers, spares=spares))
    pool = sched.pool
    done: set = set(answered)
    last_traffic = time.monotonic()
    while True:
        names = sorted(n for n in os.listdir(root)
                       if n.startswith("req-") and n.endswith(".json"))
        for name in names:
            seq = name[len("req-"):-len(".json")]
            resp_path = os.path.join(root, f"resp-{seq}.json")
            if seq in done:
                # a client retry after response loss: re-publish the
                # journaled answer — the op itself is NOT re-executed
                if not os.path.exists(resp_path) and seq in answered:
                    _atomic_write_json(resp_path, answered[seq])
                continue
            if os.path.exists(resp_path):
                done.add(seq)            # answered by a previous server
                try:                     # keep it re-servable after resp
                    answered[seq] = _read_json(resp_path)   # file loss
                except (json.JSONDecodeError, OSError):
                    pass
                continue                 # — but never re-execute its op
            try:
                req = _read_json(os.path.join(root, name))
            except (json.JSONDecodeError, OSError):
                continue                 # writer mid-flight; next scan
            done.add(seq)
            last_traffic = time.monotonic()
            op = req.get("op")
            # op execution lives in ClusterScheduler.handle — the SAME
            # dispatch the HTTP transport serves, so tenant semantics
            # can't drift between transports
            out = sched.handle(req)
            # journal BEFORE publishing: if we die in between, the respawn
            # finds the executed op in the journal and re-serves it; if we
            # die before journaling, the resp was never visible and the
            # retried op re-executes against the pre-op pool state —
            # either way the op takes effect exactly once
            answered[seq] = out
            sd = sched.state_dict()
            _atomic_write_json(state_path, {"pool": sd["pool"],
                                            "tenants": sd["tenants"],
                                            "answered": answered})
            _atomic_write_json(resp_path, out)
            if op == "shutdown":
                return pool
        if (idle_timeout_s is not None
                and time.monotonic() - last_traffic > idle_timeout_s):
            return pool
        time.sleep(poll_s)


def spawn_file_manager(root: str, workers: int,
                       idle_timeout_s: float = 300.0,
                       spares: int = 0) -> subprocess.Popen:
    """Start the file job manager as a separate process (the RPC actually
    crosses a process boundary).  The idle timeout is a safety net so an
    orphaned server never outlives its job by much."""
    return subprocess.Popen(
        [sys.executable, "-c",
         "from repro.cluster.rpc import main; main()", "--dir", root,
         "--workers", str(workers), "--idle-timeout",
         str(idle_timeout_s), "--spares", str(spares)],
        env={**os.environ,
             "PYTHONPATH": os.pathsep.join(
                 p for p in [os.environ.get("PYTHONPATH"),
                             os.path.dirname(os.path.dirname(
                                 os.path.dirname(
                                     os.path.abspath(__file__))))]
                 if p)})


def main() -> None:
    ap = argparse.ArgumentParser(description="file-backed job manager")
    ap.add_argument("--dir", required=True)
    ap.add_argument("--workers", type=int, required=True)
    ap.add_argument("--poll", type=float, default=0.01)
    ap.add_argument("--idle-timeout", type=float, default=None)
    ap.add_argument("--spares", type=int, default=0,
                    help="fresh worker ids grantable beyond the released "
                         "set (new processes, not revivals)")
    args = ap.parse_args()
    pool = serve_file_manager(args.dir, args.workers, poll_s=args.poll,
                              idle_timeout_s=args.idle_timeout,
                              spares=args.spares)
    print(f"job manager done: active={pool.num_active} "
          f"released={sorted(pool.released)} dead={sorted(pool.dead)}")


if __name__ == "__main__":
    main()
