"""Multi-tenant cluster scheduler: train and serve sharing one WorkerPool.

The job-manager boundary (``cluster.rpc``) used to assume exactly one
Session per pool: workers a trainer released just sat in ``pool.released``
with nowhere to go.  ``ClusterScheduler`` is the arbitration layer above
the pool — N concurrent Sessions (*tenants*) register with a priority and
a desired worker ceiling, and the scheduler decides who holds what:

  * ``register`` — a tenant joins and receives its initial grant.
  * ``request``  — more workers, from free capacity only (never preempts).
  * ``steal``    — more workers NOW: free capacity first, then a
    **preemption directive** is posted against the lowest-priority tenant
    holding workers above its floor.  The victim learns about it at its
    next ``poll`` and shrinks at its next safe point (the trainer sees an
    externally-originated ``ResizePlan`` — same epoch-fence machinery as
    any controller plan, DESIGN.md §14); the workers it releases are
    *reserved* for the stealing tenant, not returned to the free set.
  * ``yield``    — a tenant hands workers back voluntarily (serving load
    dropped).  Freed workers first settle outstanding steals, then become
    an ``offer`` to the highest-priority tenant running below its ceiling
    (training absorbs them back off-peak).
  * ``poll``     — a tenant's directive mailbox: ``preempt`` (how many
    workers it must release) and ``offer`` (how many it could absorb).

Arbitration is by priority and marginal utility: a steal only preempts
strictly lower-priority tenants, victims are chosen lowest-priority-first
and — within a priority — the tenant whose marginal worker is least
utilized (largest grant relative to its floor) loses first.  Directives
are *level-triggered*: ``preempt`` is recomputed from live demand at every
poll, so a directive lost to an epoch fence on the tenant side is simply
re-delivered — never acked, never dropped.

``handle(req) -> resp`` is the transport-facing dispatch.  Both transports
serve the SAME scheduler through it — the file server (``cluster.rpc``,
the crash-tested test double) and the HTTP server (``cluster.http_rpc``,
the k8s-operator-shaped real thing) — so tenant semantics can never drift
between them.  Requests without a ``tenant`` field fall through to the
legacy single-Session pool ops unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

from repro.runtime.fault_tolerance import WorkerPool


@dataclasses.dataclass
class Tenant:
    """One registered Session's standing with the scheduler."""
    tenant_id: str
    priority: int = 0
    kind: str = "train"            # "train" | "serve" (telemetry only)
    max_workers: int = 0           # ceiling for offers (0 = initial grant)
    min_workers: int = 1           # floor a steal can never push below
    granted: List[int] = dataclasses.field(default_factory=list)
    preempt_due: int = 0           # workers this tenant must still release
    reserved: List[int] = dataclasses.field(default_factory=list)
    # freed-by-preemption workers parked for THIS tenant's next request
    steal_owed: int = 0            # outstanding steal demand not yet granted
    # the thief's span context ({"trace_id","span_id"}, DESIGN.md §15):
    # forwarded to the victim on poll so the cross-process
    # steal→preempt→shrink chain correlates in a merged trace
    preempt_cause: Optional[dict] = None

    def state_dict(self) -> dict:
        return {"tenant_id": self.tenant_id, "priority": self.priority,
                "kind": self.kind, "max_workers": self.max_workers,
                "min_workers": self.min_workers,
                "granted": sorted(self.granted),
                "preempt_due": self.preempt_due,
                "reserved": sorted(self.reserved),
                "steal_owed": self.steal_owed,
                "preempt_cause": self.preempt_cause}

    @classmethod
    def from_state(cls, sd: dict) -> "Tenant":
        return cls(tenant_id=sd["tenant_id"], priority=int(sd["priority"]),
                   kind=sd.get("kind", "train"),
                   max_workers=int(sd.get("max_workers", 0)),
                   min_workers=int(sd.get("min_workers", 1)),
                   granted=[int(w) for w in sd["granted"]],
                   preempt_due=int(sd.get("preempt_due", 0)),
                   reserved=[int(w) for w in sd.get("reserved", [])],
                   steal_owed=int(sd.get("steal_owed", 0)),
                   preempt_cause=sd.get("preempt_cause"))


class SchedulerInvariantError(RuntimeError):
    """The double-grant guard tripped: scheduler/pool bookkeeping claims a
    worker is in two places at once.  Always a bug, never load."""


class ClusterScheduler:
    """Owns the ``WorkerPool`` and arbitrates grants across tenants.

    Thread-safety is the transport's problem (the file server is a single
    loop; the HTTP server serializes ``handle`` under one lock) — this
    class is deliberately lock-free and deterministic."""

    def __init__(self, pool: WorkerPool):
        self.pool = pool
        self.tenants: Dict[str, Tenant] = {}
        # grant-count timeline for utilization accounting (bench_cluster):
        # one record per worker transition, wall-stamped by the server
        self.events: List[dict] = []
        self._req_ctx: Optional[dict] = None   # requester's span context
        self._check()

    # -- telemetry ---------------------------------------------------------
    def _record(self, tenant: str, ev: str, worker: int) -> None:
        from repro.obs.events import stamp_record
        rec = {"t": time.time(), "tenant": tenant, "ev": ev,
               "worker": int(worker),
               "granted": {t.tenant_id: len(t.granted)
                           for t in self.tenants.values()}}
        # legacy "t"/"ev" keys stay (aliases, one release); the unified
        # fields ride along — with the requester's span context as the
        # trace identity when the op carried one (DESIGN.md §15)
        stamp_record(rec, source="scheduler", kind=ev, tracer=None,
                     ctx=self._req_ctx, wall=False)
        self.events.append(rec)

    # -- the double-grant guard (DESIGN.md §14) ----------------------------
    def _check(self) -> None:
        """A worker id granted to one tenant is never concurrently granted
        to another, reserved for anyone, or sitting in the pool's free/dead
        sets.  Runs after every mutating op — the pool is tiny, the check
        is O(workers)."""
        self.pool.check_consistent()
        seen: Dict[int, str] = {}
        for t in self.tenants.values():
            for w in list(t.granted) + list(t.reserved):
                if w in seen:
                    raise SchedulerInvariantError(
                        f"worker {w} held by both {seen[w]!r} and "
                        f"{t.tenant_id!r}")
                seen[w] = t.tenant_id
            for w in t.granted:
                if w not in self.pool.active:
                    raise SchedulerInvariantError(
                        f"worker {w} granted to {t.tenant_id!r} but not "
                        f"active in the pool")
            for w in t.reserved:
                if w not in self.pool.released:
                    raise SchedulerInvariantError(
                        f"worker {w} reserved for {t.tenant_id!r} but not "
                        f"released in the pool")

    # -- free capacity -----------------------------------------------------
    def _reserved_ids(self) -> set:
        return {w for t in self.tenants.values() for w in t.reserved}

    def _free(self) -> List[int]:
        """Released workers not reserved for a pending steal."""
        return sorted(set(self.pool.released) - self._reserved_ids())

    def _unassigned_active(self) -> set:
        """Active workers no tenant holds (the legacy single-Session pool
        starts fully active; a first-registering tenant must not treat
        those as its own)."""
        held = {w for t in self.tenants.values() for w in t.granted}
        return set(self.pool.active) - held

    # -- grant plumbing ----------------------------------------------------
    def _grant_to(self, t: Tenant, n: int) -> List[int]:
        """Grant up to ``n`` workers to ``t``: its reservation first, then
        the free set, then unassigned-active, then freshly-minted spares."""
        granted: List[int] = []
        while t.reserved and len(granted) < n:
            w = t.reserved.pop(0)
            self.pool.grant([w])
            granted.append(w)
        free = self._free()
        take = free[:n - len(granted)]
        if take:
            self.pool.grant(take)
            granted.extend(take)
        # active-but-unowned workers (pre-tenant pool stock) are claimable
        # without a pool transition — they are already provisioned
        for w in sorted(self._unassigned_active()):
            if len(granted) >= n:
                break
            granted.append(w)
        if len(granted) < n:
            granted.extend(self.pool.request(
                n - len(granted), exclude=self._reserved_ids()))
        t.granted.extend(granted)
        for w in granted:
            self._record(t.tenant_id, "grant", w)
        self._check()
        return granted

    def _settle_freed(self, victim: Tenant, workers: Sequence[int]) -> None:
        """Workers ``victim`` just released under preemption: park each on
        the reservation of whoever is owed a steal."""
        for w in workers:
            t = self._owed()
            if t is None:
                break
            t.reserved.append(int(w))
            self._record(t.tenant_id, "reserve", w)

    def _owed(self) -> Optional[Tenant]:
        """The tenant a freed worker should be reserved for: the highest-
        priority tenant with an unmet steal (reservation below its
        outstanding demand)."""
        for t in sorted(self.tenants.values(), key=lambda t: -t.priority):
            if t.steal_owed > len(t.reserved):
                return t
        return None

    # -- preemption --------------------------------------------------------
    def _assign_preemption(self, thief: Tenant, shortfall: int,
                           cause: Optional[dict] = None) -> int:
        """Post preemption directives worth ``shortfall`` workers against
        strictly-lower-priority tenants.  Victims: lowest priority first;
        within a priority, the tenant with the most workers above its floor
        (its marginal worker is the least useful).  Returns how many
        workers were actually assigned."""
        victims = sorted(
            (t for t in self.tenants.values()
             if t.priority < thief.priority and t is not thief),
            key=lambda t: (t.priority,
                           -(len(t.granted) - t.preempt_due
                             - t.min_workers)))
        assigned = 0
        for v in victims:
            headroom = len(v.granted) - v.preempt_due - v.min_workers
            take = min(headroom, shortfall - assigned)
            if take <= 0:
                continue
            v.preempt_due += take
            assigned += take
            if cause is not None:
                v.preempt_cause = dict(cause)
            self._record(v.tenant_id, "preempt_due", take)
            if assigned >= shortfall:
                break
        return assigned

    # -- ops ---------------------------------------------------------------
    def register(self, tenant_id: str, *, priority: int = 0,
                 kind: str = "train", workers: int = 0,
                 max_workers: Optional[int] = None,
                 min_workers: int = 1) -> List[int]:
        """Register (idempotent) and return the tenant's CURRENT grant —
        a re-register after a client retry sees the same workers."""
        t = self.tenants.get(tenant_id)
        if t is None:
            t = Tenant(tenant_id=tenant_id, priority=int(priority),
                       kind=kind,
                       max_workers=int(max_workers
                                       if max_workers is not None
                                       else workers),
                       min_workers=max(1, int(min_workers)))
            self.tenants[tenant_id] = t
            self._record(tenant_id, "register", -1)
            if workers:
                self._grant_to(t, int(workers))
        return sorted(t.granted)

    def deregister(self, tenant_id: str) -> List[int]:
        """The tenant's process is going away: everything it held returns
        to the free set (a yield of its full grant)."""
        t = self.tenants.pop(tenant_id, None)
        if t is None:
            return []
        freed = sorted(t.granted)
        self.pool.release(freed)
        for w in freed:
            self._record(tenant_id, "yield", w)
        # reservations it held go back to free too
        for w in t.reserved:
            self._record(tenant_id, "unreserve", w)
        self._check()
        return freed

    def request(self, tenant_id: str, n: int) -> List[int]:
        t = self.tenants[tenant_id]
        granted = self._grant_to(t, int(n))
        # a request that drained the reservation settles the steal ledger
        t.steal_owed = max(0, t.steal_owed - len(granted))
        return granted

    def steal(self, tenant_id: str, n: int) -> Dict[str, Any]:
        """Free capacity first; the shortfall becomes a preemption directive
        against lower-priority tenants.  Returns granted ids plus the
        number still pending (reserved-as-they-free, collect via a later
        ``request``)."""
        t = self.tenants[tenant_id]
        granted = self._grant_to(t, int(n))
        shortfall = int(n) - len(granted)
        pending = 0
        if shortfall > 0:
            pending = self._assign_preemption(t, shortfall,
                                              cause=self._req_ctx)
            t.steal_owed += pending
        if granted or pending:
            self._record(t.tenant_id, "steal",
                         granted[0] if granted else -1)
        self._check()
        return {"granted": granted, "pending": pending}

    def release(self, tenant_id: str, workers: Sequence[int]) -> List[int]:
        """Tenant-scoped release — a *yield* in multi-tenant vocabulary.
        Settles outstanding preemption first; the freed workers go to the
        stealer's reservation, the rest to the free set."""
        t = self.tenants[tenant_id]
        taken = [int(w) for w in workers if w in t.granted]
        for w in taken:
            t.granted.remove(w)
        self.pool.release(taken)
        settled = min(t.preempt_due, len(taken))
        t.preempt_due -= settled
        if t.preempt_due == 0:
            t.preempt_cause = None
        self._settle_freed(t, taken[:settled])
        for w in taken:
            self._record(t.tenant_id, "yield", w)
        self._check()
        return taken

    def fail(self, tenant_id: Optional[str], worker: int) -> None:
        w = int(worker)
        if tenant_id and tenant_id in self.tenants:
            t = self.tenants[tenant_id]
            if w in t.granted:
                t.granted.remove(w)
                # a death settles preemption debt like a release does — the
                # capacity is gone either way, don't shrink twice
                if t.preempt_due > 0:
                    t.preempt_due -= 1
            self._record(tenant_id, "fail", w)
        for t in self.tenants.values():
            if w in t.reserved:
                t.reserved.remove(w)
        self.pool.fail(w)
        self._check()

    def poll(self, tenant_id: str) -> Dict[str, int]:
        """Directive mailbox — recomputed from live state every time, so a
        directive the tenant fenced off is re-delivered, not lost."""
        t = self.tenants[tenant_id]
        offer = 0
        if len(t.granted) < t.max_workers and t.preempt_due == 0:
            # free capacity is offered to anyone below their ceiling; a
            # tenant under pressure doesn't wait for an offer — it steals
            offer = min(len(self._free()) + len(t.reserved),
                        t.max_workers - len(t.granted))
        out = {"preempt": t.preempt_due, "offer": offer}
        if t.preempt_due > 0 and t.preempt_cause is not None:
            # forward the thief's span context so the victim can parent
            # its preemption events on it (DESIGN.md §15)
            out["cause"] = dict(t.preempt_cause)
        return out

    # -- transport dispatch -------------------------------------------------
    def handle(self, req: dict) -> dict:
        """One request dict in, one response dict out — the shared body of
        the file and HTTP servers.  Ops without a ``tenant`` field keep the
        legacy single-Session pool semantics bit-for-bit."""
        op = req.get("op")
        tenant = req.get("tenant")
        # the requester's span context (shipped by the RPC transports)
        # scopes every record this op produces
        self._req_ctx = req.get("trace") if isinstance(
            req.get("trace"), dict) else None
        out: dict = {"op": op, "seq": req.get("seq")}
        try:
            if op == "release" and tenant:
                out["released"] = self.release(tenant, req["workers"])
            elif op == "yield" and tenant:
                out["released"] = self.release(tenant, req["workers"])
            elif op == "release":
                out["released"] = [int(w) for w in req["workers"]
                                   if w in self.pool.active]
                self.pool.release(req["workers"])
            elif op == "request" and tenant:
                out["granted"] = self.request(tenant, int(req["n"]))
            elif op == "request":
                out["granted"] = self.pool.request(
                    int(req["n"]), exclude=self._reserved_ids())
            elif op == "steal" and tenant:
                out.update(self.steal(tenant, int(req["n"])))
            elif op == "fail":
                self.fail(tenant, int(req["worker"]))
            elif op == "register" and tenant:
                out["granted"] = self.register(
                    tenant, priority=int(req.get("priority", 0)),
                    kind=req.get("kind", "train"),
                    workers=int(req.get("workers", 0)),
                    max_workers=req.get("max_workers"),
                    min_workers=int(req.get("min_workers", 1)))
            elif op == "deregister" and tenant:
                out["released"] = self.deregister(tenant)
            elif op == "poll" and tenant:
                out.update(self.poll(tenant))
            elif op == "metrics":
                out["events"] = list(self.events)
                out["tenants"] = {tid: t.state_dict()
                                  for tid, t in self.tenants.items()}
                out["total"] = self.pool.total + self.pool.spares
            elif op in ("status", "shutdown"):
                pass
            else:
                out["error"] = f"unknown op {op!r}"
        except KeyError as e:
            out["error"] = f"unknown tenant {e.args[0]!r} (register first)"
        finally:
            self._req_ctx = None
        out["active"] = self.pool.num_active
        return out

    # -- persistence (the file server's crash journal) ---------------------
    def state_dict(self) -> dict:
        return {"pool": self.pool.state_dict(),
                "tenants": [t.state_dict()
                            for t in self.tenants.values()]}

    @classmethod
    def from_state(cls, sd: dict) -> "ClusterScheduler":
        sched = cls(WorkerPool.from_state(sd["pool"]))
        for tsd in sd.get("tenants", []):
            t = Tenant.from_state(tsd)
            sched.tenants[t.tenant_id] = t
        sched._check()
        return sched
