"""Pipeline-parallel runtime: GPipe-style microbatch schedule over the
``model`` mesh axis via jax.shard_map (manual) with ``data``/``pod`` axes left
to XLA SPMD (auto) — FSDP/DP/vocab sharding ride on jit-level in_shardings.

The forward schedule is differentiable; jax.grad generates the reverse
pipeline (backward ppermutes run in the transposed direction), so 1F1B-like
interleaving is realised by XLA's scheduler within each tick.

dtype rule (XLA-CPU workaround, documented in DESIGN.md): any value whose
cotangent is psum'd over the *manual* axis at the shard_map boundary must be
float32 — i.e. embed/head/shared/final_norm params.  Stage params (sharded
over ``model``) stay bfloat16.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import DistConfig, ModelConfig
from repro.dynamics.config import DynamicsConfig
from repro.models import blocks as B
from repro.models import model as M

AUX_LOSS_COEF = 0.01


def _shard_map(f, *, mesh, in_specs, out_specs, axis_names):
    """jax.shard_map compat: on older jax (< jax.shard_map) fall back to
    jax.experimental.shard_map, fully manual, with check_rep=False
    (≙ check_vma=False).  Partial-auto (``auto=``) is deliberately NOT used
    there: it lowers axis_index via PartitionId, which XLA-CPU SPMD rejects.
    Axes unmentioned by the specs simply replicate — same math, DP/FSDP
    sharding of the non-manual axes only applies on current jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


@dataclasses.dataclass(frozen=True)
class PipelineShapes:
    """Concrete global shapes of one pipeline execution."""
    num_micro: int
    mb_global: int          # per-microbatch global batch (sharded over data)
    seq: int                # token positions fed to the decoder stream
    prefix: int = 0         # VLM patch prefix length (prepended)
    enc_seq: int = 0        # whisper encoder frames
    cache_len: int = 0      # decode cache capacity

    @property
    def seq_total(self) -> int:
        return self.seq + self.prefix


def plan_shapes(cfg: ModelConfig, dcfg: DistConfig, shape_kind: str,
                seq_len: int, global_batch: int, dp_degree: int
                ) -> PipelineShapes:
    """Derive microbatching from the shape cell and the mesh's DP degree."""
    if global_batch < dp_degree:
        # tiny-batch cells (e.g. long_500k B=1): batch not DP-shardable;
        # other dims (kv heads / cache capacity) shard over data instead
        shp = PipelineShapes(
            num_micro=1, mb_global=global_batch, seq=seq_len,
            prefix=cfg.num_patches if cfg.family == "vlm" else 0,
            enc_seq=cfg.encoder_seq if cfg.is_encdec else 0,
            cache_len=seq_len if shape_kind in ("decode", "prefill") else 0)
        return shp
    per_replica = max(1, global_batch // dp_degree)
    num_micro = min(per_replica, 4 * dcfg.num_stages)
    mb = max(1, per_replica // num_micro)
    num_micro = max(1, per_replica // mb)
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    enc_seq = cfg.encoder_seq if cfg.is_encdec else 0
    cache_len = seq_len if shape_kind in ("decode", "prefill") else 0
    return PipelineShapes(
        num_micro=num_micro, mb_global=mb * dp_degree,
        seq=seq_len, prefix=prefix, enc_seq=enc_seq, cache_len=cache_len)


def _ring(n):
    return [(i, (i + 1) % n) for i in range(n)]


def _make_stamp_or_none(stage_timer):
    """Stage-boundary host stamp for in-step timing; None when disabled
    (the common case — zero ops are added to the step)."""
    if stage_timer is None:
        return None
    from repro.obs.timing import make_stamp
    return make_stamp(stage_timer)


def _make_pin(mesh, dcfg):
    """Sharding pin for pipeline-carry leaves: batch dim over the DP axes.

    XLA's auto propagation sometimes assigns conflicting shardings to the
    carry across while-loop iterations and falls back to full
    rematerialization (replication) — pinning dim 0 at every tick boundary
    keeps the layout stable.  No-op when the batch dim is not divisible."""
    from jax.sharding import NamedSharding
    daxes = tuple(a for a in mesh.axis_names if a != "model")
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    spec_axes = daxes if len(daxes) > 1 else daxes[0]

    def pin(x):
        if not dcfg.pin_carry_sharding:
            return x
        if x.ndim >= 1 and x.shape[0] % dp == 0 and x.shape[0] >= dp:
            # the constraint must be built on the *context* (abstract) mesh:
            # inside shard_map 'model' is Manual there, not Auto.  Older jax
            # has no abstract-mesh tracking — the pin is a no-op there.
            am = getattr(jax.sharding, "get_abstract_mesh", lambda: None)()
            if am is None:
                return x
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(am, P(spec_axes,
                                       *([None] * (x.ndim - 1)))))
        return x

    return lambda tree: jax.tree.map(pin, tree)


def _stage_slice(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _init_carry(cfg, dyncfg, shapes: PipelineShapes, dtype, decode=False):
    mbg = shapes.mb_global
    s = 1 if decode else shapes.seq_total
    carry = {"x": jnp.zeros((mbg, s, cfg.d_model), dtype)}
    if cfg.is_encdec and not decode:
        carry["enc"] = jnp.zeros((mbg, shapes.enc_seq, cfg.d_model), dtype)
    if dyncfg.uses_early_exit and not decode:
        carry["exited"] = jnp.zeros((mbg, s), jnp.float32)
    return carry


# ---------------------------------------------------------------------------
# Training / evaluation loss
# ---------------------------------------------------------------------------
def build_loss_fn(cfg: ModelConfig, dcfg: DistConfig, dyncfg: DynamicsConfig,
                  mesh, shapes: PipelineShapes, mode: str = "train",
                  stage_timer=None):
    """Returns loss_fn(params, assignment, dyn, batch) -> (loss, stats).

    batch = {"tokens": [m, B, seq] i32, "labels": [m, B, seq] i32,
             "label_mask": [m, B, seq] f32, optional "prefix_emb"
             [m, B, P, d] f32, optional "frames" [m, B, enc_seq, d] f32}.
    stats: per-stage per-slot profiler aggregates {field: [S, L_max, ...]}.
    stage_timer: optional ``obs.timing.StageTimer`` — when set, every tick
    stamps host timestamps at the stage boundaries (in-step stage timing,
    DESIGN.md §15); numerically a no-op.
    """
    S = dcfg.num_stages
    dt = jnp.bfloat16 if dcfg.param_dtype == "bfloat16" else jnp.float32

    pin = _make_pin(mesh, dcfg)
    stamp = _make_stamp_or_none(stage_timer)

    def pipe(params, assignment, dyn, batch):
        stages = _stage_slice(params["stages"])
        tags = assignment["tags"][0]
        dyn_s = _stage_slice(dyn)
        shared = params["shared"]
        idx = jax.lax.axis_index("model")
        n = mesh.shape["model"]      # static axis extent (version-portable)
        T = shapes.num_micro + S - 1
        pos = jnp.arange(shapes.seq_total)
        depth_base = assignment["depth_base"][0]

        buf = _init_carry(cfg, dyncfg, shapes, dt)
        aux_acc = jnp.float32(0.0)
        stats0 = jax.tree.map(
            lambda sds: jnp.zeros((tags.shape[0],) + sds.shape, sds.dtype),
            B.stats_spec(cfg))

        def ingest(t):
            ti = jnp.clip(t, 0, shapes.num_micro - 1)
            tok = jax.lax.dynamic_index_in_dim(batch["tokens"], ti, 0, False)
            if os.environ.get("REPRO_DEBUG_NO_EMBED"):
                return jax.tree.map(jnp.zeros_like, buf)
            prefix = None
            if "prefix_emb" in batch:
                prefix = jax.lax.dynamic_index_in_dim(
                    batch["prefix_emb"], ti, 0, False).astype(dt)
            if "frames" in batch:
                prefix = jax.lax.dynamic_index_in_dim(
                    batch["frames"], ti, 0, False).astype(dt)
            carry = M.embed(params, cfg, tok, prefix_emb=prefix)
            carry["x"] = carry["x"].astype(dt)
            if "enc" in carry:
                carry["enc"] = carry["enc"].astype(dt)
            if dyncfg.uses_early_exit:
                carry["exited"] = jnp.zeros(
                    (tok.shape[0], shapes.seq_total), jnp.float32)
            return carry

        def stage_fn(carry, stats_acc_unused=None):
            return M.stage_forward(
                cfg, dcfg, dyncfg, mode, stages, shared, tags, dyn_s, carry,
                None, pos, depth_base)

        if dcfg.remat == "full":
            stage_fn = jax.checkpoint(stage_fn)

        def tick(state, t):
            buf, aux_acc, stats_acc = state
            # embedding gather (and its vocab-shard collective) runs on
            # stage 0 only — real lax.cond branch, not a masked select
            fresh = jax.lax.cond(
                idx == 0, ingest,
                lambda _t: jax.tree.map(jnp.zeros_like, buf), t)
            carry = jax.tree.map(
                lambda a, b: jnp.where(idx == 0, a, b), fresh, buf)
            if stamp is not None:
                carry = {**carry, "x": stamp(carry["x"], idx, jnp.int32(0))}
            carry, _, stats, aux = stage_fn(carry)
            if stamp is not None:
                carry = {**carry, "x": stamp(carry["x"], idx, jnp.int32(1))}
            # ---- last stage emits this tick's finished microbatch hidden;
            # the loss (head matmul) runs ONCE after the schedule, so its
            # logits are never live across ticks (memory) and probes count
            # it per-microbatch, not per-tick (roofline accuracy)
            emit_valid = ((t - (n - 1)) >= 0) & (idx == n - 1)
            h_out = jnp.where(emit_valid,
                              carry["x"][:, shapes.prefix:],
                              jnp.zeros_like(carry["x"][:, shapes.prefix:]))
            mvalid = ((t - idx) >= 0) & ((t - idx) < shapes.num_micro)
            aux_acc = aux_acc + jnp.where(mvalid, aux, 0.0)
            stats_acc = jax.tree.map(
                lambda acc, s_: acc + jnp.where(mvalid, s_,
                                                jnp.zeros_like(s_)),
                stats_acc, stats)
            carry = pin(carry)
            buf = jax.tree.map(
                lambda a: jax.lax.ppermute(a, "model", _ring(n)), carry)
            return (buf, aux_acc, stats_acc), pin({"h": h_out})["h"]

        state = (buf, aux_acc, stats0)
        if dcfg.unroll_ticks:
            hs = []
            for t in range(T):
                state, h_out = tick(state, jnp.int32(t))
                hs.append(h_out)
            h_seq = jnp.stack(hs[S - 1:S - 1 + shapes.num_micro])
        else:
            state, hs = jax.lax.scan(tick, state, jnp.arange(T))
            h_seq = jax.lax.slice_in_dim(hs, S - 1, S - 1 + shapes.num_micro,
                                         axis=0)
        _, aux_acc, stats_acc = state

        # ---- vocab loss on the last stage only (single real branch)
        def full_loss(h_seq):
            def one(carry_acc, inp):
                h, lab, lmask = inp

                def body(h, lab, lmask):
                    hn = M.rms_norm(h, params["final_norm"], cfg.norm_eps)
                    head = params.get("head")
                    if head is None:
                        head = params["embed"].T
                    logits = hn.astype(jnp.float32) @ head.astype(
                        jnp.float32)
                    lse = jax.nn.logsumexp(logits, axis=-1)
                    ll = jnp.take_along_axis(logits, lab[..., None],
                                             -1)[..., 0]
                    return (jnp.sum((lse - ll) * lmask), jnp.sum(lmask))

                nll, cnt = jax.checkpoint(body)(h, lab, lmask)
                return (carry_acc[0] + nll, carry_acc[1] + cnt), None

            acc0 = (jnp.float32(0.0), jnp.float32(0.0))
            if dcfg.unroll_ticks:
                acc = acc0
                for i in range(shapes.num_micro):
                    acc, _ = one(acc, (h_seq[i], batch["labels"][i],
                                       batch["label_mask"][i]))
            else:
                acc, _ = jax.lax.scan(
                    one, acc0,
                    (h_seq, batch["labels"], batch["label_mask"]))
            return acc

        if os.environ.get("REPRO_DEBUG_NO_LOSS"):
            nll = jnp.sum(h_seq.astype(jnp.float32) ** 2)
            cnt = jnp.float32(1.0)
        else:
            nll, cnt = jax.lax.cond(
                idx == n - 1, full_loss,
                lambda _h: (jnp.float32(0.0), jnp.float32(0.0)), h_seq)
        loss = jax.lax.psum(nll, "model") / jnp.maximum(
            jax.lax.psum(cnt, "model"), 1.0)
        aux = jax.lax.psum(aux_acc, "model") / (
            shapes.num_micro * max(1, cfg.total_blocks()))
        loss = loss + AUX_LOSS_COEF * aux
        return loss, stats_acc

    in_specs = (
        {"embed": P(), "final_norm": P(), "shared": P(),
         "stages": P("model"),
         **({"head": P()} if not cfg.tie_embeddings else {})},
        P("model"),       # assignment arrays lead with stage axis
        P("model"),       # dyn arrays lead with stage axis
        P(),              # batch replicated over model (sharded over data)
    )
    return _shard_map(
        pipe, mesh=mesh, in_specs=in_specs,
        out_specs=(P(), P("model")), axis_names={"model"})


# ---------------------------------------------------------------------------
# Decode (serve_step): one token for every request, pipelined microbatches
# ---------------------------------------------------------------------------
def build_decode_fn(cfg: ModelConfig, dcfg: DistConfig,
                    dyncfg: DynamicsConfig, mesh, shapes: PipelineShapes,
                    stage_timer=None, *, paged: bool = False,
                    temperature: float = 0.0,
                    num_micro: Optional[int] = None):
    """Returns decode_fn(params, assignment, dyn, cache, tokens, pos[,
    page_table][, seeds])
    -> (next_ids [m, B] i32, logprobs [m, B] f32, new_cache,
    moe_drop_sum f32 — MoE capacity-drop fractions summed over
    (moe slot, microbatch) passes; 0 for non-MoE archs).

    tokens: [m, B] current token per request; pos: scalar position (every
    lane at the same point, the one-shot serving path) or [m, B] per-lane
    absolute positions (continuous batching: each request decodes at its
    own position; cache writes and attention masks are per-lane).
    cache: stacked {field: [S, L_max, m, B, ...]}.

    ``paged``: the cache is the block-paged pool {kp, vp: [S, L_max,
    pool+1, page, kv, hd]} (no micro axis — all lanes share it) and the fn
    takes ``page_table`` [m, B, J] int32 (-1 = unmapped) as an extra arg;
    pool writes on invalid ticks are steered into the trash block instead
    of being masked out after the fact.

    ``temperature``: > 0 adds a ``seeds`` [m, B] int32 arg and samples the
    emitted token from softmax(logits / temperature) with a per-lane key;
    0 keeps the exact argmax graph (bit-identical to before).

    ``num_micro``: compile-time live microbatch count (defaults to
    shapes.num_micro).  Inputs/outputs keep their full [num_micro_full, B]
    shapes, but the tick loop runs only ``num_micro + S - 1`` ticks so
    all-empty trailing microbatch rows cost nothing.
    """
    S = dcfg.num_stages
    dt = jnp.bfloat16 if dcfg.param_dtype == "bfloat16" else jnp.float32
    m_live = shapes.num_micro if num_micro is None else num_micro
    if not (1 <= m_live <= shapes.num_micro):
        raise ValueError(f"num_micro={m_live} outside [1, "
                         f"{shapes.num_micro}]")

    pin = _make_pin(mesh, dcfg)
    stamp = _make_stamp_or_none(stage_timer)

    def pipe(params, assignment, dyn, cache, tokens, pos, *extra):
        ei = 0
        if paged:
            page_table = extra[ei]
            ei += 1
        if temperature > 0.0:
            seeds = extra[ei]
            ei += 1
        stages = _stage_slice(params["stages"])
        tags = assignment["tags"][0]
        dyn_s = _stage_slice(dyn)
        cache_s = _stage_slice(cache)           # {field: [L_max, m, B, ...]}
        shared = params["shared"]
        idx = jax.lax.axis_index("model")
        n = mesh.shape["model"]      # static axis extent (version-portable)
        m = m_live
        T = m + S - 1
        per_lane = jnp.ndim(pos) == 2           # [m, B] positions
        if per_lane and cfg.is_encdec:
            raise NotImplementedError(
                "per-lane decode positions need a per-lane dec_pos gather; "
                "encoder-decoder serving uses the scalar-pos path")
        if paged and not per_lane:
            raise NotImplementedError(
                "paged decode requires per-lane positions")

        buf = _init_carry(cfg, dyncfg, shapes, dt, decode=True)
        ids_out = jnp.zeros((shapes.num_micro, shapes.mb_global), jnp.int32)
        lp_out = jnp.zeros((shapes.num_micro, shapes.mb_global),
                           jnp.float32)
        drop_out = jnp.float32(0.0)   # MoE capacity-drop fraction, summed
        #   over (moe slot, microbatch) passes — host side divides by the
        #   pass count; zero for non-MoE archs

        def ingest(t):
            ti = jnp.clip(t, 0, m - 1)
            tok = jax.lax.dynamic_index_in_dim(tokens, ti, 0, False)
            x = jnp.take(params["embed"].astype(jnp.float32), tok, axis=0)
            if cfg.is_encdec:
                pe = jax.lax.dynamic_slice_in_dim(
                    params["shared"]["dec_pos"].astype(jnp.float32),
                    jnp.clip(pos, 0, cfg.max_seq_len - 1), 1, 0)
                x = x + pe[0][None]
            return {"x": x[:, None, :].astype(dt)}

        def tick(state, t):
            buf, cache_s, ids_out, lp_out, drop_out = state
            mi = jnp.clip(t - idx, 0, m - 1)
            mvalid = ((t - idx) >= 0) & ((t - idx) < m)
            fresh = jax.lax.cond(
                idx == 0, ingest,
                lambda _t: jax.tree.map(jnp.zeros_like, buf), t)
            carry = jax.tree.map(
                lambda a, b: jnp.where(idx == 0, a, b), fresh, buf)
            if paged:
                # pool leaves have no micro axis; thread the tick's page
                # table + write-ok flag in as cache entries so they ride
                # the per-slot gather / masked scan like any other leaf
                pt_mb = jax.lax.dynamic_index_in_dim(
                    page_table, mi, 0, False)          # [B, J]
                L_m = tags.shape[0]
                cache_mb = dict(cache_s)
                cache_mb["pt"] = jnp.broadcast_to(
                    pt_mb[None], (L_m,) + pt_mb.shape)
                cache_mb["wok"] = jnp.broadcast_to(
                    mvalid.astype(jnp.int32), (L_m,))
            else:
                cache_mb = jax.tree.map(lambda a: a[:, mi], cache_s)
            pos_mb = (jax.lax.dynamic_index_in_dim(pos, mi, 0, False)
                      if per_lane else pos)
            if stamp is not None:
                carry = {**carry, "x": stamp(carry["x"], idx, jnp.int32(0))}
            carry, new_cache_mb, st, _ = M.stage_forward(
                cfg, dcfg, dyncfg, "decode", stages, shared, tags, dyn_s,
                carry, cache_mb, pos_mb, idx * tags.shape[0])
            if stamp is not None:
                carry = {**carry, "x": stamp(carry["x"], idx, jnp.int32(1))}
            drop_out = drop_out + (jnp.sum(st["moe_dropped"])
                                   * mvalid.astype(jnp.float32))
            if paged:
                # invalid-tick writes already landed in the trash block
                # (wok gating), so the new pool is taken as-is
                cache_s = {f: new_cache_mb[f] for f in cache_s}
            else:
                cache_s = jax.tree.map(
                    lambda full, nc, old:
                    jax.lax.dynamic_update_index_in_dim(
                        full, jnp.where(mvalid, nc, old), mi, 1),
                    cache_s, new_cache_mb, cache_mb)
            # emit at last stage only (real branch; head matmul skipped
            # elsewhere)
            li = jnp.clip(t - (n - 1), 0, m - 1)
            emit = ((t - (n - 1)) >= 0) & (idx == n - 1)

            def do_head(h):
                logits = M.lm_logits(params, cfg, h)
                if temperature > 0.0:
                    # per-lane sampling: each lane folds its own seed into
                    # a key, so lanes are independent and replayable
                    sd = jax.lax.dynamic_index_in_dim(seeds, li, 0, False)

                    def samp(s_, lg):
                        return jax.random.categorical(
                            jax.random.PRNGKey(s_),
                            lg / jnp.float32(temperature))
                    nid_ = jax.vmap(samp)(sd, logits).astype(jnp.int32)
                else:
                    nid_ = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                lp_ = jax.nn.log_softmax(logits, axis=-1)
                return nid_, jnp.take_along_axis(lp_, nid_[:, None],
                                                 -1)[:, 0]

            nid, nlp = jax.lax.cond(
                emit, do_head,
                lambda h: (jnp.zeros((h.shape[0],), jnp.int32),
                           jnp.zeros((h.shape[0],), jnp.float32)),
                carry["x"][:, 0])
            ids_out = jax.lax.dynamic_update_index_in_dim(
                ids_out, jnp.where(emit, nid, ids_out[li]), li, 0)
            lp_out = jax.lax.dynamic_update_index_in_dim(
                lp_out, jnp.where(emit, nlp, lp_out[li]), li, 0)
            carry = pin(carry)
            buf = jax.tree.map(
                lambda a: jax.lax.ppermute(a, "model", _ring(n)), carry)
            return (buf, cache_s, ids_out, lp_out, drop_out), None

        if dcfg.unroll_ticks:
            state = (buf, cache_s, ids_out, lp_out, drop_out)
            for t in range(T):
                state, _ = tick(state, jnp.int32(t))
            (buf, cache_s, ids_out, lp_out, drop_out) = state
        else:
            (buf, cache_s, ids_out, lp_out, drop_out), _ = jax.lax.scan(
                tick, (buf, cache_s, ids_out, lp_out, drop_out),
                jnp.arange(T))
        # ids live on the last stage; broadcast (tiny)
        ids_out = jax.lax.psum(
            jnp.where(idx == n - 1, ids_out, jnp.zeros_like(ids_out)),
            "model")
        lp_out = jax.lax.psum(
            jnp.where(idx == n - 1, lp_out, jnp.zeros_like(lp_out)), "model")
        drop_out = jax.lax.psum(drop_out, "model")
        new_cache = jax.tree.map(lambda a: a[None], cache_s)
        return ids_out, lp_out, new_cache, drop_out

    n_extra = int(paged) + int(temperature > 0.0)
    in_specs = (
        {"embed": P(), "final_norm": P(), "shared": P(),
         "stages": P("model"),
         **({"head": P()} if not cfg.tie_embeddings else {})},
        P("model"), P("model"), P("model"), P(), P()) + (P(),) * n_extra
    return _shard_map(
        pipe, mesh=mesh, in_specs=in_specs,
        out_specs=(P(), P(), P("model"), P()), axis_names={"model"})


# ---------------------------------------------------------------------------
# Prefill: forward pass that fills the decode cache
# ---------------------------------------------------------------------------
def build_prefill_fn(cfg: ModelConfig, dcfg: DistConfig,
                     dyncfg: DynamicsConfig, mesh, shapes: PipelineShapes,
                     stage_timer=None):
    """Returns prefill_fn(params, assignment, dyn, cache, batch)
    -> (last_ids [m, B] i32, new_cache, moe_drop_sum f32)."""
    S = dcfg.num_stages
    dt = jnp.bfloat16 if dcfg.param_dtype == "bfloat16" else jnp.float32

    pin = _make_pin(mesh, dcfg)
    stamp = _make_stamp_or_none(stage_timer)

    def pipe(params, assignment, dyn, cache, batch):
        stages = _stage_slice(params["stages"])
        tags = assignment["tags"][0]
        dyn_s = _stage_slice(dyn)
        cache_s = _stage_slice(cache)
        shared = params["shared"]
        idx = jax.lax.axis_index("model")
        n = mesh.shape["model"]      # static axis extent (version-portable)
        m = shapes.num_micro
        T = m + S - 1
        pos = jnp.arange(shapes.seq_total)

        buf = _init_carry(cfg, dyncfg, shapes, dt)
        ids_out = jnp.zeros((m, shapes.mb_global), jnp.int32)
        drop_out = jnp.float32(0.0)   # MoE capacity drops, as in decode

        def ingest(t):
            ti = jnp.clip(t, 0, m - 1)
            tok = jax.lax.dynamic_index_in_dim(batch["tokens"], ti, 0, False)
            prefix = None
            if "prefix_emb" in batch:
                prefix = jax.lax.dynamic_index_in_dim(
                    batch["prefix_emb"], ti, 0, False).astype(dt)
            if "frames" in batch:
                prefix = jax.lax.dynamic_index_in_dim(
                    batch["frames"], ti, 0, False).astype(dt)
            carry = M.embed(params, cfg, tok, prefix_emb=prefix)
            carry["x"] = carry["x"].astype(dt)
            if "enc" in carry:
                carry["enc"] = carry["enc"].astype(dt)
            if dyncfg.uses_early_exit:
                carry["exited"] = jnp.zeros(
                    (tok.shape[0], shapes.seq_total), jnp.float32)
            return carry

        def tick(state, t):
            buf, cache_s, ids_out, drop_out = state
            mi = jnp.clip(t - idx, 0, m - 1)
            mvalid = ((t - idx) >= 0) & ((t - idx) < m)
            fresh = jax.lax.cond(
                idx == 0, ingest,
                lambda _t: jax.tree.map(jnp.zeros_like, buf), t)
            carry = jax.tree.map(
                lambda a, b: jnp.where(idx == 0, a, b), fresh, buf)
            cache_mb = jax.tree.map(lambda a: a[:, mi], cache_s)
            if stamp is not None:
                carry = {**carry, "x": stamp(carry["x"], idx, jnp.int32(0))}
            carry, new_cache_mb, st, _ = M.stage_forward(
                cfg, dcfg, dyncfg, "prefill", stages, shared, tags, dyn_s,
                carry, cache_mb, pos, idx * tags.shape[0])
            if stamp is not None:
                carry = {**carry, "x": stamp(carry["x"], idx, jnp.int32(1))}
            drop_out = drop_out + (jnp.sum(st["moe_dropped"])
                                   * mvalid.astype(jnp.float32))
            cache_s = jax.tree.map(
                lambda full, nc, old: jax.lax.dynamic_update_index_in_dim(
                    full, jnp.where(mvalid, nc, old), mi, 1),
                cache_s, new_cache_mb, cache_mb)
            li = jnp.clip(t - (n - 1), 0, m - 1)
            emit = ((t - (n - 1)) >= 0) & (idx == n - 1)
            nid = jax.lax.cond(
                emit,
                lambda h: jnp.argmax(M.lm_logits(params, cfg, h),
                                     axis=-1).astype(jnp.int32),
                lambda h: jnp.zeros((h.shape[0],), jnp.int32),
                carry["x"][:, -1])
            ids_out = jax.lax.dynamic_update_index_in_dim(
                ids_out, jnp.where(emit, nid, ids_out[li]), li, 0)
            carry = pin(carry)
            buf = jax.tree.map(
                lambda a: jax.lax.ppermute(a, "model", _ring(n)), carry)
            return (buf, cache_s, ids_out, drop_out), None

        if dcfg.unroll_ticks:
            state = (buf, cache_s, ids_out, drop_out)
            for t in range(T):
                state, _ = tick(state, jnp.int32(t))
            (buf, cache_s, ids_out, drop_out) = state
        else:
            (buf, cache_s, ids_out, drop_out), _ = jax.lax.scan(
                tick, (buf, cache_s, ids_out, drop_out), jnp.arange(T))
        ids_out = jax.lax.psum(
            jnp.where(idx == n - 1, ids_out, jnp.zeros_like(ids_out)),
            "model")
        drop_out = jax.lax.psum(drop_out, "model")
        return ids_out, jax.tree.map(lambda a: a[None], cache_s), drop_out

    in_specs = (
        {"embed": P(), "final_norm": P(), "shared": P(),
         "stages": P("model"),
         **({"head": P()} if not cfg.tie_embeddings else {})},
        P("model"), P("model"), P("model"), P())
    return _shard_map(
        pipe, mesh=mesh, in_specs=in_specs,
        out_specs=(P(), P("model"), P()), axis_names={"model"})
