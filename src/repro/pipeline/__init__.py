from repro.pipeline.pipeline import (
    PipelineShapes, build_decode_fn, build_loss_fn, build_prefill_fn,
)

__all__ = ["PipelineShapes", "build_decode_fn", "build_loss_fn",
           "build_prefill_fn"]
