"""Shared CLI adapter: one flag-builder for every entry point.

Both launchers (`repro.launch.train`, `repro.launch.serve`) build their
argument surface from the same three ingredients, so the flag set can never
drift between them again:

  1. ``--config run.json`` / ``--set path=value`` / ``--dump-config`` —
     the spec-native interface (``add_config_args``);
  2. **auto-generated dotted flags**, one per ``RunSpec`` leaf field
     (``--controller.repack.policy first_fit``), derived from the spec
     dataclasses by reflection (``add_spec_flags``) — new spec fields
     become flags for free;
  3. a small per-CLI table of **legacy aliases** (``--stages`` ->
     ``parallel.stages``) kept for back-compat (``add_alias_flags``).

Precedence, lowest to highest: spec defaults < ``--config`` file <
per-CLI defaults for unset alias flags (only when no ``--config`` is
given, preserving each CLI's historical defaults) < explicitly passed
alias/dotted flags < ``--set`` overrides.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Any, Dict, List, Optional, Sequence

from repro.api.specs import RunSpec, SpecError, leaf_fields

_SPEC_DEST_PREFIX = "spec__"


@dataclasses.dataclass(frozen=True)
class Alias:
    """One legacy flag mapped onto a spec leaf.  ``flag=True`` renders it
    as an argparse store_true switch; ``deprecated`` prints a warning on
    use."""
    opt: str                 # e.g. "--stages"
    path: str                # e.g. "parallel.stages"
    help: str = ""
    flag: bool = False
    choices: Optional[Sequence[str]] = None
    deprecated: Optional[str] = None   # replacement hint


def add_config_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--config", default=None, metavar="RUN.JSON",
                    help="load a RunSpec config file (see "
                         "configs/scenarios/ for presets)")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    dest="set_overrides",
                    help="dotted spec override, e.g. "
                         "--set controller.repack.policy=first_fit "
                         "(repeatable; highest precedence)")
    ap.add_argument("--dump-config", action="store_true",
                    help="print the resolved RunSpec JSON and exit "
                         "without running")


def add_spec_flags(ap: argparse.ArgumentParser) -> None:
    """One auto-generated option per spec leaf: ``--parallel.stages 8``.
    Values are strings here; typed coercion happens in ``RunSpec.override``
    so bools/Optionals parse the same as in ``--set``."""
    grp = ap.add_argument_group(
        "spec fields", "dotted overrides generated from RunSpec "
                       "(same semantics as --set PATH=VALUE)")
    for path, f in leaf_fields():
        if path == "schema_version":
            continue
        try:
            grp.add_argument(
                f"--{path}", default=None, metavar="V",
                dest=_SPEC_DEST_PREFIX + path.replace(".", "__"),
                help=f"[{_type_name(f.type)}] default: {f.default}")
        except argparse.ArgumentError:
            # a dotless top-level leaf ("--seed") already covered by an
            # alias flag with the same spelling — the alias wins
            pass


def add_alias_flags(ap: argparse.ArgumentParser,
                    aliases: Sequence[Alias]) -> None:
    for a in aliases:
        kw: Dict[str, Any] = {"default": None, "help": a.help,
                              "dest": _alias_dest(a)}
        if a.flag:
            kw["action"] = "store_true"
            kw["default"] = None
        if a.choices:
            kw["choices"] = list(a.choices)
        ap.add_argument(a.opt, **kw)


def _alias_dest(a: Alias) -> str:
    return "alias__" + a.path.replace(".", "__")


def _type_name(t) -> str:
    return getattr(t, "__name__", None) or str(t).replace("typing.", "")


def build_spec(args: argparse.Namespace, aliases: Sequence[Alias],
               base: Optional[RunSpec] = None,
               cli_defaults: Optional[Dict[str, Any]] = None) -> RunSpec:
    """Resolve the final ``RunSpec`` from parsed args (see module docstring
    for precedence).  ``cli_defaults`` are this CLI's historical defaults
    where they differ from the spec's (e.g. the train CLI always ran a
    reduced 8-layer model); they apply only when no ``--config`` is given —
    a config file is the complete source of truth."""
    spec = base or RunSpec()
    if args.config:
        spec = RunSpec.load(args.config)
    overrides: Dict[str, Any] = {}
    if not args.config:
        overrides.update(cli_defaults or {})
    for a in aliases:
        v = getattr(args, _alias_dest(a), None)
        if v is not None:
            if a.deprecated:
                print(f"warning: {a.opt} is deprecated; {a.deprecated}",
                      file=sys.stderr)
            overrides[a.path] = v
    for path, f in leaf_fields():
        v = getattr(args, _SPEC_DEST_PREFIX + path.replace(".", "__"), None)
        if v is not None:
            overrides[path] = v
    for item in args.set_overrides:
        if "=" not in item:
            raise SpecError(f"--set expects PATH=VALUE, got {item!r}")
        path, _, value = item.partition("=")
        overrides[path.strip()] = value
    return spec.override(overrides) if overrides else spec


def maybe_dump(args: argparse.Namespace, spec: RunSpec) -> bool:
    if getattr(args, "dump_config", False):
        print(spec.to_json())
        return True
    return False


# ---------------------------------------------------------------------------
# Alias tables: the historical flag surfaces of the two CLIs.  Shared
# entries live in _COMMON so train/serve can't drift on them again.
# ---------------------------------------------------------------------------
_COMMON: List[Alias] = [
    Alias("--arch", "model.arch"),
    Alias("--layers", "model.layers",
          help="reduce the arch to this many layers (none = full size)"),
    Alias("--d-model", "model.d_model"),
    Alias("--stages", "parallel.stages"),
    Alias("--mb-global", "parallel.mb_global"),
    Alias("--dynamism", "dynamics.kind",
          help="dynamism scheme (spec field dynamics.kind)"),
    Alias("--kernel-impl", "parallel.kernel_impl",
          choices=["reference", "scan", "pallas"]),
    Alias("--measure-stage-times", "controller.measure_stage_times",
          flag=True,
          help="feed MEASURED per-stage wall times (engine stage probe) "
               "into the straggler detector / serve report"),
    Alias("--job-manager", "cluster.job_manager",
          choices=["inproc", "file", "http"],
          help="'file' puts the WorkerPool behind a file-RPC server in a "
               "separate process; 'http' behind the multi-tenant cluster "
               "scheduler's HTTP job manager"),
    Alias("--job-manager-dir", "cluster.job_manager_dir"),
    Alias("--tenant-id", "cluster.tenant_id",
          help="register this run as a cluster tenant (multi-tenant "
               "scheduling; requires --job-manager file|http)"),
    Alias("--priority", "cluster.priority",
          help="tenant priority — higher-priority tenants can steal "
               "workers from lower ones at their next safe point"),
    Alias("--manager-url", "cluster.manager_url",
          help="attach to an already-running HTTP job manager "
               "(http://host:port) instead of spawning one"),
    Alias("--chaos", "faults.enabled", flag=True,
          help="inject a seeded fault schedule (worker crashes, manager "
               "kills, RPC loss) — see faults.* fields and DESIGN.md §12"),
    Alias("--chaos-seed", "faults.seed",
          help="fault-schedule seed; same seed => byte-identical faults"),
    Alias("--spares", "cluster.spares",
          help="spare workers the job manager can grant beyond the "
               "initial pool (crash recovery headroom)"),
    Alias("--seed", "seed"),
    Alias("--log-every", "log_every"),
]

TRAIN_ALIASES: List[Alias] = _COMMON + [
    Alias("--steps", "steps"),
    Alias("--seq", "parallel.seq"),
    Alias("--num-micro", "parallel.num_micro"),
    Alias("--balancer", "controller.balancer",
          choices=["diffusion", "partition"]),
    Alias("--rebalance-every", "controller.rebalance_every"),
    Alias("--ckpt-dir", "ckpt_dir"),
    Alias("--ckpt-every", "ckpt_every",
          help="take a crash-safe safe point every N steps (resumable "
               "with Session.resume / --resume); needs --ckpt-dir"),
    Alias("--repack", "controller.repack.enabled", flag=True,
          help="enable live worker consolidation (paper Alg. 2)"),
    Alias("--repack-policy", "controller.repack.policy",
          choices=["adjacent", "first_fit"]),
    Alias("--repack-mem-cap", "controller.repack.mem_cap",
          help="per-worker memory budget as a multiple of the unpruned "
               "per-stage footprint"),
    Alias("--repack-target", "controller.repack.target",
          help="never consolidate below this many workers"),
    Alias("--grow-back", "cluster.grow_back",
          deprecated="use --autoscale (signal-driven re-expansion)",
          help="DEPRECATED: re-expand N steps after a shrink"),
    Alias("--async-controller", "controller.async_decide", flag=True,
          help="run profile->decide on a background thread "
               "(double-buffered stats mailbox, epoch-fenced plans)"),
    Alias("--async-drain", "controller.async_drain", flag=True,
          help="deterministic async mode: block for each decision "
               "(parity testing)"),
    Alias("--autoscale", "cluster.autoscale", flag=True,
          help="signal-driven shrink/grow: heartbeat failures/recoveries "
               "(+ throughput watermark with --autoscale-watermark)"),
    Alias("--autoscale-watermark", "cluster.autoscale_watermark", flag=True,
          help="also scale on the per-worker throughput watermark "
               "(wall-clock based — leave off on noisy shared machines)"),
    Alias("--heartbeat-timeout", "cluster.heartbeat_timeout",
          help="missed-beat timeout in steps (simulated clock)"),
    Alias("--simulate-recover", "cluster.simulate_recover",
          help="revive all non-active workers at this step "
               "(heartbeat-recovery demo)"),
    Alias("--straggler", "controller.straggler",
          help="simulate slow workers, e.g. '2:1.5' (worker 2 runs 1.5x "
               "slow); the detector feeds the balancer"),
]

# the train CLI's historical defaults where they differ from the spec's
TRAIN_CLI_DEFAULTS: Dict[str, Any] = {"model.layers": 8}

SERVE_ALIASES: List[Alias] = _COMMON + [
    Alias("--micro", "parallel.num_micro"),
    Alias("--prompt-len", "serve.prompt_len"),
    Alias("--gen", "serve.gen"),
    Alias("--requests", "serve.requests"),
    Alias("--min-prompt", "serve.min_prompt"),
    Alias("--burst-period", "serve.burst_period"),
    Alias("--burst-len", "serve.burst_len"),
    Alias("--burst-rate", "serve.burst_rate"),
    Alias("--lull-rate", "serve.lull_rate"),
    Alias("--early-exit-frac", "serve.early_exit_frac"),
    Alias("--defrag-every", "serve.defrag_every"),
    Alias("--autoscale", "cluster.autoscale", flag=True,
          help="queue-depth/occupancy watermark scaling"),
    Alias("--min-stages", "serve.min_stages"),
    Alias("--queue-high", "serve.queue_high"),
    Alias("--occupancy-low", "serve.occupancy_low"),
    Alias("--patience", "serve.patience"),
    Alias("--cooldown", "serve.cooldown"),
    Alias("--latency-slo-s", "serve.latency_slo_s"),
    Alias("--max-ticks", "serve.max_ticks"),
    Alias("--kv-page-size", "serve.kv_page_size",
          help="tokens per KV block; >0 switches serving to the paged KV "
               "subsystem (0 = dense contiguous lanes)"),
    Alias("--kv-pool-pages", "serve.kv_pool_pages",
          help="physical KV blocks in the pool (0 = dense-equivalent "
               "auto-size)"),
    Alias("--prefix-cache", "serve.prefix_cache", flag=True,
          help="share full prompt pages across requests with a common "
               "prefix (copy-on-write; requires --kv-page-size)"),
    Alias("--temperature", "serve.temperature",
          help="per-lane decode sampling temperature (0 = argmax)"),
]

# the serve CLI's historical defaults where they differ from the spec's
SERVE_CLI_DEFAULTS: Dict[str, Any] = {"model.layers": 8,
                                      "parallel.num_micro": 2}
