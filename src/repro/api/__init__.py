"""The typed front door: ``RunSpec`` describes a run, ``Session`` runs it.

    from repro.api import RunSpec, Session, scenario

    with Session(scenario("early_exit")) as s:
        report = s.train()

See DESIGN.md §11 for the layering and the deprecation policy covering the
legacy ``run_training``/``run_elastic_serving`` kwarg shims.
"""
from repro.api.scenarios import SCENARIOS, scenario, scenario_names
from repro.api.session import Session, SessionEvent
from repro.api.specs import (SCHEMA_VERSION, ClusterSpec, ControllerSpec,
                             DynamicsSpec, ModelSpec, ParallelSpec,
                             RepackSpec, RunSpec, ServeSpec, SpecError)

__all__ = [
    "SCHEMA_VERSION", "ClusterSpec", "ControllerSpec", "DynamicsSpec",
    "ModelSpec", "ParallelSpec", "RepackSpec", "RunSpec", "ServeSpec",
    "SpecError", "Session", "SessionEvent", "SCENARIOS", "scenario",
    "scenario_names",
]
